"""Regenerate the §Roofline table inside EXPERIMENTS.md from results/dryrun.

Replaces the block between <!-- ROOFLINE-TABLE --> and the following blank
'Reading of the baselines' paragraph marker with a markdown table (single-pod
rows first, then multi-pod, optimized '+' rows inline).
"""

from __future__ import annotations

import glob
import json

ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def rows():
    out = []
    for path in sorted(glob.glob("results/dryrun/*.json")):
        with open(path) as f:
            r = json.load(f)
        if r.get("status") != "ok":
            continue
        out.append(r)
    out.sort(key=lambda r: (r["mesh"] != "single", r["arch"],
                            ORDER.get(r["shape"], 9)))
    return out


def fmt(rs):
    lines = [
        "| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) | bound "
        "| useful | roofline |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rs:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute']:.3e} | {r['t_memory']:.3e} "
            f"| {r['t_collective']:.3e} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {100*r['roofline_fraction']:.1f}% |")
    return "\n".join(lines)


def main() -> None:
    rs = rows()
    table = fmt(rs)
    text = open("EXPERIMENTS.md").read()
    marker = "<!-- ROOFLINE-TABLE -->"
    start = text.index(marker)
    end = text.index("\nReading of the baselines", start)
    text = text[:start] + marker + "\n\n" + table + "\n" + text[end:]
    open("EXPERIMENTS.md", "w").write(text)
    print(f"embedded {len(rs)} rows")


if __name__ == "__main__":
    main()
