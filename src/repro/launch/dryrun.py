import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory / cost / collective analysis.

The two lines above run before ANY other import (jax locks the device count
on first init); 512 placeholder host devices back the (2, 16, 16) multi-pod
mesh and the (16, 16) single-pod mesh.

Per cell we lower the REAL step function — train_step (fwd+bwd+AdamW) for
train shapes, prefill for prefill shapes, one-token decode_step with a full
KV/SSM cache for decode shapes — against pure ShapeDtypeStruct inputs (no
allocation), compile, and dump:

  * compiled.memory_analysis()   (fits-in-HBM evidence)
  * compiled.cost_analysis()     (FLOPs / bytes for the roofline)
  * collective bytes parsed from the optimized HLO

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch import mesh as mesh_lib  # noqa: E402
from repro.launch import specs  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models.config import SHAPES, ModelConfig, ShapeSpec, supports_shape  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.roofline import analysis as roofline  # noqa: E402
from repro.training.train import make_train_step  # noqa: E402


def rules_for(shape: ShapeSpec, cfg: ModelConfig) -> dict:
    """Per-shape logical->mesh overrides (see DESIGN.md §5)."""
    if shape.kind == "train":
        return {"batch": ("pod", "data"), "seq_kv": None}
    if shape.kind == "prefill":
        # batch owns the data axis; the emitted KV caches shard their seq dim
        # over the (otherwise idle for caches) model axis — a 60-layer 32k
        # bf16 cache is ~16 GB/device if left replicated across 'model'
        return {"batch": ("pod", "data"), "seq_kv": ("model",)}
    if shape.name == "long_500k":
        # batch=1: DP is useless; shard the KV/state sequence dim instead (SP)
        return {"batch": None, "seq_kv": ("pod", "data")}
    # decode_32k: batch is plentiful (128); keep caches whole per replica
    return {"batch": ("pod", "data"), "seq_kv": None}


def step_and_args(cfg: ModelConfig, shape: ShapeSpec,
                  hp: adamw.Hparams | None = None):
    """(fn, abstract_args) for the cell's step function."""
    if shape.kind == "train":
        hp = hp or adamw.Hparams()
        fn = make_train_step(cfg, hp)
        params, opt = specs.train_state_spec(cfg, hp)
        batch = specs.batch_spec(cfg, shape)
        return fn, (params, opt, batch)
    if shape.kind == "prefill":
        def fn(params, inputs):
            return M.prefill(params, inputs, cfg)
        return fn, (specs.params_spec(cfg), specs.prefill_inputs_spec(cfg, shape))
    # decode
    def fn(params, tok, caches, index):
        return M.decode_step(params, tok, caches, index, cfg)
    tok, index = specs.decode_inputs_spec(cfg, shape)
    return fn, (specs.params_spec(cfg), tok, specs.caches_spec(cfg, shape),
                index)


def serve_dtype(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Serving cells hold bf16 weights (deployment numerics)."""
    if shape.kind == "train":
        return cfg
    return dataclasses.replace(cfg, param_dtype="bfloat16")


def _donate(shape: ShapeSpec) -> tuple[int, ...]:
    if shape.kind == "train":
        return (0, 1)        # params, opt_state
    if shape.kind == "decode":
        return (2,)          # caches
    return ()


def _cell_costs(cfg: ModelConfig, shape: ShapeSpec,
                hp: adamw.Hparams | None = None) -> dict:
    """flops / bytes / collective-bytes of one compiled variant (per device)."""
    fn, args = step_and_args(cfg, shape, hp)
    compiled = jax.jit(fn).lower(*args).compile()
    cost = roofline.cost_dict(compiled)
    coll = roofline.collective_bytes(compiled.as_text() or "")
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": {k: float(v) for k, v in coll.items()},
    }


def _depth_variants(cfg: ModelConfig) -> tuple[ModelConfig, ModelConfig, int]:
    """(depth-1 cfg, depth-2 cfg, n_units) for exact-cost extrapolation.

    XLA's cost analysis counts a while-loop body once, so the scanned
    production program under-reports layer costs.  We compile the SAME cell
    unrolled at depths d1 < d2 (cheap) and extrapolate linearly: with
    unit = c(d2) - c(d1) and base = c(d1) - unit, total = base + n_units*unit.
    For hybrids the repeating unit is a whole group (mamba x per + shared
    attn); the tail layers are present in both depths, i.e. in `base`.

    The cost variants also switch attention to the dense oracle ("xla"):
    the production xla_chunked path hides the q-chunk loop inside another
    while-loop that cost analysis would count once, while the dense oracle
    computes the IDENTICAL flops/bytes with no inner loop.  (Compile-only:
    the s x s logits buffer is never allocated.)
    """
    if cfg.family == "hybrid":
        _, _, tail = M.hybrid_counts(cfg)
        d1 = cfg.attn_every + tail
        d2 = 2 * cfg.attn_every + tail
        n_units = cfg.num_layers // cfg.attn_every
    else:
        d1, d2, n_units = 1, 2, cfg.num_layers
    mk = lambda d: dataclasses.replace(cfg, num_layers=d, scan_layers=False,
                                       attention_impl="xla")
    return mk(d1), mk(d2), n_units


def _extrapolate(c1: dict, c2: dict, n_units: int) -> dict:
    def lin(a, b):
        unit = b - a
        return (a - unit) + n_units * unit
    coll = {k: lin(c1["coll"][k], c2["coll"][k]) for k in c1["coll"]}
    return {"flops": lin(c1["flops"], c2["flops"]),
            "bytes": lin(c1["bytes"], c2["bytes"]), "coll": coll}


SP_PREFILL_RULES = {
    # §Perf cell B: sequence-parallel prefill — activations/logits shard the
    # seq dim over 'model'; heads stay replicated (no uneven-head padding),
    # K/V get all-gathered per layer (the only collective).  2.1x roofline
    # on llava-next-34b prefill_32k; the win generalises to every
    # full-attention prefill cell.
    "seq": ("model",), "heads": None, "kv_heads": None, "mlp": None,
}


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: str | None,
             verbose: bool = True, exact_costs: bool = True,
             sp_prefill: bool = False) -> dict:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    ok, reason = supports_shape(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}
    cfg = serve_dtype(cfg, shape)
    mesh = mesh_lib.make_production_mesh(
        multi_pod=(mesh_name == "multi"),
        num_devices=512 if mesh_name == "multi" else 256)
    chips = mesh.devices.size
    rules = rules_for(shape, cfg)
    tag = ""
    if sp_prefill and shape.kind == "prefill":
        rules = dict(rules, **SP_PREFILL_RULES)
        tag = "+sp"
    t0 = time.perf_counter()
    with mesh, shd.activate(mesh, rules):
        # 1) production (scanned) program: THE dry-run compile + memory proof
        fn, args = step_and_args(cfg, shape)
        lowered = jax.jit(fn, donate_argnums=_donate(shape)).lower(*args)
        compiled = lowered.compile()
        r = roofline.analyze(arch, shape, cfg, mesh_name, chips, compiled,
                             compiled.as_text() or "")
        scanned = {"flops": r.device_flops, "bytes": r.device_bytes,
                   "coll": dict(r.collective_breakdown)}
        try:
            mem_str = str(compiled.memory_analysis())
        except Exception as e:  # pragma: no cover
            mem_str = f"<memory_analysis unavailable: {e}>"
        # 2) exact per-layer costs from unrolled depth-1/2 compiles
        if exact_costs:
            cfg1, cfg2, n_units = _depth_variants(cfg)
            total = _extrapolate(_cell_costs(cfg1, shape),
                                 _cell_costs(cfg2, shape), n_units)
            r.device_flops = total["flops"]
            r.device_bytes = total["bytes"]
            r.collective_breakdown = total["coll"]
            r.device_collective_bytes = float(sum(total["coll"].values()))
    dt = time.perf_counter() - t0
    r.arch = arch + tag
    rec = r.to_dict()
    rec.update(status="ok", compile_seconds=dt, memory_analysis=mem_str,
               scanned_costs=scanned)
    if verbose:
        print(f"[{mesh_name}] {arch}{tag} x {shape_name}: OK in {dt:.1f}s | "
              f"flops/dev={r.device_flops:.3e} bytes/dev={r.device_bytes:.3e} "
              f"coll/dev={r.device_collective_bytes:.3e} "
              f"bound={r.bottleneck} useful={r.useful_flops_ratio:.2f} "
              f"roofline={100*r.roofline_fraction:.1f}%")
        print(f"  memory_analysis: {mem_str[:300]}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir,
                            f"{mesh_name}__{arch}{tag}__{shape_name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def krr_model_flops(n: int, d: int, m: int, m_kde: int) -> float:
    """Useful flops of the SA+Nyström pipeline (global, per §Roofline)."""
    kde = 2.0 * n * m_kde * d
    k_nm = 2.0 * n * m * d
    normal_eq = 2.0 * n * m * m
    solve = (2.0 / 3.0) * m ** 3
    fitted = 2.0 * n * m
    return kde + k_nm + normal_eq + solve + fitted


def run_krr_cell(mesh_name: str, out_dir: str | None, n: int = 1 << 24,
                 d: int = 3, kde_method: str = "direct") -> dict:
    """Dry-run the paper's own pipeline (core/distributed.py) on the mesh."""
    from repro.core import distributed as D
    mesh = mesh_lib.make_production_mesh(
        multi_pod=(mesh_name == "multi"),
        num_devices=512 if mesh_name == "multi" else 256)
    chips = mesh.devices.size
    m = int(5 * n ** (1.0 / 3.0))
    m_kde = max(1024, int(n ** 0.5))
    t0 = time.perf_counter()
    lowered, compiled = D.lower_pipeline(mesh, n=n, d=d, m=m, m_kde=m_kde,
                                         kde_method=kde_method)
    cost = roofline.cost_dict(compiled)
    coll = roofline.collective_bytes(compiled.as_text() or "")
    try:
        mem_str = str(compiled.memory_analysis())
    except Exception as e:  # pragma: no cover
        mem_str = f"<unavailable: {e}>"
    tag = "+binned" if kde_method == "binned" else ""
    r = roofline.Roofline(
        arch="krr-sa-pipeline" + tag, shape=f"n{n}", mesh=mesh_name,
        chips=chips,
        device_flops=float(cost.get("flops", 0.0)),
        device_bytes=float(cost.get("bytes accessed", 0.0)),
        device_collective_bytes=float(sum(coll.values())),
        collective_breakdown={k: float(v) for k, v in coll.items()},
        model_flops_global=krr_model_flops(n, d, m, m_kde),
    )
    rec = r.to_dict()
    rec.update(status="ok", compile_seconds=time.perf_counter() - t0,
               memory_analysis=mem_str, n=n, d=d, m=m, m_kde=m_kde)
    print(f"[{mesh_name}] krr-sa-pipeline n={n}: OK | "
          f"flops/dev={r.device_flops:.3e} bytes/dev={r.device_bytes:.3e} "
          f"coll/dev={r.device_collective_bytes:.3e} bound={r.bottleneck} "
          f"useful={r.useful_flops_ratio:.2f} "
          f"roofline={100*r.roofline_fraction:.1f}%")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir,
                               f"{mesh_name}__krr-sa-pipeline{tag}__n{n}.json"),
                  "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(configs.ARCHS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--krr", action="store_true",
                    help="dry-run the paper's SA+Nyström pipeline cell")
    ap.add_argument("--kde-method", default="direct",
                    choices=["direct", "binned"],
                    help="KRR cell KDE substrate (binned = §Perf optimized)")
    ap.add_argument("--sp-prefill", action="store_true",
                    help="sequence-parallel prefill rules (§Perf optimized)")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    if args.krr:
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        for mesh_name in meshes:
            run_krr_cell(mesh_name, args.out, kde_method=args.kde_method)
        return

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cell_list = [(a, s) for a in configs.ARCHS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cell_list = [(args.arch, args.shape)]

    failures = []
    for mesh_name in meshes:
        for arch, shape_name in cell_list:
            try:
                rec = run_cell(arch, shape_name, mesh_name, args.out,
                               sp_prefill=args.sp_prefill)
                if rec["status"] == "skipped":
                    print(f"[{mesh_name}] {arch} x {shape_name}: SKIP "
                          f"({rec['reason']})")
            except Exception:
                failures.append((mesh_name, arch, shape_name))
                print(f"[{mesh_name}] {arch} x {shape_name}: FAILED")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: {failures}")
    print("dry-run complete: all requested cells compiled.")


if __name__ == "__main__":
    main()
