"""Production meshes.  A FUNCTION (not a module-level constant) so importing
this module never touches jax device state — jax locks the device count on
first backend init, and only dryrun.py is allowed to force 512 host devices.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(axis: str = "data"):
    """All addressable devices on one axis (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), (axis,))
