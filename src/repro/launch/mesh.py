"""Production meshes.  A FUNCTION (not a module-level constant) so importing
this module never touches jax device state — jax locks the device count on
first backend init, and only dryrun.py is allowed to force 512 host devices.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False,
                         model_parallelism: int = 16,
                         num_devices: int | None = None):
    """2D (data, model) mesh — or 3D (pod, data, model) for ``multi_pod`` —
    derived from the visible device count.

    ``model_parallelism`` sizes the "model" axis (the independent-work axis:
    calibrate h/lam candidates, per-tenant batched fits — see
    `repro.core.streaming`'s "models" rule); the "data" axis takes whatever
    remains, so the same call scales from a forced-host-device test rig to a
    full pod without editing a hardcoded shape.  ``num_devices`` pins the
    chip budget explicitly (dryrun.py uses it to model fixed pod sizes on a
    forced 512-device host); the default uses every visible device.
    """
    n_dev = int(num_devices) if num_devices is not None else len(jax.devices())
    pods = 2 if multi_pod else 1
    if model_parallelism < 1:
        raise ValueError(f"model_parallelism must be >= 1, "
                         f"got {model_parallelism}")
    denom = pods * model_parallelism
    if n_dev % denom != 0:
        raise ValueError(
            f"cannot build a {'multi-pod ' if multi_pod else ''}mesh from "
            f"{n_dev} devices with model_parallelism={model_parallelism}"
            f"{' and 2 pods' if multi_pod else ''}: {n_dev} is not divisible "
            f"by {denom}. Pick a model_parallelism that divides the device "
            f"count (or pass num_devices= to use a subset).")
    data = n_dev // denom
    if multi_pod:
        return jax.make_mesh((pods, data, model_parallelism),
                             ("pod", "data", "model"))
    return jax.make_mesh((data, model_parallelism), ("data", "model"))


def make_local_mesh(axis: str = "data"):
    """All addressable devices on one axis (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), (axis,))


def make_local_mesh_2d(model_parallelism: int = 2):
    """All addressable devices as a (data, model) grid — the forced-host-
    device test shape (e.g. 4 devices -> (2, 2) under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``)."""
    n = len(jax.devices())
    if model_parallelism < 1 or n % model_parallelism != 0:
        raise ValueError(
            f"cannot split {n} devices into a (data, model) grid with "
            f"model_parallelism={model_parallelism}: pick a divisor of the "
            f"device count")
    return jax.make_mesh((n // model_parallelism, model_parallelism),
                         ("data", "model"))
