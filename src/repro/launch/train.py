"""Training launcher.

On real hardware this is the per-host entry point (jax.distributed.initialize
is called when JAX_COORDINATOR is set, then the production mesh spans all
pods); on CPU it drives smoke/example-scale runs with the same Trainer,
checkpointing and data-resume machinery.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 20 --batch 4 --seq 128 --ckpt /tmp/repro_ckpt
"""

from __future__ import annotations

import argparse
import os

import jax

from repro import configs
from repro.data import tokens
from repro.optim import adamw
from repro.training.train import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize()

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    hp = adamw.Hparams(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                       total_steps=args.steps)
    data = tokens.for_config(cfg, args.batch, args.seq, seed=args.seed)
    trainer = Trainer(cfg, hp, data,
                      TrainerConfig(checkpoint_dir=args.ckpt,
                                    checkpoint_every=args.ckpt_every),
                      jax.random.PRNGKey(args.seed),
                      num_microbatches=args.microbatches)
    start = trainer.step
    print(f"arch={cfg.name} params={cfg.param_count():,} resume_step={start}")

    def log(step, metrics):
        if step % 10 == 0 or step == start + 1:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f}")

    final = trainer.run(args.steps - start, on_step=log)
    print("final:", final)


if __name__ == "__main__":
    main()
