"""ShapeDtypeStruct stand-ins for every model input of every dry-run cell.

Nothing here allocates device memory: params, optimizer state, batches and
caches are all abstract (weak-type-correct, shardable).  The same specs feed
``jax.jit(...).lower(...)`` for the dry-run and the roofline analysis.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models import model as M
from repro.models.config import ModelConfig, ShapeSpec
from repro.models.layers import abstract_params
from repro.optim import adamw


def _sds(shape, dtype, axes) -> jax.ShapeDtypeStruct:
    act = shd.active()
    if act is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=act.sharding(axes, shape))


def params_spec(cfg: ModelConfig):
    return abstract_params(M.model_specs(cfg), cfg.pdtype)


def batch_spec(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.inputs_embeds:
        inputs = _sds((b, s, cfg.d_model), cfg.cdtype, ("batch", "seq", None))
    else:
        inputs = _sds((b, s), jnp.int32, ("batch", "seq"))
    labels = _sds((b, s), jnp.int32, ("batch", "seq"))
    return {"inputs": inputs, "labels": labels}


def caches_spec(cfg: ModelConfig, shape: ShapeSpec):
    """Abstract mirror of model.init_caches (same shapes/dtypes)."""
    concrete = jax.eval_shape(
        lambda: M.init_caches(cfg, shape.global_batch, shape.seq_len))

    def annotate(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        key = names[-1] if names else ""
        if key in ("k", "v"):
            axes = ("layers", "batch", "kv_heads", "seq_kv", None)
        elif key == "ssm":
            axes = ("layers", "batch", "ssm_heads", None, None)
        else:  # conv rings
            axes = ("layers", "batch", None, "mlp" if key == "conv_x" else None)
        return _sds(leaf.shape, leaf.dtype, axes[:len(leaf.shape)]
                    if len(axes) >= len(leaf.shape) else
                    axes + (None,) * (len(leaf.shape) - len(axes)))

    return jax.tree_util.tree_map_with_path(annotate, concrete)


def decode_inputs_spec(cfg: ModelConfig, shape: ShapeSpec):
    b = shape.global_batch
    if cfg.inputs_embeds:
        tok = _sds((b, 1, cfg.d_model), cfg.cdtype, ("batch", None, None))
    else:
        tok = _sds((b, 1), jnp.int32, ("batch", None))
    index = jax.ShapeDtypeStruct((), jnp.int32)
    return tok, index


def prefill_inputs_spec(cfg: ModelConfig, shape: ShapeSpec):
    b, s = shape.global_batch, shape.seq_len
    if cfg.inputs_embeds:
        return _sds((b, s, cfg.d_model), cfg.cdtype, ("batch", "seq", None))
    return _sds((b, s), jnp.int32, ("batch", "seq"))


def train_state_spec(cfg: ModelConfig, hp=None):
    p = params_spec(cfg)
    return p, adamw.abstract_init(p, hp)
