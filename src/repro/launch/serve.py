"""Serving launcher: freeze a fitted SA-KRR pipeline and drive the
microbatching predict engine under concurrent synthetic load.

  PYTHONPATH=src python -m repro.launch.serve --smoke
  PYTHONPATH=src python -m repro.launch.serve --n 16384 --m 1024 \
      --requests 4096 --producers 4 --window 64

Flow: fit on the paper's bimodal design -> `ServableKRR.freeze` ->
save/load round-trip (asserting bit-parity with the live pipeline) ->
`ServingEngine` -> warm sequential single-row latency, then >= 4 producer
threads each keeping a sliding window of requests in flight.  Prints p50 /
p99 latency and sustained rows/sec.  `benchmarks/bench_serving.py` reuses
the load helpers here and adds the JSON trajectory record.
"""

from __future__ import annotations

import argparse
import os
import tempfile
import threading
import time
from collections import deque

import jax
import numpy as np

from repro.data import krr_data
from repro.pipeline import PipelineConfig, SAKRRPipeline
from repro.serving import ServableKRR, ServingEngine


# ----------------------------------------------------------------- fitting --
def fit_and_freeze(n: int, m: int, *, d: int = 3, seed: int = 0,
                   tile: int | None = None) -> tuple[SAKRRPipeline,
                                                     ServableKRR]:
    """Fit the bimodal workload, freeze, and save/load round-trip the
    artifact (so every launcher run exercises the persistence path)."""
    data = krr_data.bimodal(jax.random.PRNGKey(seed), n, d=d)
    cfg = PipelineConfig(num_landmarks=m, tile=tile, seed=seed)
    pipe = SAKRRPipeline(cfg).fit(data.x, data.y)
    frozen = ServableKRR.freeze(pipe)
    with tempfile.TemporaryDirectory() as td:
        art = ServableKRR.load(frozen.save(os.path.join(td, "model.npz")))
    return pipe, art


def make_queries(n_rows: int, d: int, seed: int) -> np.ndarray:
    """Fresh draws from the same bimodal input law (includes the far mode)."""
    return np.asarray(
        krr_data.bimodal(jax.random.PRNGKey(seed), max(n_rows, 2), d=d).x
    )[:n_rows]


# -------------------------------------------------------------------- load --
def sequential_latency(engine: ServingEngine, queries: np.ndarray,
                       rows_per_request: int = 1) -> list[float]:
    """One-request-at-a-time round trips (the latency floor / throughput
    baseline): submit, block, record, repeat."""
    lats = []
    for i in range(0, len(queries), rows_per_request):
        chunk = queries[i:i + rows_per_request]
        t0 = time.perf_counter()
        engine.predict(chunk)
        lats.append(time.perf_counter() - t0)
    return lats


def _producer(engine: ServingEngine, chunks: list[np.ndarray], window: int,
              lats: list[float]) -> None:
    inflight: deque = deque()
    for chunk in chunks:
        if len(inflight) >= window:
            t0, fut = inflight.popleft()
            fut.result()
            lats.append(time.perf_counter() - t0)
        inflight.append((time.perf_counter(), engine.submit(chunk)))
    while inflight:
        t0, fut = inflight.popleft()
        fut.result()
        lats.append(time.perf_counter() - t0)


def concurrent_load(engine: ServingEngine, queries: np.ndarray, *,
                    producers: int, window: int,
                    rows_per_request: int = 1) -> tuple[list[float], float]:
    """`producers` threads, each with a sliding `window` of requests in
    flight (open-loop-ish arrival: the engine sees real queue depth, not
    one request per thread).  Returns (per-request latencies, wall secs)."""
    chunks = [queries[i:i + rows_per_request]
              for i in range(0, len(queries), rows_per_request)]
    shares = [chunks[p::producers] for p in range(producers)]
    lat_lists: list[list[float]] = [[] for _ in range(producers)]
    threads = [threading.Thread(target=_producer,
                                args=(engine, shares[p], window,
                                      lat_lists[p]))
               for p in range(producers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    return [l for ls in lat_lists for l in ls], wall


def pct(lats: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(lats), q)) if lats else float("nan")


# -------------------------------------------------------------------- main --
def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI")
    ap.add_argument("--n", type=int, default=16384, help="training rows")
    ap.add_argument("--m", type=int, default=1024, help="landmarks")
    ap.add_argument("--d", type=int, default=3)
    ap.add_argument("--requests", type=int, default=2048)
    ap.add_argument("--producers", type=int, default=4)
    ap.add_argument("--window", type=int, default=64,
                    help="in-flight requests per producer")
    ap.add_argument("--rows-per-request", type=int, default=1)
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--tile", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.smoke:
        args.n, args.m = min(args.n, 2048), min(args.m, 128)
        args.requests, args.window = min(args.requests, 192), 16

    t0 = time.perf_counter()
    pipe, art = fit_and_freeze(args.n, args.m, d=args.d, seed=args.seed,
                               tile=args.tile)
    fit_s = time.perf_counter() - t0
    queries = make_queries(args.requests * args.rows_per_request, args.d,
                           args.seed + 1)
    live = np.asarray(pipe.predict(jax.numpy.asarray(queries[:64])))
    loaded = np.asarray(art.predict(jax.numpy.asarray(queries[:64])))
    bitpar = bool(np.array_equal(live, loaded))
    print(f"fit n={args.n} m={args.m} d={args.d}: {fit_s:.2f}s  "
          f"save/load bit-parity={bitpar}")
    if not bitpar:
        raise SystemExit("artifact round-trip is NOT bit-equal to the "
                         "live pipeline predict")

    with ServingEngine(art, max_batch=args.max_batch) as eng:
        eng.warm()
        seq = sequential_latency(eng, queries[:min(128, len(queries))],
                                 args.rows_per_request)
        print(f"sequential single-request: p50={pct(seq, 50) * 1e3:.2f}ms "
              f"p99={pct(seq, 99) * 1e3:.2f}ms")
        lats, wall = concurrent_load(eng, queries,
                                     producers=args.producers,
                                     window=args.window,
                                     rows_per_request=args.rows_per_request)
        rows = len(queries)
        print(f"concurrent x{args.producers} (window {args.window}): "
              f"{rows / wall:.0f} rows/s  p50={pct(lats, 50) * 1e3:.2f}ms "
              f"p99={pct(lats, 99) * 1e3:.2f}ms  wall={wall:.2f}s")
        st = eng.stats
        print(f"engine: batches={st.batches} compiles={st.compiles} "
              f"occupancy={st.occupancy:.2f}")


if __name__ == "__main__":
    main()
