"""Serving launcher: batched generation with the jit'd decode engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 16 --max-new 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model as M
from repro.serving.engine import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    key = jax.random.PRNGKey(args.seed)
    params = M.init(key, cfg)
    engine = Engine(cfg, params)
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.perf_counter()
    out = engine.generate(jax.random.fold_in(key, 2), prompts, args.max_new,
                          temperature=args.temperature)
    jax.block_until_ready(out.tokens)
    dt = time.perf_counter() - t0
    tps = args.batch * args.max_new / dt
    print(f"arch={cfg.name} batch={args.batch} new={args.max_new} "
          f"wall={dt:.2f}s tokens/s={tps:.1f}")
    print("sample tokens:", out.tokens[0][:16].tolist())
    print("mean logprob:", float(out.logprobs.mean()))


if __name__ == "__main__":
    main()
