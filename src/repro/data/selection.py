"""Leverage-score data selection — the paper's technique applied to the LM
data pipeline (beyond-paper bridge, OFF by default; see DESIGN.md §4).

The paper's insight: statistical leverage (how much a point matters to a
kernel regressor) is an analytic function of the *local input density*:
l(x) ∝ min{1, (λ/p(x))^{1-d/(2α)}} — rare points matter more.  Applied to
LM training: embed each candidate sequence, estimate p̂ at the embeddings
(KDE, O(n) binned or tiled-direct), convert to SA sampling weights, and
importance-sample the batch.  Up-weights rare/unique data, exactly the
"sample where density is low" behaviour the paper proves optimal for KRR.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import kde as core_kde
from repro.core import kernels as K
from repro.core import leverage, sampling

Array = jax.Array


def sa_weights(embeddings: Array, lam: float, *, nu: float = 1.5,
               kde_bandwidth: float | None = None,
               density_floor: float = 1e-6) -> Array:
    """Normalised SA sampling weights for a set of example embeddings."""
    n, d = embeddings.shape
    h = (kde_bandwidth if kde_bandwidth is not None
         else float(core_kde.scott_bandwidth(embeddings)))
    p = core_kde.kde_direct(embeddings, embeddings, h)
    p = jnp.maximum(p, density_floor)
    kern = K.Matern(nu=nu)
    lev = leverage.matern_closed_form(p, lam, kern, d)
    return lev / jnp.sum(lev)


def select(key: Array, embeddings: Array, k: int, lam: float = 1e-3,
           **kw) -> Array:
    """Pick k example indices by SA leverage importance sampling."""
    q = sa_weights(embeddings, lam, **kw)
    return sampling.sample_without_replacement(key, q, k)
