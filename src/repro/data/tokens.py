"""Deterministic, stateless synthetic token pipeline.

``batch_at(step)`` is a pure function of (seed, step), which gives exact
checkpoint/restart data resume for free: a restarted trainer replays the
stream from its restored step with no iterator state to persist.  Batches
are placed with the active mesh's batch sharding when one is installed.

The generator is a Zipf-ish unigram stream with a short induced n-gram
structure (next token depends on the previous one through a permuted
offset), so a real model trains to a visibly decreasing loss — enough signal
for the ~100M-param example run without external data.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models.config import ModelConfig


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    inputs_embeds_dim: int = 0   # >0: emit embeddings (audio/vlm stubs)

    def batch_at(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        b, s, v = self.batch, self.seq_len, self.vocab_size
        # low-entropy first-order structure: 80% repeat the previous token,
        # else jump by a small zipf-ish offset -> x_{t+1} = (x_t + base) % v
        u = jax.random.uniform(k1, (b, s + 1))
        jump = jax.random.uniform(k3, (b, s + 1))
        base = jnp.where(u < 0.8, 0,
                         1 + (jump * jump * 30).astype(jnp.int32))
        toks = jnp.cumsum(base, axis=1) % v
        inputs, labels = toks[:, :-1], toks[:, 1:]
        out = {"labels": labels}
        if self.inputs_embeds_dim:
            emb = jax.random.normal(
                k2, (b, s, self.inputs_embeds_dim), dtype=jnp.float32)
            out["inputs"] = emb
        else:
            out["inputs"] = inputs
        act = shd.active()
        if act is not None:
            out = {
                "inputs": jax.device_put(
                    out["inputs"],
                    act.sharding(("batch", "seq", None)
                                 if self.inputs_embeds_dim
                                 else ("batch", "seq"))),
                "labels": jax.device_put(out["labels"],
                                         act.sharding(("batch", "seq"))),
            }
        return out


def for_config(cfg: ModelConfig, batch: int, seq_len: int,
               seed: int = 0) -> TokenStream:
    return TokenStream(
        vocab_size=cfg.vocab_size, batch=batch, seq_len=seq_len, seed=seed,
        inputs_embeds_dim=cfg.d_model if cfg.inputs_embeds else 0)
