"""Synthetic input distributions and targets from the paper's experiments.

Paper App. B defines:
  * Fig. 1: 3-D bimodal (gamma = 0.4): w.p. n/(n+n^g) ~ Unif[0,1]^3, else a
    product triangular pdf prop. to prod_j (5 - 2 x_j) on [2, 2.5]^3.
  * Fig. 2: 1-D Unif[0,1]; Beta(15,2); 1-D bimodal (gamma = 0.6): Unif[0,.5]
    vs triangular pdf prop. to (3 - 2x) on [1, 1.5].
  * Target f*(x) = g(||x||_2 / d), g(x) = 1.6|(x-.4)(x-.6)| - x(x-1)(x-2) - .5,
    noise N(0, 0.25).

The paper writes the second-mode pdfs unnormalized (they integrate to 1/4 per
dim); we use the normalized triangulars 4*(3-2x) / 64*prod(5-2x_j), which is
what "pdf proportional to" means, and expose exact densities for tests.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


def g_scalar(x: Array) -> Array:
    return 1.6 * jnp.abs((x - 0.4) * (x - 0.6)) - x * (x - 1.0) * (x - 2.0) - 0.5


def target_f(x: Array) -> Array:
    """f*(x) = g(||x||_2 / d)."""
    d = x.shape[-1]
    return g_scalar(jnp.linalg.norm(x, axis=-1) / d)


def _triangular_inverse_cdf(u: Array) -> Array:
    """Inverse CDF of the density 4(1-2t) on t in [0, 0.5]."""
    return 0.5 * (1.0 - jnp.sqrt(1.0 - u))


class Dataset(NamedTuple):
    x: Array          # (n, d)
    y: Array          # (n,) noisy responses
    f_star: Array     # (n,) noiseless targets
    density: Array    # (n,) true input density p(x_i)


def _finish(key, x, density) -> Dataset:
    f = target_f(x)
    noise = 0.5 * jax.random.normal(key, f.shape, dtype=x.dtype)  # N(0, 0.25)
    return Dataset(x=x, y=f + noise, f_star=f, density=density)


def bimodal(key: jax.Array, n: int, d: int, gamma: float = 0.4,
            offset: float = 2.0, dtype=jnp.float32) -> Dataset:
    """Paper's bimodal design: Unif[0,1]^d mode + small far triangular mode.

    d=3, gamma=0.4, offset=2.0 reproduces Fig. 1;
    d=1, gamma=0.6, offset=1.0 reproduces Fig. 2's bimodal (mode at [1,1.5],
    main mode Unif[0, 0.5] -> use main_width=0.5 there via `main_width`).
    """
    return bimodal_general(key, n, d, gamma=gamma, offset=offset,
                           main_width=1.0, dtype=dtype)


def bimodal_general(key: jax.Array, n: int, d: int, gamma: float,
                    offset: float, main_width: float, dtype=jnp.float32) -> Dataset:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    w2 = n ** gamma / (n + n ** gamma)
    is_minor = jax.random.uniform(k1, (n,)) < w2
    major = main_width * jax.random.uniform(k2, (n, d), dtype=dtype)
    minor = offset + _triangular_inverse_cdf(
        jax.random.uniform(k3, (n, d), dtype=dtype)
    )
    x = jnp.where(is_minor[:, None], minor, major)
    # Exact mixture density at the sampled points (modes have disjoint support)
    p_major = (1.0 / main_width ** d) * jnp.all(
        (x >= 0) & (x <= main_width), axis=1
    ).astype(dtype)
    t = x - offset
    tri = jnp.where((t >= 0) & (t <= 0.5), 4.0 * (1.0 - 2.0 * t), 0.0)
    p_minor = jnp.prod(tri, axis=1)
    density = (1.0 - w2) * p_major + w2 * p_minor
    return _finish(k4, x, density)


def bimodal_1d_paper(key: jax.Array, n: int, dtype=jnp.float32) -> Dataset:
    """Fig. 2 bimodal: Unif[0,0.5] major mode, triangular on [1,1.5]."""
    return bimodal_general(key, n, d=1, gamma=0.6, offset=1.0,
                           main_width=0.5, dtype=dtype)


def uniform(key: jax.Array, n: int, d: int = 1, dtype=jnp.float32) -> Dataset:
    k1, k2 = jax.random.split(key)
    x = jax.random.uniform(k1, (n, d), dtype=dtype)
    density = jnp.ones((n,), dtype=dtype)
    return _finish(k2, x, density)


def beta_15_2(key: jax.Array, n: int, dtype=jnp.float32) -> Dataset:
    """Fig. 2's Beta(15, 2) design (1-D)."""
    k1, k2 = jax.random.split(key)
    x = jax.random.beta(k1, 15.0, 2.0, (n, 1)).astype(dtype)
    a, b = 15.0, 2.0
    log_beta = math.lgamma(a) + math.lgamma(b) - math.lgamma(a + b)
    xs = jnp.clip(x[:, 0], 1e-6, 1.0 - 1e-6)
    density = jnp.exp((a - 1) * jnp.log(xs) + (b - 1) * jnp.log1p(-xs) - log_beta)
    return _finish(k2, x, density)


def uci_like(key: jax.Array, n: int, d: int, dtype=jnp.float32) -> Dataset:
    """Synthetic surrogate for the UCI Table-1 datasets (RQC/HTRU2/CCPP):
    a normalized anisotropic two-component Gaussian mixture — mimics the
    'normalize then build kernel matrix' setting; the true density is known
    so SA can also be run density-free in ablations."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    w2 = 0.25
    is_minor = jax.random.uniform(k1, (n,)) < w2
    scales1 = 0.6 + 0.8 * jnp.arange(d) / max(d - 1, 1)
    scales2 = 0.35 * jnp.ones((d,))
    mu2 = 1.5 * jnp.ones((d,))
    z = jax.random.normal(k2, (n, d), dtype=dtype)
    x1 = z * scales1
    x2 = mu2 + z * scales2
    x = jnp.where(is_minor[:, None], x2, x1)

    def gauss_pdf(x, mu, s):
        q = jnp.sum(((x - mu) / s) ** 2, axis=1)
        log_norm = jnp.sum(jnp.log(s)) + 0.5 * d * math.log(2 * math.pi)
        return jnp.exp(-0.5 * q - log_norm)

    density = (1 - w2) * gauss_pdf(x, 0.0, scales1) + w2 * gauss_pdf(
        x, mu2, scales2)
    # normalize to zero-mean unit-var per feature (as the paper normalizes);
    # density transforms by the Jacobian of the affine map
    mean = x.mean(axis=0)
    std = x.std(axis=0)
    xn = (x - mean) / std
    density = density * jnp.prod(std)
    return _finish(k4, xn, density)


def paper_lambda(n: int, d: int, kernel_alpha: float, scale: float = 0.15) -> float:
    """Minimax-rate regularization lam = scale * n^{-2a/(2a+d)} (App. B.2)."""
    return scale * n ** (-2.0 * kernel_alpha / (2.0 * kernel_alpha + d))
