"""Config-driven SA→Nyström estimator: KDE → SA leverage → landmark
sampling → streaming Nyström solve → batched predict.

This is the deployment surface of the paper: every stage is Õ(n) time and
O(tile · m) memory, so a single CPU fits n = 10^6 and a mesh shards rows
over the "rows" logical axis (mesh axis "data") with one psum for the
normal equations — activate a mesh with `repro.distributed.sharding` and
the same `fit` call runs sharded, no code change.

Stages (all overridable through `PipelineConfig`):

  1. density   — `repro.core.kde.estimate_densities` (binned FFT KDE for
                 d <= 3, O(n); direct tiled KDE otherwise);
  2. leverage  — `repro.core.leverage.sa_leverage` (Eq. 6 closed form /
                 grid / quadrature), elementwise in the densities;
  3. sampling  — m landmarks iid ~ q (paper Thm 2, with replacement);
  4. solve     — `repro.core.nystrom.fit_streaming`: G = K_nm^T K_nm and
                 rhs = K_nm^T y accumulated over row tiles (lax.scan on the
                 XLA backend, the fused Pallas `gram` kernel on TPU) — the
                 (n, m) cross-kernel matrix is never materialized;
  5. predict   — `nystrom.predict_streaming`, O(tile · m) per batch.

`fit` records per-stage wall-clock seconds in `state.seconds` so benchmarks
(benchmarks/bench_pipeline.py) get the trajectory for free.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import kde, kernels, leverage, nystrom, sampling

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Everything the pipeline needs, serializable via to_dict/from_dict.

    lam / num_landmarks default to the paper's rates when None:
    lam = 0.075 n^{-2/3}, m = 5 n^{1/3} (clipped to >= 8).
    """

    # kernel
    kernel_kind: str = "matern"       # "matern" | "gaussian"
    nu: float = 1.5                   # Matern smoothness (0.5 / 1.5 / 2.5)
    lengthscale: float = 1.0          # Matern lengthscale
    sigma: float = 1.0                # Gaussian bandwidth
    # regression
    lam: float | None = None
    num_landmarks: int | None = None
    jitter: float = 1e-6
    # leverage estimation
    leverage_method: str = "closed_form"   # closed_form | grid | quadrature
    kde_method: str = "auto"               # auto | binned | direct
    kde_grid_size: int | None = None
    density_floor: float | None = None
    # execution
    tile: int = 8192                  # rows per streaming slab
    backend: str = "auto"             # auto | xla | pallas (dispatch.resolve)
    seed: int = 0

    def build_kernel(self) -> kernels.Kernel:
        if self.kernel_kind == "matern":
            return kernels.Matern(nu=self.nu, lengthscale=self.lengthscale)
        if self.kernel_kind == "gaussian":
            return kernels.Gaussian(sigma=self.sigma)
        raise ValueError(f"unknown kernel_kind {self.kernel_kind!r}")

    def resolve_lam(self, n: int) -> float:
        return self.lam if self.lam is not None else 0.075 * n ** (-2.0 / 3.0)

    def resolve_num_landmarks(self, n: int) -> int:
        if self.num_landmarks is not None:
            return self.num_landmarks
        return max(8, int(5 * n ** (1.0 / 3.0)))

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "PipelineConfig":
        return cls(**d)


@dataclasses.dataclass
class PipelineState:
    """Everything `fit` produced (arrays are O(n) or O(m), never O(n·m))."""

    n: int
    d: int
    lam: float
    num_landmarks: int
    densities: Array          # (n,)
    leverage: leverage.SALeverage
    fit: nystrom.NystromFit
    seconds: dict[str, float]  # per-stage wall clock


class SAKRRPipeline:
    """sklearn-shaped estimator over the streaming SA→Nyström stack."""

    def __init__(self, config: PipelineConfig | None = None):
        self.config = config or PipelineConfig()
        self.kernel = self.config.build_kernel()
        self.state: PipelineState | None = None

    # ------------------------------------------------------------------ fit --
    def fit(self, x: Array, y: Array) -> "SAKRRPipeline":
        cfg = self.config
        n, d = x.shape
        lam = cfg.resolve_lam(n)
        m = cfg.resolve_num_landmarks(n)
        seconds: dict[str, float] = {}

        t0 = time.perf_counter()
        dens = kde.estimate_densities(x, method=cfg.kde_method,
                                      grid_size=cfg.kde_grid_size)
        dens = jax.block_until_ready(dens)
        seconds["kde"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        sa = leverage.sa_leverage(dens, lam, self.kernel, d, n=n,
                                  method=cfg.leverage_method,
                                  floor=cfg.density_floor)
        jax.block_until_ready(sa.probs)
        seconds["leverage"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        idx = sampling.sample_with_replacement(
            jax.random.PRNGKey(cfg.seed), sa.probs, m)
        idx = jax.block_until_ready(idx)
        seconds["sample"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        fit_ = nystrom.fit_streaming(self.kernel, x, y, lam, idx,
                                     tile=cfg.tile, backend=_backend(cfg),
                                     jitter=cfg.jitter)
        jax.block_until_ready(fit_.beta)
        seconds["solve"] = time.perf_counter() - t0

        self.state = PipelineState(n=n, d=d, lam=lam, num_landmarks=m,
                                   densities=dens, leverage=sa, fit=fit_,
                                   seconds=seconds)
        return self

    # -------------------------------------------------------------- predict --
    def predict(self, x_new: Array, tile: int | None = None) -> Array:
        st = self._fitted_state()
        return nystrom.predict_streaming(
            self.kernel, st.fit, x_new,
            tile=tile if tile is not None else self.config.tile,
            backend=_backend(self.config))

    def fitted(self, x_train: Array) -> Array:
        """In-sample predictions (the paper's R_n functional)."""
        return self.predict(x_train)

    # ---------------------------------------------------------------- misc --
    def _fitted_state(self) -> PipelineState:
        if self.state is None:
            raise RuntimeError("call fit(x, y) before predict()")
        return self.state

    @property
    def d_stat(self) -> float:
        return float(self._fitted_state().leverage.d_stat)

    @property
    def seconds(self) -> dict[str, float]:
        return dict(self._fitted_state().seconds)


def _backend(cfg: PipelineConfig) -> str | None:
    return None if cfg.backend == "auto" else cfg.backend
