"""Config-driven SA→Nyström estimator: KDE → SA leverage → landmark
sampling → streaming Nyström solve → batched predict.

This is the deployment surface of the paper: every stage is Õ(n) time and
O(tile · m) memory, so a single CPU fits n = 10^6 and a mesh shards rows
over the "rows" logical axis (mesh axis "data") with one psum for the
normal equations and one for the KDE grid — activate a mesh with
`repro.distributed.sharding` and the same `fit` call runs sharded, no code
change.

`fit` is a fold over `repro.pipeline.stages` stage objects (see that module
and pipeline/README.md for the stage contract and how to compose custom
workloads — precomputed densities, fixed landmarks, KDE-only benchmarking):

  1. kde       — `stages.DensityStage`: binned FFT KDE for d <= 3 (windowed
                 streaming CIC scatter on XLA, the Pallas `kde_binned`
                 kernel on TPU, `kde_binned_sharded` under a mesh); direct
                 tiled KDE otherwise;
  2. leverage  — `stages.LeverageStage`: Eq. 6 closed form / grid /
                 quadrature, elementwise in the densities;
  3. sample    — `stages.SampleStage`: Gumbel top-k without replacement +
                 importance weights by default; iid with replacement (paper
                 Thm 2) behind `sample_with_replacement=True`;
  4. solve     — `stages.SolveStage` -> `nystrom.fit_streaming`: G =
                 K_nm^T K_nm and rhs = K_nm^T y accumulated over row tiles
                 (lax.scan on XLA, the fused Pallas `gram` kernel on TPU) —
                 the (n, m) cross-kernel matrix is never materialized;
  5. predict   — `stages.PredictStage` -> `nystrom.predict_streaming`,
                 O(tile · m) per batch, row-sharded under a mesh;
  6. score     — `stages.ScoreStage`: mse/rmse against observed targets,
                 the paper's R_n risk against f_star when known.

`predict` runs through the same stage fold as `fit` (so its backend/tile
overrides and wall-clock seconds follow the same contract), and
`evaluate(x, y, f_star=...)` folds all six stages in one `run_stages` pass —
the entry point that measures the paper's §4.1 claim end-to-end.
`calibrate(x, y, ...)` prepends a `stages.CalibrateStage`: a one-fold
(lam, h) grid sweep whose Gram accumulation is shared across the lam grid
and whose KDE deposit is shared across the bandwidth grid (see the stage
docstring and pipeline/README.md "Tuning λ and h"), rewriting lam/bandwidth
for the full-data refit that follows in the same fold.

Each stage records its wall-clock seconds in `state.seconds`, so benchmarks
(benchmarks/bench_pipeline.py, incl. `--stages kde`/`--stages score`
subsets) get the trajectory for free.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional, Sequence

import jax

from repro.core import kernels, leverage, nystrom
from repro.pipeline import stages as stages_mod

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Everything the pipeline needs, serializable via to_dict/from_dict.

    lam / num_landmarks default to the paper's rates when None:
    lam = 0.075 n^{-2/3}, m = 5 n^{1/3} (clipped to >= 8).

    ``SCHEMA_VERSION`` stamps every `to_dict` payload.  `from_dict` refuses
    a dict stamped with a DIFFERENT version (a persisted artifact from an
    incompatible library revision must fail loudly at load, not mis-predict
    silently at serve time); an unstamped dict is accepted as the
    pre-versioning legacy layout.  `repro.serving.ServableKRR` persists
    exactly this dict inside its npz bundle, so the stamp rides through the
    serving save/load round-trip too.
    """

    SCHEMA_VERSION = 2

    # kernel
    kernel_kind: str = "matern"       # "matern" | "gaussian"
    nu: float = 1.5                   # Matern smoothness (0.5 / 1.5 / 2.5)
    lengthscale: float = 1.0          # Matern lengthscale
    sigma: float = 1.0                # Gaussian bandwidth
    # regression
    lam: float | None = None
    num_landmarks: int | None = None
    jitter: float = 1e-6
    # leverage estimation
    leverage_method: str = "closed_form"   # closed_form | grid | quadrature
    kde_method: str = "auto"               # auto | binned | direct
    kde_bandwidth: float | None = None     # fixed h; None -> Scott's rule
    kde_grid_size: int | None = None
    kde_tile: int | None = None            # rows per streaming scatter slab
    density_floor: float | None = None
    # calibration (CalibrateStage / SAKRRPipeline.calibrate): explicit
    # candidate grids, or None for the default factor grids bracketing the
    # paper-rate lam and Scott's-rule h (stages.DEFAULT_LAM_FACTORS/
    # DEFAULT_H_FACTORS)
    lam_grid: tuple[float, ...] | None = None
    h_grid: tuple[float, ...] | None = None
    calibrate_val_fraction: float = 0.2    # holdout share of the one CV fold
    # k-fold selection: 1 keeps the historical single holdout fold
    # bit-for-bit; k > 1 runs the shared-Gram sweep once per fold and
    # averages the per-candidate val MSE (k x the cost, k x lower selection
    # variance — the fold axis rides the same multi-lam machinery)
    calibrate_folds: int = 1
    # sampling
    sample_with_replacement: bool = False  # paper Thm 2 iid mode when True
    # execution
    # rows per streaming slab; None autotunes per (device, op, shape bucket)
    # through repro.tuning (roofline-ranked, cache-persisted — see
    # pipeline/README.md "Autotuning & tile selection")
    tile: int | None = None
    backend: str = "auto"             # auto | xla | pallas (dispatch.resolve)
    # autotune=True additionally MEASURES the top roofline candidates with a
    # one-off cached micro-benchmark during fit/evaluate/calibrate (same
    # numerics either way — tuning only picks tile sizes)
    autotune: bool = False
    # streaming-accumulation strategy (repro.core.streaming): "plain" is the
    # historical fp32 running sum, "compensated" the two-float (Kahan)
    # error-carrying sum — lower Gram noise floor, ~2 extra adds per tile
    accumulator: str = "plain"        # plain | compensated
    # Gram-contraction precision mode (repro.core.precision): "fp32" is the
    # historical dot, "bf16x2"/"bf16x3" split the kernel tiles into bf16
    # words (MXU-rate partial matmuls, error-compensated combine).  None
    # autotunes the (tile, precision) pair jointly when the tile is also
    # None, and means "fp32" when the tile is pinned (bit parity) — see
    # pipeline/README.md "Precision modes".
    precision: str | None = None      # None | fp32 | bf16x2 | bf16x3
    seed: int = 0

    def build_kernel(self) -> kernels.Kernel:
        if self.kernel_kind == "matern":
            return kernels.Matern(nu=self.nu, lengthscale=self.lengthscale)
        if self.kernel_kind == "gaussian":
            return kernels.Gaussian(sigma=self.sigma)
        raise ValueError(f"unknown kernel_kind {self.kernel_kind!r}")

    def resolve_lam(self, n: int) -> float:
        return self.lam if self.lam is not None else 0.075 * n ** (-2.0 / 3.0)

    def resolve_num_landmarks(self, n: int) -> int:
        if self.num_landmarks is not None:
            return self.num_landmarks
        return max(8, int(5 * n ** (1.0 / 3.0)))

    def to_dict(self) -> dict[str, Any]:
        return dict(dataclasses.asdict(self),
                    schema_version=self.SCHEMA_VERSION)

    # tuple-typed fields that JSON round-trips as lists; from_dict restores
    # the tuples so the frozen dataclass stays hashable and == its pre-dump
    # self (the servable-artifact contract: `repro.serving` persists exactly
    # this dict)
    _TUPLE_FIELDS = ("lam_grid", "h_grid")

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "PipelineConfig":
        d = dict(d)
        version = d.pop("schema_version", None)
        if version is not None and version != cls.SCHEMA_VERSION:
            raise ValueError(
                f"PipelineConfig schema_version mismatch: the dict was "
                f"written at version {version!r} but this library reads "
                f"version {cls.SCHEMA_VERSION}; re-export the config (or "
                "the serving artifact carrying it) with a matching library "
                "revision")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(
                f"unknown PipelineConfig key(s) {unknown}; known fields: "
                f"{sorted(known)} (a config dict from a newer version of "
                "this library cannot be loaded here)")
        for name in cls._TUPLE_FIELDS:
            if d.get(name) is not None:
                d[name] = tuple(float(v) for v in d[name])
        return cls(**d)


@dataclasses.dataclass
class PipelineState:
    """Everything `fit` produced (arrays are O(n) or O(m), never O(n·m)).

    Fields past `num_landmarks` are Optional because a partial stage list
    (e.g. bench --stages kde) legitimately stops before producing them.
    """

    n: int
    d: int
    lam: float
    num_landmarks: int
    densities: Optional[Array]              # (n,)
    leverage: Optional[leverage.SALeverage]
    fit: Optional[nystrom.NystromFit]
    seconds: dict[str, float]               # per-stage wall clock
    sample_weights: Optional[Array] = None  # (m,) inverse-inclusion weights
    predictions: Optional[Array] = None     # (n_eval,) PredictStage output
    scores: Optional[dict[str, float]] = None  # ScoreStage metrics
    bandwidth: Optional[float] = None       # calibrated KDE h (CalibrateStage)
    cv_scores: Optional[list] = None        # per-(lam, h) candidate records
    cv_best: Optional[dict] = None          # winning candidate summary
    batched_fit: Optional[nystrom.BatchedNystromFit] = None  # fit_many


class SAKRRPipeline:
    """sklearn-shaped estimator over the streaming SA→Nyström stack.

    `stages` overrides the default KDE→leverage→sample→solve composition —
    pass any sequence of `repro.pipeline.stages.Stage` objects (e.g. swap in
    `PrecomputedDensityStage` / `FixedLandmarkStage`, or reconfigure a
    single stage's backend/tile) and `fit` folds the context through them.
    """

    def __init__(self, config: PipelineConfig | None = None,
                 stages: Sequence[stages_mod.Stage] | None = None):
        self.config = config or PipelineConfig()
        self.kernel = self.config.build_kernel()
        self.stages = (list(stages) if stages is not None
                       else stages_mod.default_stages(self.config))
        self.state: PipelineState | None = None
        self._ctx: stages_mod.StageContext | None = None
        self._online = None   # pipeline.online.OnlineState, lazy

    # ------------------------------------------------------------------ fit --
    def _make_context(self, x: Array, y: Array,
                      **eval_inputs: Any) -> stages_mod.StageContext:
        cfg = self.config
        n, d = x.shape
        return stages_mod.StageContext(
            config=cfg, kernel=self.kernel, x=x, y=y, n=n, d=d,
            lam=cfg.resolve_lam(n),
            num_landmarks=cfg.resolve_num_landmarks(n), **eval_inputs)

    def _snapshot(self, ctx: stages_mod.StageContext) -> None:
        self._ctx = ctx
        self._online = None   # a fresh fold supersedes any online state
        self.state = PipelineState(
            n=ctx.n, d=ctx.d, lam=ctx.lam, num_landmarks=ctx.num_landmarks,
            densities=ctx.densities, leverage=ctx.leverage, fit=ctx.fit,
            seconds=ctx.seconds, sample_weights=ctx.sample_weights,
            predictions=ctx.predictions, scores=ctx.scores,
            bandwidth=ctx.bandwidth, cv_scores=ctx.cv_scores,
            cv_best=ctx.cv_best, batched_fit=ctx.batched_fit)

    def _run(self, stage_list: Sequence[stages_mod.Stage],
             ctx: stages_mod.StageContext) -> None:
        """`run_stages` under the config's tuning mode: `autotune=True`
        enables measured plan selection (`repro.tuning.measured`) for every
        tile the fold resolves — cached, so only the first cold fold pays."""
        if getattr(self.config, "autotune", False):
            from repro import tuning
            with tuning.measured():
                stages_mod.run_stages(stage_list, ctx)
        else:
            stages_mod.run_stages(stage_list, ctx)

    def fit(self, x: Array, y: Array) -> "SAKRRPipeline":
        ctx = self._make_context(x, y)
        self._run(self.stages, ctx)
        self._snapshot(ctx)
        return self

    # ------------------------------------------------------------- fit_many --
    def fit_many(self, x: Array, ys: Array, *,
                 lams: Array | Sequence[float] | float | None = None,
                 share_landmarks: bool = False) -> "SAKRRPipeline":
        """Fit MANY tenant models over ONE shared x tile stream.

        `ys` is (B, n) — B target vectors over the same design `x` — and
        `lams` an optional (B,) per-model regularization (scalar / None
        broadcasts; None means the config/paper-rate lam).  The shared
        KDE -> leverage front end runs ONCE; `stages.BatchedSampleStage`
        draws B landmark sets from the one leverage distribution (ONE set
        when ``share_landmarks=True``), and `stages.BatchedSolveStage` ->
        `nystrom.fit_streaming_batched` accumulates all B normal equations
        in one pass over the x tiles — the per-tile cross-kernel block is
        the dominant cost and is paid once per model only in FLOPs, never
        in data movement.  Under a 2D (data x model) mesh the model axis
        shards the B models; see pipeline/README.md "Meshes & many-model
        batching".

        The batched artifact lands on `state.batched_fit`; serve it with
        `predict_many`.
        """
        ys = jax.numpy.asarray(ys)
        if ys.ndim == 1:
            raise ValueError(
                f"fit_many wants ys of shape (num_models, n); got "
                f"{ys.shape} — use fit() for a single model")
        ctx = self._make_context(x, ys[0])
        ctx.ys = ys
        if lams is not None:
            ctx.lams = jax.numpy.broadcast_to(
                jax.numpy.asarray(lams, jax.numpy.float32),
                (int(ys.shape[0]),))
        # reuse the fitted front end (custom density/leverage stages keep
        # their overrides); the single-model sample/solve/... tail is
        # replaced by the batched pair
        prefix = []
        for s in self.stages:
            if getattr(s, "name", "") in ("sample", "solve", "predict",
                                          "score", "calibrate"):
                break
            prefix.append(s)
        if not prefix:
            prefix = [stages_mod.DensityStage(), stages_mod.LeverageStage()]
        solve = self._solve_stage()
        stage_list = prefix + [
            stages_mod.BatchedSampleStage(
                share_landmarks=share_landmarks,
                with_replacement=self.config.sample_with_replacement),
            stages_mod.BatchedSolveStage(
                backend=self._predict_backend(), tile=self._predict_tile(),
                weighted=solve.weighted if solve is not None else False,
                accumulator=solve.accumulator if solve is not None else None,
                precision=self._solve_precision())]
        self._run(stage_list, ctx)
        self._snapshot(ctx)
        return self

    def predict_many(self, x_new: Array, tile: int | None = None) -> Array:
        """(B, n_new) predictions from the `fit_many` artifact — one x_new
        tile stream feeds every model's landmark block (model-axis-sharded
        under a 2D mesh)."""
        st = self._fitted_state()
        if st.batched_fit is None:
            raise RuntimeError("call fit_many(x, ys) before predict_many()")
        t0 = time.perf_counter()
        preds = nystrom.predict_streaming_batched(
            self.kernel, st.batched_fit, jax.numpy.asarray(x_new),
            tile=self._predict_tile(tile), backend=self._predict_backend(),
            precision=self._solve_precision())
        jax.block_until_ready(preds)
        st.seconds["predict_many"] = time.perf_counter() - t0
        return preds

    # ---------------------------------------------------------- partial_fit --
    @property
    def online(self):
        """The live `repro.pipeline.online.OnlineState` (lazy: seeded from
        the banked SolveStage state on first `partial_fit`)."""
        if self._online is None:
            from repro.pipeline import online as online_mod
            if self._ctx is None:
                raise RuntimeError("call fit(x, y) before going online")
            solve = self._solve_stage()
            self._online = online_mod.from_context(
                self._ctx,
                weighted=solve.weighted if solve is not None else False)
        return self._online

    def partial_fit(self, x_new: Array, y_new: Array, *,
                    decay: float | None = None,
                    window: int | None = None) -> "SAKRRPipeline":
        """Absorb new rows and re-solve WITHOUT re-streaming the old data.

        The SolveStage banked its raw normal-equation accumulator state at
        fit time (`repro.core.accstate` — the finalize of the stream it
        already ran, deferred for free), so appending k rows costs
        O(k · m) for the Gram absorb plus ONE O(m^3) solve — independent
        of the rows already absorbed.  On a single-device XLA stream the
        absorb continues the scan carry, so a tile-aligned sequence of
        `partial_fit` calls reproduces the one-shot `fit` beta bit-for-bit
        under the plain accumulator (and within the compensated tolerance
        otherwise).

        ``decay=gamma`` exponentially forgets the past before absorbing
        (drifting streams); ``window=k`` keeps a ring of the last k chunks
        and refolds them (bounded-horizon streams).  The landmark set is
        FROZEN — pair with `repro.pipeline.online.OnlineLandmarks` when
        the dictionary itself must track the drift.

        Updates `state.fit` / the live context in place, so `predict`
        serves the refreshed model immediately.  Returns self.
        """
        st = self._fitted_state()
        if st.fit is None:
            raise RuntimeError("the fitted stage list produced no solve; "
                               "include a SolveStage to partial_fit")
        t0 = time.perf_counter()
        online = self.online
        online.absorb(self.kernel, jax.numpy.asarray(x_new),
                      jax.numpy.asarray(y_new), decay=decay, window=window)
        fit_ = online.solve_fit(self._ctx.lam, jitter=self.config.jitter)
        jax.block_until_ready(fit_.beta)
        self._ctx.fit = st.fit = fit_
        self._ctx.solve_state = online.solve
        st.seconds["partial_fit"] = time.perf_counter() - t0
        return self

    # ------------------------------------------------------------- evaluate --
    def evaluate(self, x: Array, y: Array, *, f_star: Array | None = None,
                 x_eval: Array | None = None, y_eval: Array | None = None
                 ) -> dict[str, float]:
        """KDE -> leverage -> sample -> solve -> predict -> score in ONE
        `run_stages` fold.

        Default is the paper's in-sample setting (predict at x, mse against
        y, risk against f_star when the workload knows the noiseless truth);
        pass x_eval/y_eval for held-out scoring.  Returns the ScoreStage
        metrics dict; the full artifacts (predictions, per-stage seconds)
        land on `self.state` like fit's do.  A custom stage list is
        COMPLETED, not truncated: missing Predict/Score stages are appended
        (evaluate always scores — use `fit` for folds that must stop
        earlier).
        """
        ctx = self._make_context(x, y, x_eval=x_eval, y_eval=y_eval,
                                 f_star=f_star)
        eval_stages = self._completed_eval_stages()
        ctx.fuse_scoring = self._can_fuse(eval_stages, x_eval, y_eval)
        self._run(eval_stages, ctx)
        self._snapshot(ctx)
        return dict(ctx.scores or {})

    @staticmethod
    def _can_fuse(stage_list: Sequence[stages_mod.Stage],
                  x_eval: Array | None, y_eval: Array | None) -> bool:
        """Fused in-sample scoring is only valid when every eval input is
        the paper's default (predict at x, score against y/f_star): any
        caller- or stage-level eval override falls back to the explicit
        predict-then-score fold."""
        if x_eval is not None or y_eval is not None:
            return False
        for s in stage_list:
            if getattr(s, "x_eval", None) is not None:
                return False
            if isinstance(s, stages_mod.ScoreStage) and (
                    s.y_eval is not None or s.f_star is not None):
                return False
        return True

    def _completed_eval_stages(self) -> list[stages_mod.Stage]:
        """self.stages COMPLETED to a scoring fold (Predict/Score appended
        when missing — shared by evaluate() and calibrate())."""
        eval_stages = list(self.stages)
        if not any(isinstance(s, stages_mod.PredictStage)
                   for s in eval_stages):
            # insert before any user-supplied ScoreStage (which requires the
            # predictions artifact), else append
            at = next((i for i, s in enumerate(eval_stages)
                       if isinstance(s, stages_mod.ScoreStage)),
                      len(eval_stages))
            eval_stages.insert(at, stages_mod.PredictStage(
                backend=self._predict_backend(), tile=self._predict_tile(),
                precision=self._solve_precision()))
        if not any(isinstance(s, stages_mod.ScoreStage) for s in eval_stages):
            eval_stages.append(stages_mod.ScoreStage())
        return eval_stages

    # ------------------------------------------------------------ calibrate --
    def calibrate(self, x: Array, y: Array, *, f_star: Array | None = None,
                  x_eval: Array | None = None, y_eval: Array | None = None
                  ) -> dict[str, Any]:
        """One-fold (lam, h) sweep, then the full evaluate fold at the winner.

        Prepends a `CalibrateStage` (unless the stage list already has one)
        to the completed evaluate fold: the stage sweeps
        `config.lam_grid` x `config.h_grid` (default factor grids around the
        paper rate / Scott's rule) through ONE shared-expensive-work holdout
        fold — one Gram accumulation per h re-solved per lam, one KDE
        deposit for all h — then rewrites ctx.lam/ctx.bandwidth so the
        stages after it refit the FULL data at the best candidate.

        Returns {"lam", "bandwidth", "val_mse", "cv_scores", "scores"}; the
        fitted artifacts and per-candidate seconds land on `self.state` like
        fit's do (state.cv_scores / state.cv_best / state.lam).
        """
        ctx = self._make_context(x, y, x_eval=x_eval, y_eval=y_eval,
                                 f_star=f_star)
        cal_stages = self._completed_eval_stages()
        ctx.fuse_scoring = self._can_fuse(cal_stages, x_eval, y_eval)
        if not any(isinstance(s, stages_mod.CalibrateStage)
                   for s in cal_stages):
            # mirror ALL of the SolveStage's per-stage overrides (backend,
            # tile, weighted, accumulator, precision) so every candidate is
            # scored under the same solve configuration the winning refit
            # will use
            solve = self._solve_stage()
            cal_stages.insert(0, stages_mod.CalibrateStage(
                backend=self._predict_backend(), tile=self._predict_tile(),
                weighted=solve.weighted if solve is not None else False,
                accumulator=solve.accumulator if solve is not None else None,
                precision=self._solve_precision()))
        self._run(cal_stages, ctx)
        self._snapshot(ctx)
        return dict(ctx.cv_best or {}, cv_scores=ctx.cv_scores,
                    scores=dict(ctx.scores or {}))

    # -------------------------------------------------------------- predict --
    def _solve_stage(self) -> "stages_mod.SolveStage | None":
        return next((s for s in self.stages
                     if isinstance(s, stages_mod.SolveStage)), None)

    def _predict_backend(self) -> str | None:
        # honor the SolveStage's per-stage overrides so fit and predict run
        # the same backend/tile unless the caller says otherwise
        solve = self._solve_stage()
        return (solve.backend if solve is not None and
                solve.backend is not None
                else stages_mod.resolve_backend(self.config))

    def _predict_tile(self, tile: int | None = None) -> int | None:
        """None falls through to autotune inside `nystrom.predict_streaming`."""
        if tile is not None:
            return tile
        solve = self._solve_stage()
        return (solve.tile if solve is not None and solve.tile is not None
                else self.config.tile)

    def _solve_precision(self) -> str | None:
        solve = self._solve_stage()
        return (solve.precision if solve is not None and
                solve.precision is not None
                else getattr(self.config, "precision", None))

    def predict(self, x_new: Array, tile: int | None = None) -> Array:
        st = self._fitted_state()
        if st.fit is None:
            raise RuntimeError("the fitted stage list produced no solve; "
                               "include a SolveStage to predict")
        # predict is the same stage fold as fit — one PredictStage — but it
        # folds over a SHALLOW PER-CALL COPY of the fitted context: the
        # fitted snapshot (state.scores from a prior evaluate(), the
        # evaluate-time predictions, the eval inputs) stays untouched, and
        # interleaved / concurrent predict calls each own their context so
        # they cannot corrupt each other's results.  Only the stage's
        # wall-clock is folded back (state.seconds is additive metadata).
        ctx = dataclasses.replace(
            self._ctx, x_eval=None, y_eval=None, f_star=None,
            predictions=None, scores=None, score_moments=None, seconds={})
        stage = stages_mod.PredictStage(
            x_eval=x_new, backend=self._predict_backend(),
            tile=self._predict_tile(tile), precision=self._solve_precision())
        self._run([stage], ctx)
        self.state.seconds["predict"] = ctx.seconds["predict"]
        return ctx.predictions

    def fitted(self, x_train: Array) -> Array:
        """In-sample predictions (the paper's R_n functional)."""
        return self.predict(x_train)

    # ---------------------------------------------------------------- misc --
    def _fitted_state(self) -> PipelineState:
        if self.state is None:
            raise RuntimeError("call fit(x, y) before predict()")
        return self.state

    @property
    def d_stat(self) -> float:
        lev = self._fitted_state().leverage
        if lev is None:
            raise RuntimeError("the fitted stage list produced no leverage "
                               "scores; include a LeverageStage for d_stat")
        return float(lev.d_stat)

    @property
    def seconds(self) -> dict[str, float]:
        return dict(self._fitted_state().seconds)
