"""Composable pipeline stages: KDE, leverage, sampling, solve, predict and
score as uniform stage objects.

`SAKRRPipeline.fit` / `.predict` / `.evaluate` are folds over a list of
stages.  Each stage reads and writes named artifacts on a shared
`StageContext` (densities -> leverage -> landmark_idx -> fit ->
predictions -> scores), declares what it `requires`/`provides`, and records
its own wall-clock seconds — so benchmarks get per-stage timing for free
and new workloads compose instead of forking the pipeline class:

  * precomputed densities:  [PrecomputedDensityStage(p), LeverageStage(),
                             SampleStage(), SolveStage()]
  * fixed landmarks:        [FixedLandmarkStage(idx), SolveStage()]
  * KDE-only benchmarking:  [DensityStage()]          (bench --stages kde)
  * end-to-end evaluation:  default_stages() + [PredictStage(),
                             ScoreStage()]            (bench --stages score)

Per-stage execution config (backend / tile / sharding) is a constructor
argument on the stage, overriding the pipeline-wide `PipelineConfig`
defaults; `REPRO_KERNEL_BACKEND` still overrides 'auto' resolution inside
`repro.kernels.dispatch` for every stage.

Sharding: stages are mesh-aware through `repro.distributed.sharding`.
Under an active mesh, DensityStage routes the binned KDE through
`core.distributed.kde_binned_sharded` (rows scattered locally, one grid
psum) and SolveStage's `nystrom.fit_streaming` shards the normal-equation
row stream; with no mesh both run single-device, same numbers.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kde, kernels, leverage, nystrom, sampling

Array = jax.Array


class StageError(RuntimeError):
    """A stage was run before its required artifacts existed."""


@dataclasses.dataclass
class StageContext:
    """Shared state the stages fold over (arrays are O(n) or O(m))."""

    config: Any                   # PipelineConfig (untyped: avoid the cycle)
    kernel: kernels.Kernel
    x: Array
    y: Array
    n: int
    d: int
    lam: float
    num_landmarks: int
    bandwidth: Optional[float] = None       # KDE h; None -> Scott's rule
    densities: Optional[Array] = None
    leverage: Optional[leverage.SALeverage] = None
    landmark_idx: Optional[Array] = None
    sample_weights: Optional[Array] = None
    fit: Optional[nystrom.NystromFit] = None
    # calibration outputs (CalibrateStage): per-candidate records + the
    # winning (lam, bandwidth) pair (which also rewrites lam/bandwidth above)
    cv_scores: Optional[list] = None
    cv_best: Optional[dict] = None
    # evaluation inputs (PredictStage/ScoreStage): default to in-sample
    x_eval: Optional[Array] = None          # defaults to x
    y_eval: Optional[Array] = None          # observed targets at x_eval
    f_star: Optional[Array] = None          # noiseless truth at x_eval
    predictions: Optional[Array] = None
    scores: Optional[dict[str, float]] = None
    # fused in-sample scoring (SAKRRPipeline.evaluate/calibrate set
    # fuse_scoring): SolveStage banks the score moments (G, K_nm^T t, t^T t)
    # in the SAME row stream that builds the normal equations, PredictStage
    # skips its pass, and ScoreStage assembles mse/risk from the moments —
    # evaluate() then streams x at most twice (deposit + Gram) instead of
    # three times.  Raw `run_stages` folds keep the historical
    # predict-then-score path (fuse_scoring defaults False).
    fuse_scoring: bool = False
    score_moments: Optional[dict] = None
    # first-class accumulator state (repro.core.accstate): SolveStage banks
    # the raw normal-equation fold here for free (same stream, finalize
    # deferred), so `SAKRRPipeline.partial_fit` can absorb new tiles and
    # re-solve in O(tile * m) without ever re-streaming the old rows
    solve_state: Optional[nystrom.NormalEqState] = None
    # many-model batched fits (SAKRRPipeline.fit_many): B tenant models
    # sharing the row stream — per-model responses / regularizers /
    # landmark sets ride a leading model axis that the "models" sharding
    # rule may split across a 2D (data, model) mesh
    ys: Optional[Array] = None              # (B, n) per-model responses
    lams: Optional[Array] = None            # (B,) per-model regularizers
    landmark_sets: Optional[Array] = None   # (B, m) per-model landmark idx
    batch_weights: Optional[Array] = None   # (B, m) importance weights
    batched_fit: Optional["nystrom.BatchedNystromFit"] = None
    seconds: dict[str, float] = dataclasses.field(default_factory=dict)

    def require(self, *names: str) -> None:
        missing = [a for a in names if getattr(self, a) is None]
        if missing:
            raise StageError(
                f"missing artifacts {missing}; run the providing stage(s) "
                "first (e.g. DensityStage before LeverageStage)")


class Stage:
    """Base class: `run(ctx)` produces artifacts; `__call__` times it.

    Subclasses set `name` (the `seconds` key), `requires`/`provides`
    (artifact names on StageContext), and may take per-stage overrides
    (backend, tile, ...) in their constructor.
    """

    name: str = "stage"
    requires: tuple[str, ...] = ()
    provides: tuple[str, ...] = ()

    def run(self, ctx: StageContext) -> None:
        raise NotImplementedError

    def __call__(self, ctx: StageContext) -> StageContext:
        ctx.require(*self.requires)
        t0 = time.perf_counter()
        self.run(ctx)
        for name in self.provides:   # block so seconds mean what they say
            art = getattr(ctx, name)
            if art is not None:
                jax.block_until_ready(jax.tree.leaves(art))
        ctx.seconds[self.name] = time.perf_counter() - t0
        return ctx


def _resolve_kde_method(method: str, d: int) -> str:
    return ("binned" if d <= 3 else "direct") if method == "auto" else method


class DensityStage(Stage):
    """p_hat(x_i) via binned (d <= 3) or direct KDE; mesh-aware.

    Under an active `repro.distributed.sharding` mesh the binned path runs
    `core.distributed.kde_binned_sharded` on grid bounds computed from the
    global data (so it matches the single-device `kde.kde_binned` grid
    exactly); otherwise `kde.estimate_densities`.  `backend`/`tile` override
    the config-wide deposit-stage knobs; `sharded=False` forces the
    single-device path even under a mesh.
    """

    name = "kde"
    provides = ("densities",)

    def __init__(self, *, method: str | None = None, h: float | None = None,
                 grid_size: int | None = None, backend: str | None = None,
                 tile: int | None = None, sharded: bool | None = None,
                 accumulator: str | None = None):
        self.method = method
        self.h = h
        self.grid_size = grid_size
        self.backend = backend
        self.tile = tile
        self.sharded = sharded
        self.accumulator = accumulator

    def run(self, ctx: StageContext) -> None:
        from repro.distributed import sharding as shd

        cfg = ctx.config
        method = _resolve_kde_method(self.method or cfg.kde_method, ctx.d)
        grid_size = (self.grid_size or cfg.kde_grid_size
                     or kde.default_grid_size(ctx.d))
        backend, tile, accumulator, _ = resolve_exec(self, cfg,
                                                     tile_attr="kde_tile")
        # bandwidth resolution: stage override > calibrated ctx.bandwidth >
        # config > Scott's rule (the pre-calibration default)
        h = self.h if self.h is not None else ctx.bandwidth
        if h is None:
            h = getattr(cfg, "kde_bandwidth", None)
        act = shd.active()
        use_sharded = (self.sharded if self.sharded is not None
                       else act is not None)
        if method == "binned" and use_sharded and act is not None:
            from repro.core import distributed as dist
            h = jnp.asarray(h if h is not None
                            else kde.scott_bandwidth(ctx.x), ctx.x.dtype)
            lo, hi = kde.binned_bounds(ctx.x, ctx.x, h)
            ctx.densities = dist.kde_binned_sharded(
                ctx.x, h, grid_size=grid_size, lo=lo, hi=hi, tile=tile,
                backend=backend, accumulator=accumulator)
        else:
            ctx.densities = kde.estimate_densities(
                ctx.x, h=h, method=method, grid_size=grid_size,
                backend=backend, tile=tile, accumulator=accumulator)


class PrecomputedDensityStage(Stage):
    """Drop-in density source for workloads that already know p(x_i)."""

    name = "kde"
    provides = ("densities",)

    def __init__(self, densities: Array):
        self.densities = densities

    def run(self, ctx: StageContext) -> None:
        if self.densities.shape != (ctx.n,):
            raise ValueError(
                f"precomputed densities have shape {self.densities.shape}, "
                f"expected ({ctx.n},)")
        ctx.densities = jnp.asarray(self.densities)


class LeverageStage(Stage):
    """SA leverage scores (paper Eq. 6) from the densities, elementwise."""

    name = "leverage"
    requires = ("densities",)
    provides = ("leverage",)

    def __init__(self, *, method: str | None = None):
        self.method = method

    def run(self, ctx: StageContext) -> None:
        cfg = ctx.config
        ctx.leverage = leverage.sa_leverage(
            ctx.densities, ctx.lam, ctx.kernel, ctx.d, n=ctx.n,
            method=self.method or cfg.leverage_method,
            floor=cfg.density_floor)


class SampleStage(Stage):
    """m landmarks ~ q: Gumbel top-k without replacement by default
    (distinct landmarks + importance weights), iid with replacement (paper
    Thm 2 setting) behind `config.sample_with_replacement`."""

    name = "sample"
    requires = ("leverage",)
    provides = ("landmark_idx",)

    def __init__(self, *, with_replacement: bool | None = None):
        self.with_replacement = with_replacement

    def run(self, ctx: StageContext) -> None:
        cfg = ctx.config
        key = jax.random.PRNGKey(cfg.seed)
        probs = ctx.leverage.probs
        with_rep = (self.with_replacement if self.with_replacement is not None
                    else cfg.sample_with_replacement)
        m = ctx.num_landmarks
        if with_rep or m > ctx.n:   # top-k needs m distinct points to exist
            ctx.landmark_idx = sampling.sample_with_replacement(key, probs, m)
        else:
            ctx.landmark_idx, ctx.sample_weights = (
                sampling.sample_weighted_without_replacement(key, probs, m))


class FixedLandmarkStage(Stage):
    """Drop-in landmark source (precomputed index set; skips KDE/leverage)."""

    name = "sample"
    provides = ("landmark_idx",)

    def __init__(self, landmark_idx: Array):
        self.landmark_idx = landmark_idx

    def run(self, ctx: StageContext) -> None:
        ctx.landmark_idx = jnp.asarray(self.landmark_idx, dtype=jnp.int32)


class SolveStage(Stage):
    """Streaming Nystrom normal equations on the sampled landmarks
    (lax.scan row slabs on XLA, the fused Pallas `gram` kernel on TPU;
    rows psum-sharded under an active mesh).

    ``weighted=True`` feeds the without-replacement importance weights
    (`ctx.sample_weights`, when present) into the column-rescaled SoR solve
    (`nystrom.weighted_normal_eq`).  The SoR predictor is invariant to the
    rescaling in exact arithmetic, so this is off by default — fp32
    whitening order shifts results slightly and the unweighted solve is the
    parity oracle for the dense path.

    ``accumulator`` ("plain" | "compensated", default from the config)
    picks the `repro.core.streaming` Gram-accumulation strategy; the
    compensated two-float sum also lowers the solve's spectral truncation
    floor (`nystrom.solve_normal_eq(eps_scale=...)`).  ``precision``
    ("fp32" | "bf16x2" | "bf16x3" | None, default from the config) picks
    the Gram-contraction mode (`repro.core.precision`).

    Under ``ctx.fuse_scoring`` with in-sample evaluation inputs, the stage
    runs `nystrom.fit_streaming_scored` instead: the score targets ride the
    rhs of the SAME Gram stream and the quadratic-form moments land on
    ``ctx.score_moments`` — PredictStage/ScoreStage then finish the fold
    without re-streaming x."""

    name = "solve"
    requires = ("landmark_idx",)
    provides = ("fit",)

    def __init__(self, *, backend: str | None = None, tile: int | None = None,
                 weighted: bool = False, accumulator: str | None = None,
                 precision: str | None = None):
        self.backend = backend
        self.tile = tile
        self.weighted = weighted
        self.accumulator = accumulator
        self.precision = precision

    @staticmethod
    def _fuse(ctx: StageContext) -> bool:
        return (ctx.fuse_scoring and ctx.x_eval is None
                and ctx.y_eval is None
                and (ctx.f_star is None or ctx.f_star.shape[0] == ctx.n))

    def run(self, ctx: StageContext) -> None:
        cfg = ctx.config
        weights = ctx.sample_weights if self.weighted else None
        backend, tile, accumulator, precision = resolve_exec(self, cfg)
        if self._fuse(ctx):
            ctx.fit, ctx.score_moments, ctx.solve_state = (
                nystrom.fit_streaming_scored(
                    ctx.kernel, ctx.x, ctx.y, ctx.lam, ctx.landmark_idx,
                    f_star=ctx.f_star, tile=tile, backend=backend,
                    jitter=cfg.jitter, weights=weights,
                    accumulator=accumulator, precision=precision,
                    return_state=True))
            return
        ctx.fit, ctx.solve_state = nystrom.fit_streaming(
            ctx.kernel, ctx.x, ctx.y, ctx.lam, ctx.landmark_idx,
            tile=tile, backend=backend, jitter=cfg.jitter, weights=weights,
            accumulator=accumulator, precision=precision, return_state=True)


class BatchedSampleStage(Stage):
    """Per-model landmark draws for the many-model fold (`fit_many`).

    Every model draws its own landmark set from the SHARED leverage
    distribution (the models share x, so they share densities/leverage);
    the draws are vectorized — one (B, n) Gumbel field, vmapped top-k —
    instead of B python-level sampling calls.  ``share_landmarks=True``
    broadcasts ONE draw to every model (cheaper downstream Grams when
    tenants may share a dictionary); the default keeps per-model draws so
    tenant models stay independently sampled.  Weights follow SampleStage:
    inverse-inclusion importance weights for the without-replacement
    default, none for the with-replacement (paper Thm 2) mode.
    """

    name = "sample"
    requires = ("leverage", "ys")
    provides = ("landmark_sets",)

    def __init__(self, *, share_landmarks: bool = False,
                 with_replacement: bool | None = None):
        self.share_landmarks = share_landmarks
        self.with_replacement = with_replacement

    def run(self, ctx: StageContext) -> None:
        cfg = ctx.config
        probs = ctx.leverage.probs
        big = int(ctx.ys.shape[0])
        m = min(ctx.num_landmarks, ctx.n)
        key = jax.random.PRNGKey(cfg.seed)
        with_rep = (self.with_replacement if self.with_replacement is not None
                    else cfg.sample_with_replacement) or ctx.num_landmarks > ctx.n
        if self.share_landmarks:
            if with_rep:
                idx = sampling.sample_with_replacement(key, probs, m)
                weights = None
            else:
                idx, weights = sampling.sample_weighted_without_replacement(
                    key, probs, m)
            ctx.landmark_sets = jnp.broadcast_to(idx[None, :], (big, m))
            ctx.batch_weights = (None if weights is None else
                                 jnp.broadcast_to(weights[None, :], (big, m)))
            return
        if with_rep:
            keys = jax.random.split(key, big)
            ctx.landmark_sets = jax.vmap(
                lambda k: sampling.sample_with_replacement(k, probs, m))(keys)
            ctx.batch_weights = None
            return
        race_dtype = jnp.promote_types(ctx.x.dtype, jnp.float32)
        races = jax.random.gumbel(key, (big, ctx.n), dtype=race_dtype)
        ctx.landmark_sets, ctx.batch_weights = jax.vmap(
            lambda g: sampling.sample_weighted_without_replacement(
                key, probs, m, gumbel=g))(races)


class BatchedSolveStage(Stage):
    """B independent normal-equation fits off ONE shared row stream
    (`nystrom.fit_streaming_batched`): per-model (y, lam, landmark set),
    rows psummed over the data axis, models sharded over the model axis of
    a 2D mesh.  Execution knobs follow the SolveStage convention (stage
    constructor beats config); ``weighted=True`` applies the per-model
    importance weights banked by BatchedSampleStage."""

    name = "solve"
    requires = ("landmark_sets", "ys")
    provides = ("batched_fit",)

    def __init__(self, *, backend: str | None = None, tile: int | None = None,
                 weighted: bool = False, accumulator: str | None = None,
                 precision: str | None = None):
        self.backend = backend
        self.tile = tile
        self.weighted = weighted
        self.accumulator = accumulator
        self.precision = precision

    def run(self, ctx: StageContext) -> None:
        cfg = ctx.config
        backend, tile, accumulator, precision = resolve_exec(self, cfg)
        lams = ctx.lams if ctx.lams is not None else ctx.lam
        weights = ctx.batch_weights if self.weighted else None
        ctx.batched_fit = nystrom.fit_streaming_batched(
            ctx.kernel, ctx.x, ctx.ys, lams, ctx.landmark_sets,
            tile=tile, backend=backend, jitter=cfg.jitter, weights=weights,
            accumulator=accumulator, precision=precision)


class PredictStage(Stage):
    """Batched predictions at `x_eval` (default: in-sample, ctx.x) through
    `nystrom.predict_streaming` — O(tile * m) per batch, row-sharded under
    an active mesh exactly like the solve.  backend/tile/precision overrides
    follow the SolveStage convention (stage constructor beats config).

    When the fold fused its scoring (SolveStage banked ``ctx.score_moments``
    for the in-sample setting), there is nothing left to predict: the stage
    records its (near-zero) seconds and leaves ``ctx.predictions`` None —
    ScoreStage assembles the metrics from the moments instead.  Explicit
    eval points always run the real pass and invalidate the moments."""

    name = "predict"
    requires = ("fit",)
    provides = ("predictions",)

    def __init__(self, *, x_eval: Array | None = None,
                 backend: str | None = None, tile: int | None = None,
                 precision: str | None = None):
        self.x_eval = x_eval
        self.backend = backend
        self.tile = tile
        self.precision = precision

    def run(self, ctx: StageContext) -> None:
        cfg = ctx.config
        if self.x_eval is not None:
            ctx.x_eval = jnp.asarray(self.x_eval)
        if ctx.x_eval is None:
            ctx.x_eval = ctx.x                       # the paper's R_n setting
            if ctx.score_moments is not None:        # fused in-sample scoring
                return
        ctx.score_moments = None   # real predictions supersede the moments
        backend, tile, _, precision = resolve_exec(self, cfg)
        ctx.predictions = nystrom.predict_streaming(
            ctx.kernel, ctx.fit, ctx.x_eval, tile=tile, backend=backend,
            precision=precision)


class ScoreStage(Stage):
    """Scalar quality metrics from the predictions (or the fused moments).

    Emits a dict on `ctx.scores`:

      * ``mse`` / ``rmse``  — against the observed targets ``y_eval``
        (defaulting to ctx.y when the predictions are in-sample);
      * ``risk``            — the paper's R_n functional, against the
        noiseless ``f_star`` when the workload knows it (synthetic data).

    When the fold fused its scoring (``ctx.score_moments`` from
    SolveStage, no predictions), the metrics come from the quadratic-form
    identity  sum (f - t)^2 = beta^T G beta - 2 beta^T (K_nm^T t) + t^T t
    assembled in host f64 — the two big terms cancel to ~n·mse, so f64
    keeps the score accurate where f32 assembly would lose it.

    Values are host floats (the stage blocks on them, so its recorded
    seconds include the device work it triggered).
    """

    name = "score"
    requires = ()     # predictions OR score_moments; checked in run()
    provides = ("scores",)

    def __init__(self, *, f_star: Array | None = None,
                 y_eval: Array | None = None):
        self.f_star = f_star
        self.y_eval = y_eval

    @staticmethod
    def _scores_from_moments(ctx: StageContext) -> dict[str, float]:
        mom = ctx.score_moments
        beta = np.asarray(ctx.fit.beta, np.float64)
        q = beta @ np.asarray(mom["g"], np.float64) @ beta
        n_eval = mom["n_eval"]
        mse = max(0.0, float(
            (q - 2.0 * (beta @ np.asarray(mom["rhs_y"], np.float64))
             + mom["y_sq"]) / n_eval))
        scores = {"mse": mse, "rmse": mse ** 0.5}
        if mom.get("rhs_f") is not None:
            scores["risk"] = max(0.0, float(
                (q - 2.0 * (beta @ np.asarray(mom["rhs_f"], np.float64))
                 + mom["f_sq"]) / n_eval))
        return scores

    def run(self, ctx: StageContext) -> None:
        if self.y_eval is not None:
            ctx.y_eval = jnp.asarray(self.y_eval)
        if self.f_star is not None:
            ctx.f_star = jnp.asarray(self.f_star)
        if ctx.predictions is None:
            # stage-level targets describe a predict pass the fused fold
            # never ran — they cannot be scored from the moments
            if (ctx.score_moments is not None and self.y_eval is None
                    and self.f_star is None):
                if ctx.y_eval is None and ctx.x_eval is ctx.x:
                    ctx.y_eval = ctx.y               # in-sample default
                ctx.scores = self._scores_from_moments(ctx)
                return
            raise StageError(
                "missing artifacts ['predictions']; run the providing "
                "stage(s) first (e.g. PredictStage before ScoreStage)")
        if ctx.y_eval is None and ctx.x_eval is ctx.x:
            ctx.y_eval = ctx.y                       # in-sample default
        if ctx.y_eval is None and ctx.f_star is None:
            raise StageError(
                "ScoreStage needs targets: set y_eval and/or f_star (on the "
                "stage or the context) for out-of-sample predictions")
        pred = ctx.predictions
        scores: dict[str, float] = {}
        if ctx.y_eval is not None:
            mse = float(jnp.mean((pred - ctx.y_eval) ** 2))
            scores["mse"] = mse
            scores["rmse"] = mse ** 0.5
        if ctx.f_star is not None:
            scores["risk"] = float(jnp.mean((pred - ctx.f_star) ** 2))
        ctx.scores = scores


# ----------------------------------------------------------- calibration --

# Default grids when neither the stage nor the config pins them: lam
# candidates bracket the paper's asymptotic rate symmetrically in log space,
# bandwidth candidates bracket Scott's rule.  Both contain the 1.0 factor,
# so the paper-rate default is always IN the swept set — calibration can
# only match or beat it on the validation fold.
DEFAULT_LAM_FACTORS = (0.1, 0.3, 1.0, 3.0, 10.0)
DEFAULT_H_FACTORS = (0.5, 1.0, 2.0)


class CalibrateStage(Stage):
    """One-fold (lam, h) cross-validation with SHARED expensive work.

    The sweep's costs factor exactly:

      * the tiled Gram ``K_nm^T K_nm`` / moments are lam-independent, so each
        bandwidth candidate accumulates them ONCE and re-solves the whitened
        normal equations per lam (`nystrom.fit_streaming_multi` — bit-equal
        to per-lam `fit_streaming` loops, at 1/L of the row-stream cost);
      * the binned-KDE CIC deposit is h-independent on a fixed grid, so the
        whole bandwidth grid shares ONE deposit and only the FFT smooth +
        gather re-run per h (`kde.kde_binned_multi`;
        `core.distributed.kde_binned_sharded_multi` under an active mesh —
        one deposit AND one grid psum for the whole sweep);
      * validation scoring shares ONE x_val stream across the WHOLE
        (h, lam) grid (`nystrom.val_mse_streaming_multi` — a fused
        `streaming.multi_reduce` scan with one squared-error accumulator
        slot per bandwidth), recorded as ``seconds["calibrate[val]"]``.

    So an H x L sweep costs ~H fits + one KDE instead of H·L of each.  The
    fold: a deterministic holdout split (``val_fraction``, seeded by the
    config; the train side is rounded to divide an active mesh so the Gram
    psum stays sharded), per-h densities -> SA leverage at the reference
    ctx.lam -> one landmark draw per h sharing ONE Gumbel race (the noise
    is drawn once and passed to every draw via ``gumbel=``, so the h axis
    of the sweep differs only through the probs — zero sampling noise
    between candidates, ROADMAP gap (e)) -> multi-lam fit -> multi-lam
    validation MSE.  Emits `ctx.cv_scores` (one record per (h, lam) with
    val_mse/val_rmse and the per-h fit/block seconds), `ctx.cv_best`, and
    REWRITES ``ctx.lam`` / ``ctx.bandwidth`` so every downstream stage
    (DensityStage reads ctx.bandwidth, Leverage/SolveStage read ctx.lam)
    refits the full data at the winning candidate.  Per-h wall-clock lands
    in ``ctx.seconds["calibrate[h=...]"]`` next to the stage total.
    """

    name = "calibrate"
    provides = ("cv_scores",)

    def __init__(self, *, lam_grid: Sequence[float] | None = None,
                 h_grid: Sequence[float] | None = None,
                 val_fraction: float | None = None,
                 folds: int | None = None,
                 backend: str | None = None, tile: int | None = None,
                 weighted: bool = False, accumulator: str | None = None,
                 precision: str | None = None):
        self.lam_grid = lam_grid
        self.h_grid = h_grid
        self.val_fraction = val_fraction
        self.folds = folds
        self.backend = backend
        self.tile = tile
        self.weighted = weighted
        self.accumulator = accumulator
        self.precision = precision

    # ------------------------------------------------------------ helpers --
    def _grids(self, ctx: StageContext) -> tuple[list[float], list[float]]:
        cfg = ctx.config
        lam_grid = self.lam_grid or getattr(cfg, "lam_grid", None)
        if lam_grid is None:
            lam_grid = [f * ctx.lam for f in DEFAULT_LAM_FACTORS]
        h_grid = self.h_grid or getattr(cfg, "h_grid", None)
        if h_grid is None:
            # bracket the user-pinned bandwidth when one is configured (so
            # the configured candidate is always IN the swept set and can
            # only be beaten, never silently discarded), else Scott's rule
            h0 = getattr(cfg, "kde_bandwidth", None)
            h0 = float(h0) if h0 is not None else float(
                kde.scott_bandwidth(ctx.x))
            h_grid = [f * h0 for f in DEFAULT_H_FACTORS]
        return [float(l) for l in lam_grid], [float(h) for h in h_grid]

    def _split(self, ctx: StageContext) -> tuple[Array, Array]:
        """Deterministic holdout (train_idx, val_idx); the train side is
        shrunk (val grows) until it divides an active mesh, so the shared
        Gram/deposit run sharded with their single psum."""
        from repro.distributed import sharding as shd
        cfg = ctx.config
        frac = (self.val_fraction if self.val_fraction is not None
                else getattr(cfg, "calibrate_val_fraction", 0.2))
        n_val = min(ctx.n - 1, max(1, int(frac * ctx.n)))
        act = shd.active()
        if act is not None:
            size = act.mesh.devices.size
            n_tr = ctx.n - n_val
            if n_tr > size:    # else: leave it; the kernels fall back local
                n_val += n_tr % size
        perm = jax.random.permutation(jax.random.PRNGKey(cfg.seed ^ 0x5EED),
                                      ctx.n)
        return perm[n_val:], perm[:n_val]

    def _folds(self, ctx: StageContext) -> list[tuple[Array, Array]]:
        """The fold list: k == 1 (the default) reproduces the historical
        holdout split bit-for-bit; k > 1 slices ONE deterministic
        permutation into k equal validation blocks (remainder rows stay on
        every fold's train side), each train side shrunk to divide an
        active mesh exactly like `_split`.  k-fold selection runs the
        shared-Gram sweep k times — k× the cost for k× lower selection
        variance, the fold axis riding the same multi-lam machinery."""
        from repro.distributed import sharding as shd
        cfg = ctx.config
        k = (self.folds if self.folds is not None
             else getattr(cfg, "calibrate_folds", 1))
        k = int(k)
        if k < 1:
            raise ValueError(f"calibrate folds must be >= 1, got {k}")
        if k == 1:
            return [self._split(ctx)]
        if k > ctx.n:
            raise ValueError(f"cannot make {k} folds from {ctx.n} rows")
        perm = jax.random.permutation(jax.random.PRNGKey(cfg.seed ^ 0x5EED),
                                      ctx.n)
        fs = ctx.n // k
        act = shd.active()
        folds: list[tuple[Array, Array]] = []
        for j in range(k):
            val = perm[j * fs:(j + 1) * fs]
            tr = jnp.concatenate([perm[:j * fs], perm[(j + 1) * fs:]])
            if act is not None:
                size = act.mesh.devices.size
                n_tr = int(tr.shape[0])
                if n_tr > size and n_tr % size:
                    extra = n_tr % size
                    val = jnp.concatenate([val, tr[:extra]])
                    tr = tr[extra:]
            folds.append((tr, val))
        return folds

    def _densities_multi(self, ctx: StageContext, x_tr: Array,
                         h_grid: list[float]) -> Array:
        """(H, n_tr) densities at every bandwidth, one deposit (+ one psum
        under a mesh); direct KDE (d > 3) has no shareable deposit and just
        loops."""
        from repro.distributed import sharding as shd
        cfg = ctx.config
        method = _resolve_kde_method(cfg.kde_method, ctx.d)
        if method != "binned":
            return jnp.stack([kde.kde_direct(x_tr, x_tr, h) for h in h_grid])
        grid_size = cfg.kde_grid_size or kde.default_grid_size(ctx.d)
        backend, tile, accumulator, _ = resolve_exec(self, cfg,
                                                     tile_attr="kde_tile",
                                                     stage_tile=False)
        h_max = jnp.asarray(max(h_grid), x_tr.dtype)
        lo, hi = kde.binned_bounds(x_tr, x_tr, h_max)
        if shd.active() is not None:
            from repro.core import distributed as dist
            return dist.kde_binned_sharded_multi(
                x_tr, h_grid, grid_size=grid_size, lo=lo, hi=hi, tile=tile,
                backend=backend, accumulator=accumulator)
        return kde.kde_binned_multi(x_tr, x_tr, h_grid, grid_size,
                                    lo=lo, hi=hi, backend=backend, tile=tile,
                                    accumulator=accumulator)

    # ---------------------------------------------------------------- run --
    def _run_fold(self, ctx: StageContext, lam_grid: list[float],
                  h_grid: list[float], tr_idx: Array, val_idx: Array,
                  tag: str, fold: int) -> tuple[np.ndarray, list[float],
                                                list[float]]:
        """One fold of the (h, lam) sweep: shared deposit, per-h Gram
        re-solved per lam, one fused validation stream.  Returns the fold's
        (H, L) val-MSE matrix and per-h fit/block seconds; per-h wall-clock
        lands in ctx.seconds keyed with `tag` ("" for the single-fold
        sweep — the historical keys — "fJ|" under k-fold)."""
        cfg = ctx.config
        x_tr, y_tr = ctx.x[tr_idx], ctx.y[tr_idx]
        x_val, y_val = ctx.x[val_idx], ctx.y[val_idx]
        n_tr = int(x_tr.shape[0])
        backend, tile, accumulator, precision = resolve_exec(self, cfg)

        t0 = time.perf_counter()
        dens = self._densities_multi(ctx, x_tr, h_grid)
        jax.block_until_ready(dens)
        kde_s = time.perf_counter() - t0

        key = jax.random.PRNGKey(cfg.seed)
        if tag:   # k-fold: each fold draws its own race/landmarks
            key = jax.random.fold_in(key, fold)
        # ONE Gumbel race for the whole bandwidth grid: every h's landmark
        # draw perturbs its own probs with the SAME noise, so the h axis of
        # the sweep carries zero sampling noise (drawn once here instead of
        # re-derived from the key inside every per-h call)
        race_dtype = jnp.promote_types(ctx.x.dtype, jnp.float32)
        race = jax.random.gumbel(key, (n_tr,), dtype=race_dtype)
        fits_by_h: list[list] = []
        fit_seconds: list[float] = []
        h_seconds: list[float] = []
        for i, h in enumerate(h_grid):
            t_h = time.perf_counter()
            lev = leverage.sa_leverage(
                dens[i], ctx.lam, ctx.kernel, ctx.d, n=n_tr,
                method=cfg.leverage_method, floor=cfg.density_floor)
            # top-k needs m DISTINCT train points to exist, so the fallback
            # tests the unclamped request (SampleStage semantics) while the
            # draw itself is clamped to the fold's size
            m = min(ctx.num_landmarks, n_tr)
            if cfg.sample_with_replacement or ctx.num_landmarks > n_tr:
                idx = sampling.sample_with_replacement(key, lev.probs, m)
                weights = None
            else:
                idx, weights = sampling.sample_weighted_without_replacement(
                    key, lev.probs, m, gumbel=race)
            t1 = time.perf_counter()
            fits = nystrom.fit_streaming_multi(
                ctx.kernel, x_tr, y_tr, lam_grid, idx,
                tile=tile, backend=backend, jitter=cfg.jitter,
                weights=weights if self.weighted else None,
                accumulator=accumulator, precision=precision)
            jax.block_until_ready(fits[0].beta)
            fit_s = time.perf_counter() - t1
            h_s = time.perf_counter() - t_h
            sec_key = f"calibrate[{tag}h={h:.3g}]"
            if sec_key in ctx.seconds:   # grid values equal at 3 sig figs
                sec_key = f"calibrate[{tag}h={h:.3g}#{i}]"
            ctx.seconds[sec_key] = h_s
            fits_by_h.append(fits)
            fit_seconds.append(fit_s)
            h_seconds.append(h_s)
        # validation: ONE fused x_val stream scores every (h, lam) candidate
        # (`nystrom.val_mse_streaming_multi` — slot h applies its own
        # landmarks/betas per tile) instead of H predict passes
        t_val = time.perf_counter()
        val_mse_hl = nystrom.val_mse_streaming_multi(
            [ctx.kernel] * len(h_grid), fits_by_h, x_val, y_val,
            tile=tile, backend=backend, precision=precision)
        val_mse_hl = np.asarray(jax.block_until_ready(val_mse_hl))
        ctx.seconds[f"calibrate[{tag}val]"] = time.perf_counter() - t_val
        ctx.seconds[f"calibrate[{tag}kde]"] = kde_s
        return val_mse_hl, fit_seconds, h_seconds

    def run(self, ctx: StageContext) -> None:
        lam_grid, h_grid = self._grids(ctx)
        folds = self._folds(ctx)
        k = len(folds)
        total = np.zeros((len(h_grid), len(lam_grid)))
        fit_seconds = np.zeros(len(h_grid))
        h_seconds = np.zeros(len(h_grid))
        for j, (tr_idx, val_idx) in enumerate(folds):
            tag = "" if k == 1 else f"f{j}|"
            mse_hl, fit_s, h_s = self._run_fold(ctx, lam_grid, h_grid,
                                                tr_idx, val_idx, tag, j)
            total += mse_hl
            fit_seconds += np.asarray(fit_s)
            h_seconds += np.asarray(h_s)
        val_mse_hl = total / k
        records: list[dict] = []
        for i, h in enumerate(h_grid):
            for j, lam in enumerate(lam_grid):
                mse = float(val_mse_hl[i, j])
                records.append({
                    "h": float(h), "lam": float(lam), "val_mse": mse,
                    "val_rmse": mse ** 0.5,
                    "fit_seconds": round(float(fit_seconds[i]), 4),
                    "h_block_seconds": round(float(h_seconds[i]), 4),
                    "best": False})

        # non-finite val_mse (a diverged candidate) must never win min():
        # NaN compares False against everything, so key on finiteness first
        best = min(records, key=lambda r: (not math.isfinite(r["val_mse"]),
                                           r["val_mse"]))
        best["best"] = True
        ctx.cv_scores = records
        ctx.cv_best = {"lam": best["lam"], "bandwidth": best["h"],
                       "val_mse": best["val_mse"], "folds": k}
        # rewrite the downstream knobs: the full-data refit (DensityStage
        # onward) now runs at the calibrated candidate
        ctx.lam = best["lam"]
        ctx.bandwidth = best["h"]
        ctx.densities = ctx.leverage = ctx.landmark_idx = None
        ctx.sample_weights = ctx.fit = ctx.predictions = ctx.scores = None
        ctx.score_moments = ctx.solve_state = None
        ctx.landmark_sets = ctx.batch_weights = ctx.batched_fit = None


def default_stages(config: Any = None) -> list[Stage]:
    """The paper's Algorithm 1 as a stage list: KDE -> leverage -> sample ->
    solve.  Per-stage overrides come from constructing the stages yourself."""
    del config  # stages read the config from the context at run time
    return [DensityStage(), LeverageStage(), SampleStage(), SolveStage()]


def evaluate_stages(config: Any = None) -> list[Stage]:
    """default_stages + in-sample predict/score — one fold from raw data to
    risk numbers (`SAKRRPipeline.evaluate`, bench --stages score)."""
    return default_stages(config) + [PredictStage(), ScoreStage()]


def run_stages(stages: Sequence[Stage], ctx: StageContext,
               until: str | None = None) -> StageContext:
    """Fold ctx through stages; stop (inclusive) at stage name `until`."""
    for stage in stages:
        stage(ctx)
        if until is not None and stage.name == until:
            break
    return ctx


def resolve_backend(cfg: Any) -> str | None:
    """Config backend -> dispatch arg (None lets dispatch resolve 'auto')."""
    return None if cfg.backend == "auto" else cfg.backend


def resolve_accumulator(cfg: Any) -> str:
    """Config accumulation strategy (repro.core.streaming); older configs
    without the field mean the historical plain running sum."""
    return getattr(cfg, "accumulator", None) or "plain"


def resolve_exec(stage: Any, cfg: Any, *, tile_attr: str = "tile",
                 stage_tile: bool = True
                 ) -> tuple[str | None, int | None, str, str | None]:
    """Per-stage execution knobs: (backend, tile, accumulator, precision).

    One resolver for every stage's precedence chain — stage constructor
    override beats the pipeline-wide config default.  ``tile_attr`` names
    the config field the stage's tile falls back to ("tile" for the
    solve/predict row slabs, "kde_tile" for the deposit);
    ``stage_tile=False`` ignores the stage's own tile attribute
    (CalibrateStage's shared deposit reads the config's kde_tile, not the
    stage's Gram tile).  A resolved tile of None means autotune
    (`repro.tuning` through `kernels.dispatch.resolve_plan`); a resolved
    precision of None means the Gram stream picks its (tile, precision)
    pair jointly from the autotune plan — or the historical "fp32" when
    the tile is pinned (`nystrom._resolve_gram_exec`).  Stages without a
    Gram contraction (KDE deposit) ignore the precision slot.
    """
    backend = getattr(stage, "backend", None)
    if backend is None:
        backend = resolve_backend(cfg)
    tile = getattr(stage, "tile", None) if stage_tile else None
    if tile is None:
        tile = getattr(cfg, tile_attr, None)
    accumulator = (getattr(stage, "accumulator", None)
                   or resolve_accumulator(cfg))
    precision = getattr(stage, "precision", None)
    if precision is None:
        precision = getattr(cfg, "precision", None)
    return backend, tile, accumulator, precision
