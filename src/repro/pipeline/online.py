"""Online layer over the first-class accumulator state: incremental
`partial_fit`, decayed / sliding-window absorption, and SQUEAK-style
online landmark maintenance.

The streaming stack already expresses every expensive reduction as a
monoid fold (`repro.core.accstate`); this module drives those folds over
time instead of over one batch:

  * `OnlineState` — the pipeline's live accumulators: the banked
    normal-equation state (`nystrom.NormalEqState`, free at fit time —
    SolveStage defers the finalize of the SAME stream it already ran), an
    optional CIC deposit state (`kde.DepositState`) for density drift
    tracking, and an optional ring buffer of per-chunk states for
    sliding-window absorption.  `absorb` folds a new (x, y) chunk in at
    O(chunk · m); `solve_fit` re-runs only the O(m^3) solve.
  * decay — `absorb(decay=gamma)` exponentially forgets the past IN THE
    ACCUMULATOR DOMAIN (for the compensated strategy both hi and lo scale,
    so the banked rounding error keeps compensating the sum it belongs
    to); the effective row count decays identically, so the solve's
    n·lam regularizer tracks the true effective sample size.
  * window — monoids have no inverse, so a sliding window keeps the last
    `window` per-chunk states in a ring (`accstate.SlidingWindow`) and
    refolds O(window) merges per update instead of subtracting.
  * `OnlineLandmarks` / `OnlineLandmarkStage` — SQUEAK-style sequential
    ridge-leverage dictionary maintenance: each member keeps the uniform
    that admitted it, inclusion probabilities only ever shrink as the
    stream grows (monotone coupling), so drops are exactly the members
    whose retained uniform climbs above their re-estimated probability.
    The O(|D|^2) weighted coreset refit runs only when the dictionary
    actually changed.

Checkpointing: `OnlineState.checkpoint_state()` returns a pure pytree
(the accumulator states) that `repro.checkpoint.Manager` persists
tear-safely; `restore_checkpoint_state` folds a restored tree back in so
an interrupted stream continues bit-where-it-left-off.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accstate, kde, nystrom, rls
from repro.core import kernels as core_kernels
from repro.pipeline import stages as stages_mod

Array = jax.Array


def _empty_like(state: nystrom.NormalEqState) -> nystrom.NormalEqState:
    """A zero-row clone of `state` (same landmarks / exec knobs) — the
    identity element fresh window chunks are absorbed into."""
    acc = accstate.wrap(state.acc.spec,
                        jax.tree.map(jnp.zeros_like, state.acc.value),
                        rows=0.0, steps=0)
    return dataclasses.replace(state, acc=acc)


@dataclasses.dataclass
class OnlineState:
    """Live accumulator state a fitted pipeline keeps absorbing into.

    ``solve`` is the source of truth; ``deposit`` (optional) tracks the
    density grid for drift diagnostics; ``window`` (optional) holds the
    per-chunk ring for sliding-window mode.  ``weights`` are the landmark
    column weights the original solve used (None for the unweighted
    default) — `solve_fit` re-applies them so an online re-solve is the
    same solve the SolveStage ran.
    """

    solve: nystrom.NormalEqState
    weights: Optional[Array] = None
    deposit: Optional[kde.DepositState] = None
    window: Optional[accstate.SlidingWindow] = None

    # ------------------------------------------------------------- absorb --
    def absorb(self, kernel: core_kernels.Kernel, x: Array, y: Array, *,
               decay: float | None = None,
               window: int | None = None) -> "OnlineState":
        """Fold a new chunk in: O(chunk · m) for the Gram stream plus an
        O(chunk · 2^d) deposit when one is attached.

        ``decay=gamma`` scales every accumulated moment (and the effective
        row count) by gamma BEFORE absorbing the chunk — exponential
        forgetting with per-call granularity.  ``window=k`` switches to
        sliding-window mode on first use (the current state becomes chunk
        0 of the ring); decay and window are different forgetting policies
        and cannot be combined.
        """
        if decay is not None and (window is not None or
                                  self.window is not None):
            raise ValueError("decay and window are mutually exclusive "
                             "forgetting policies; pick one")
        if window is not None:
            if self.window is None:
                self.window = accstate.SlidingWindow(
                    window, merge_fn=nystrom.normal_eq_merge)
                self.window.push(self.solve)
            elif self.window.window != window:
                raise ValueError(
                    f"window size is fixed at first use "
                    f"({self.window.window}); got {window}")
        x = jnp.asarray(x)
        y = jnp.asarray(y)
        if self.window is not None:
            chunk = nystrom.normal_eq_absorb(kernel, _empty_like(self.solve),
                                             x, y)
            self.window.push(chunk)
            self.solve = self.window.state()
        else:
            solve = self.solve
            if decay is not None:
                solve = nystrom.normal_eq_decay(solve, decay)
            self.solve = nystrom.normal_eq_absorb(kernel, solve, x, y)
        if self.deposit is not None:
            dep = self.deposit
            if decay is not None:
                dep = kde.deposit_decay(dep, decay)
            self.deposit = kde.deposit_absorb(dep, x)
        return self

    # -------------------------------------------------------------- solve --
    def solve_fit(self, lam: float, *,
                  jitter: float = 1e-6) -> nystrom.NystromFit:
        """Re-run the O(m^3) whitened solve on the current accumulators."""
        return nystrom.solve_from_state(self.solve, lam, jitter=jitter,
                                        weights=self.weights)

    @property
    def rows(self) -> float:
        """Effective (possibly decayed / windowed) absorbed row count."""
        return accstate.rows_of(self.solve.acc)

    # --------------------------------------------------------- checkpoint --
    def checkpoint_state(self) -> dict:
        """Pure-pytree view for `repro.checkpoint.Manager.save`.

        Window mode checkpoints the ring chunks (the folded state is
        derived data); otherwise the solve state itself, plus the deposit
        when one is attached.
        """
        tree: dict[str, Any] = {}
        if self.window is not None:
            tree["window"] = tuple(self.window.chunks)
        else:
            tree["solve"] = self.solve
        if self.deposit is not None:
            tree["deposit"] = self.deposit
        if self.weights is not None:
            tree["weights"] = self.weights
        return tree

    def restore_checkpoint_state(self, tree: dict) -> "OnlineState":
        """Adopt a tree previously produced by `checkpoint_state` (after a
        `checkpoint.Manager.restore` round-trip)."""
        if "window" in tree:
            chunks = tree["window"]
            if self.window is None:
                self.window = accstate.SlidingWindow(
                    max(1, len(chunks)), merge_fn=nystrom.normal_eq_merge)
            for chunk in chunks:
                self.window.push(chunk)
            self.solve = self.window.state()
        else:
            self.solve = tree["solve"]
        if "deposit" in tree:
            self.deposit = tree["deposit"]
        if "weights" in tree:
            self.weights = tree["weights"]
        return self


def from_context(ctx: stages_mod.StageContext, *, weighted: bool = False,
                 deposit: bool = False,
                 grid_size: int | None = None) -> OnlineState:
    """Seed an `OnlineState` from a fitted StageContext.

    The solve state is the one SolveStage banked (free — no re-stream).
    ``weighted=True`` mirrors a ``SolveStage(weighted=True)`` fold: the
    recorded importance weights keep rescaling every online re-solve.
    ``deposit=True`` additionally replays ONE O(n · 2^d) CIC pass over the
    training rows so density drift can be tracked online (only meaningful
    for the binned-KDE regime, d <= 3).
    """
    if ctx.solve_state is None:
        raise RuntimeError(
            "the fitted stage list banked no normal-equation state; "
            "include a SolveStage (and run fit/evaluate) before going "
            "online")
    weights = ctx.sample_weights if weighted else None
    state = OnlineState(solve=ctx.solve_state, weights=weights)
    if deposit:
        x = ctx.x
        h = ctx.bandwidth
        h = jnp.asarray(h if h is not None else kde.scott_bandwidth(x),
                        x.dtype)
        gs = grid_size or getattr(ctx.config, "kde_grid_size", None) \
            or kde.default_grid_size(ctx.d)
        lo, hi = kde.binned_bounds(x, x, h)
        cfg = ctx.config
        dep = kde.deposit_init(
            lo, hi, gs, dtype=x.dtype,
            tile=getattr(cfg, "kde_tile", None),
            accumulator=getattr(cfg, "accumulator", "plain"),
            backend=stages_mod.resolve_backend(cfg))
        state.deposit = kde.deposit_absorb(dep, x)
    return state


def _seed_probs(ctx: stages_mod.StageContext) -> np.ndarray:
    """Inclusion probabilities of the fitted landmark set: inverse
    importance weights when the without-replacement sampler recorded them,
    else m · leverage-probs at the sampled indices, else certain (1)."""
    idx = np.asarray(jax.device_get(ctx.landmark_idx))
    if ctx.sample_weights is not None:
        probs = 1.0 / np.clip(
            np.asarray(jax.device_get(ctx.sample_weights), np.float64),
            1.0, np.inf)
    elif ctx.leverage is not None:
        probs = len(idx) * np.asarray(
            jax.device_get(ctx.leverage.probs), np.float64)[idx]
    else:
        probs = np.ones(len(idx))
    return np.clip(probs, 1e-12, 1.0)


def _seed_from_ctx(ctx: stages_mod.StageContext, *, oversample: float,
                   seed: int | None,
                   backend: str | None) -> OnlineLandmarks:
    cfg = ctx.config
    idx = np.asarray(jax.device_get(ctx.landmark_idx))
    x_d = np.asarray(jax.device_get(ctx.x))[idx]
    y_d = np.asarray(jax.device_get(ctx.y))[idx]
    return OnlineLandmarks(
        ctx.kernel, x_d, y_d, _seed_probs(ctx),
        lam=ctx.lam, n0=float(ctx.n), oversample=oversample,
        seed=cfg.seed if seed is None else seed, jitter=cfg.jitter,
        backend=backend or stages_mod.resolve_backend(cfg), idx=idx)


# ------------------------------------------------------- online landmarks --

class OnlineLandmarks:
    """SQUEAK-style sequential ridge-leverage landmark dictionary.

    Each dictionary member j carries its admission uniform ``u_j`` and its
    current inclusion probability ``p_j`` (= 1 / importance weight).  As
    the stream grows, ridge leverage — and hence p — can only shrink
    (more data explains each point better), so re-estimated probabilities
    are clamped monotone (``p <- min(p, p_new)``) and a member is dropped
    exactly when its retained uniform climbs above its probability
    (``u_j >= p_j``).  This is the standard monotone coupling: the
    dictionary is distributed as if every point had been offered the
    CURRENT probabilities, without ever revisiting the stream.

    New points are admitted with probability min(1, oversample · RLS),
    where the RLS estimate projects onto the current weighted dictionary
    (`rls.projection_leverage`, mu = n_eff · lam).  The weighted coreset
    refit (`refit`) is O(|D|^2 · d + |D|^3) and only worth running when
    `update` reports a change.
    """

    def __init__(self, kernel: core_kernels.Kernel, x_dict: Array,
                 y_dict: Array, probs, *, lam: float, n0: float,
                 oversample: float = 2.0, seed: int = 0,
                 jitter: float = 1e-6, backend: str | None = None,
                 idx: Array | None = None):
        self.kernel = kernel
        self.x = np.asarray(x_dict)
        self.y = np.asarray(y_dict)
        p = np.clip(np.asarray(probs, np.float64), 1e-12, 1.0)
        self.p = p
        self.idx = (np.asarray(idx, np.int64) if idx is not None
                    else np.full(len(p), -1, np.int64))
        self._rng = np.random.default_rng(seed)
        # monotone coupling: a seeded member was admitted at probability p,
        # so its (unobserved) admission uniform is distributed U(0, p)
        self.u = self._rng.uniform(0.0, p)
        self.lam = float(lam)
        self.n = float(n0)              # effective stream size (drives mu)
        self.oversample = float(oversample)
        self.jitter = float(jitter)
        self.backend = backend
        self.changes = 0                # dictionary-changed update count
        self.updates = 0

    def __len__(self) -> int:
        return len(self.p)

    @property
    def weights(self) -> np.ndarray:
        """Inverse-inclusion importance weights 1 / p."""
        return 1.0 / self.p

    def _rls(self, x: np.ndarray) -> np.ndarray:
        mu = self.n * self.lam
        lev = rls.projection_leverage(
            self.kernel, jnp.asarray(x), jnp.asarray(self.x),
            jnp.asarray(self.weights, jnp.float32), mu,
            jitter=self.jitter, backend=self.backend)
        return np.asarray(jax.device_get(lev), np.float64)

    def update(self, x_new: Array, y_new: Array,
               idx_new: Array | None = None) -> bool:
        """Offer a new chunk to the dictionary; returns True when the
        dictionary changed (admissions and/or drops) — the caller's cue
        that a coreset `refit` is worth its O(|D|^2)."""
        x_new = np.asarray(x_new)
        y_new = np.asarray(y_new)
        self.updates += 1
        self.n += float(len(x_new))
        # admission: RLS of the offered points against the CURRENT dict
        q = np.clip(self.oversample * self._rls(x_new), 1e-12, 1.0)
        u = self._rng.uniform(0.0, 1.0, size=len(x_new))
        take = u < q
        changed = bool(take.any())
        if changed:
            self.x = np.concatenate([self.x, x_new[take]])
            self.y = np.concatenate([self.y, y_new[take]])
            self.p = np.concatenate([self.p, q[take]])
            self.u = np.concatenate([self.u, u[take]])
            new_idx = (np.asarray(idx_new, np.int64)[take]
                       if idx_new is not None
                       else np.full(int(take.sum()), -1, np.int64))
            self.idx = np.concatenate([self.idx, new_idx])
        # re-estimation: probabilities shrink monotonically with the
        # grown stream; members whose retained uniform now exceeds their
        # probability fall out (inclusion stays exactly Bernoulli(p))
        p_new = np.clip(self.oversample * self._rls(self.x), 1e-12, 1.0)
        self.p = np.minimum(self.p, p_new)
        keep = self.u < self.p
        if not keep.all():
            changed = True
            self.x, self.y = self.x[keep], self.y[keep]
            self.p, self.u = self.p[keep], self.u[keep]
            self.idx = self.idx[keep]
        if changed:
            self.changes += 1
        return changed

    def refit(self, lam: float | None = None, *,
              jitter: float | None = None) -> nystrom.NystromFit:
        """Weighted coreset KRR on the dictionary (importance weights
        1/p correct the inclusion bias): beta solves
        K^T W K beta + n lam K beta = K^T W y on the |D| x |D| system."""
        lam = self.lam if lam is None else float(lam)
        jitter = self.jitter if jitter is None else float(jitter)
        xd = jnp.asarray(self.x, jnp.float32)
        k = core_kernels.kernel_matrix(self.kernel, xd)
        w = jnp.asarray(self.weights, k.dtype)
        g = k.T @ (w[:, None] * k)
        rhs = k.T @ (w * jnp.asarray(self.y, k.dtype))
        n_eff = float(np.sum(self.weights))
        beta = nystrom.solve_normal_eq(g, rhs, k, n_eff, lam, jitter)
        return nystrom.NystromFit(beta=beta, landmarks=xd,
                                  landmark_idx=jnp.asarray(self.idx,
                                                           jnp.int32),
                                  lam=lam)


def seed_landmarks(pipeline, *, oversample: float = 2.0,
                   seed: int | None = None,
                   backend: str | None = None) -> OnlineLandmarks:
    """Seed an `OnlineLandmarks` dictionary from a fitted pipeline.

    Inclusion probabilities come from the fitted artifacts: the inverse
    Gumbel-top-k importance weights when the without-replacement sampler
    recorded them, else m · leverage-probs at the sampled indices, else
    uniform 1 (fixed landmarks — every member certain until re-estimated).
    """
    ctx = pipeline._ctx
    if ctx is None or ctx.fit is None:
        raise RuntimeError("seed_landmarks needs a fitted pipeline")
    return _seed_from_ctx(ctx, oversample=oversample, seed=seed,
                          backend=backend)


class OnlineLandmarkStage(stages_mod.Stage):
    """Stage-shaped carrier for online landmark maintenance.

    Appended to a pipeline's stage list, it seeds an `OnlineLandmarks`
    dictionary from the fold's fitted artifacts at fit time and exposes it
    as ``self.landmarks`` — so a serving loop can keep calling
    ``stage.landmarks.update(x_new, y_new)`` / ``.refit()`` after the fit
    without re-deriving the seed state.  It provides no context artifact
    (the dictionary lives on the stage, across folds).
    """

    name = "online_landmarks"
    requires = ("fit",)
    provides = ()

    def __init__(self, *, oversample: float = 2.0, seed: int | None = None,
                 backend: str | None = None):
        self.oversample = oversample
        self.seed = seed
        self.backend = backend
        self.landmarks: OnlineLandmarks | None = None

    def run(self, ctx: stages_mod.StageContext) -> None:
        self.landmarks = _seed_from_ctx(ctx, oversample=self.oversample,
                                        seed=self.seed,
                                        backend=self.backend)
