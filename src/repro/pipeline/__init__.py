"""End-to-end SA→Nyström KRR pipeline (the paper as a production system).

    from repro.pipeline import PipelineConfig, SAKRRPipeline

    pipe = SAKRRPipeline(PipelineConfig(nu=1.5, tile=8192)).fit(x, y)
    y_hat = pipe.predict(x_new)
    scores = SAKRRPipeline(cfg).evaluate(x, y, f_star=f_star)  # one fold

See `repro.pipeline.api` for the full contract.
"""

from repro.pipeline.api import (  # noqa: F401
    PipelineConfig,
    PipelineState,
    SAKRRPipeline,
)
from repro.pipeline.online import (  # noqa: F401
    OnlineLandmarks,
    OnlineLandmarkStage,
    OnlineState,
)
from repro.pipeline.stages import (  # noqa: F401
    BatchedSampleStage,
    BatchedSolveStage,
    CalibrateStage,
    DensityStage,
    FixedLandmarkStage,
    LeverageStage,
    PrecomputedDensityStage,
    PredictStage,
    SampleStage,
    ScoreStage,
    SolveStage,
    Stage,
    StageContext,
    StageError,
    default_stages,
    evaluate_stages,
    run_stages,
)
