"""LM assembly: embedding -> (scanned) blocks -> norm -> logits.

Families:
  dense   — [attn + SwiGLU] x L
  moe     — [attn + routed-MoE] x L                  (aux loss threaded out)
  ssm     — [mamba2] x L
  hybrid  — repeating unit of (attn_every-1) mamba2 layers followed by ONE
            globally *shared* attention+MLP block (zamba2); tail mamba layers
            if L % attn_every != 0.

All layer stacks run through ``_scan`` — lax.scan over stacked params when
cfg.scan_layers (compile time O(1) in depth; the production path) or an
unrolled Python loop otherwise.  The unrolled path exists because XLA's cost
analysis counts a while-loop body ONCE; the dry-run extrapolates exact FLOPs
/ bytes / collective counts from unrolled depth-1/depth-2 compiles (see
launch/dryrun.py) while the scanned compile proves memory feasibility.

Prefill returns last-token logits + caches; decode_step consumes/updates
caches (KV ring for SWA, SSM state for mamba) — O(1) per token for SSM
archs, which is what makes the long_500k cells runnable.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import attention, moe, ssm
from repro.models.config import ModelConfig
from repro.models.layers import (Spec, cross_entropy, init_params, rms_norm,
                                 swiglu)

Array = jax.Array


# ------------------------------------------------------------------ specs --
def _stack(specs, n: int):
    return jax.tree.map(
        lambda s: Spec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale),
        specs, is_leaf=lambda x: isinstance(x, Spec))


def _mlp_specs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": Spec((d, f), ("embed", "mlp")),
        "w_up": Spec((d, f), ("embed", "mlp")),
        "w_down": Spec((f, d), ("mlp", "embed")),
    }


def _attn_block_specs(cfg: ModelConfig, mlp: bool = True) -> dict:
    s = {
        "ln1": Spec((cfg.d_model,), ("norm",), init="ones"),
        "attn": attention.specs(cfg),
        "ln2": Spec((cfg.d_model,), ("norm",), init="ones"),
    }
    if mlp:
        s["mlp"] = _mlp_specs(cfg)
    return s


def _moe_block_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": Spec((cfg.d_model,), ("norm",), init="ones"),
        "attn": attention.specs(cfg),
        "ln2": Spec((cfg.d_model,), ("norm",), init="ones"),
        "moe": moe.specs(cfg),
    }


def _ssm_block_specs(cfg: ModelConfig) -> dict:
    return {
        "ln": Spec((cfg.d_model,), ("norm",), init="ones"),
        "ssm": ssm.specs(cfg),
    }


def hybrid_counts(cfg: ModelConfig) -> tuple[int, int, int]:
    """(n_groups, mamba_per_group, n_tail) for the hybrid layout."""
    k = cfg.attn_every
    n_groups = cfg.num_layers // k
    tail = cfg.num_layers - n_groups * k
    return n_groups, k - 1, tail


def model_specs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.padded_vocab
    s: dict[str, Any] = {
        "embed": Spec((v, d), ("vocab", "embed")),
        "final_norm": Spec((d,), ("norm",), init="ones"),
    }
    if not cfg.tie_embeddings:
        s["head"] = Spec((d, v), ("embed", "vocab"))
    if cfg.family == "dense":
        s["layers"] = _stack(_attn_block_specs(cfg), cfg.num_layers)
    elif cfg.family == "moe":
        s["layers"] = _stack(_moe_block_specs(cfg), cfg.num_layers)
    elif cfg.family == "ssm":
        s["layers"] = _stack(_ssm_block_specs(cfg), cfg.num_layers)
    elif cfg.family == "hybrid":
        ng, per, tail = hybrid_counts(cfg)
        s["mamba_groups"] = _stack(_stack(_ssm_block_specs(cfg), per), ng)
        if tail:
            s["mamba_tail"] = _stack(_ssm_block_specs(cfg), tail)
        s["shared"] = _attn_block_specs(cfg)
    else:
        raise ValueError(cfg.family)
    return s


def init(key: Array, cfg: ModelConfig) -> dict:
    return init_params(key, model_specs(cfg), cfg.pdtype)


# ------------------------------------------------------------------ blocks --
def _gather_axes(cfg: ModelConfig, key: str):
    """Per-leaf logical axes for one layer (leading 'layers' axes dropped)
    with the fsdp ('embed') axis cleared — constraining a weight to these
    axes all-gathers its fsdp shards just-in-time."""
    spec = model_specs(cfg)[key]

    def leaf(s: Spec):
        ax = s.axes
        while ax and ax[0] == "layers":
            ax = ax[1:]
        return tuple(None if a == "embed" else a for a in ax)

    return jax.tree.map(leaf, spec, is_leaf=lambda x: isinstance(x, Spec))


def _maybe_gather(lp, gaxes, cfg: ModelConfig):
    if not cfg.gather_weights:
        return lp
    return jax.tree.map(lambda w, ax: constrain(w, ax), lp, gaxes)


def _scan(body, carry, xs, use_scan: bool):
    """lax.scan or an unrolled Python loop with identical semantics."""
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # "full": save nothing


def _dense_block(p, x, cfg):
    x = x + attention.self_attention(p["attn"],
                                     rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + swiglu(h, p["mlp"]["w_gate"].astype(h.dtype),
                   p["mlp"]["w_up"].astype(h.dtype),
                   p["mlp"]["w_down"].astype(h.dtype))
    return constrain(x, ("batch", "seq", None))


def _moe_block(p, x, cfg):
    x = x + attention.self_attention(p["attn"],
                                     rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
    y, aux = moe.block(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return constrain(x + y, ("batch", "seq", None)), aux


def _ssm_block(p, x, cfg):
    x = x + ssm.block(p["ssm"], rms_norm(x, p["ln"], cfg.norm_eps), cfg)
    return constrain(x, ("batch", "seq", None))


def _run_layers(params, x, cfg: ModelConfig):
    """Full-sequence stack; returns (x, aux_loss)."""
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe"):
        gaxes = _gather_axes(cfg, "layers") if cfg.gather_weights else None

        def body(carry, lp):
            x, aux = carry
            lp = _maybe_gather(lp, gaxes, cfg)
            if cfg.family == "dense":
                x = _dense_block(lp, x, cfg)
            else:
                x, a = _moe_block(lp, x, cfg)
                aux = aux + a
            return (x, aux), None
        body = _remat(body, cfg)
        (x, aux), _ = _scan(body, (x, aux0), params["layers"],
                            cfg.scan_layers)
        return x, aux

    if cfg.family == "ssm":
        gaxes = _gather_axes(cfg, "layers") if cfg.gather_weights else None

        def body(carry, lp):
            lp = _maybe_gather(lp, gaxes, cfg)
            return (_ssm_block(lp, carry[0], cfg), carry[1]), None
        body = _remat(body, cfg)
        (x, _), _ = _scan(body, (x, aux0), params["layers"], cfg.scan_layers)
        return x, aux0

    # hybrid (zamba2): groups of mamba layers punctuated by the shared block
    gaxes = (_gather_axes(cfg, "mamba_groups") if cfg.gather_weights else None)
    shared = params["shared"]
    if cfg.gather_weights:
        shared = _maybe_gather(shared, _gather_axes(cfg, "shared"), cfg)

    def mamba_body(carry, lp):
        lp = _maybe_gather(lp, gaxes, cfg)
        return (_ssm_block(lp, carry[0], cfg), carry[1]), None
    mamba_body = _remat(mamba_body, cfg)

    def shared_block(x):
        return _dense_block(shared, x, cfg)
    shared_block = _remat(shared_block, cfg)

    def group_body(carry, gp):
        x, aux = carry
        (x, aux), _ = _scan(mamba_body, (x, aux), gp, cfg.scan_layers)
        x = shared_block(x)
        return (x, aux), None

    (x, _), _ = _scan(group_body, (x, aux0), params["mamba_groups"],
                      cfg.scan_layers)
    if "mamba_tail" in params:
        (x, _), _ = _scan(mamba_body, (x, aux0), params["mamba_tail"],
                          cfg.scan_layers)
    return x, aux0


# ----------------------------------------------------------------- forward --
def _embed(params, inputs, cfg: ModelConfig) -> Array:
    if cfg.inputs_embeds:
        x = inputs.astype(cfg.cdtype)
    else:
        x = jnp.take(params["embed"], inputs, axis=0).astype(cfg.cdtype)
    return constrain(x, ("batch", "seq", None))


def _logits(params, x, cfg: ModelConfig) -> Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = x @ w.astype(x.dtype)
    if cfg.padded_vocab != cfg.vocab_size:  # mask alignment-padding columns
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(col < cfg.vocab_size,
                           logits, jnp.finfo(logits.dtype).min / 2)
    return constrain(logits, ("batch", "seq", "vocab"))


def forward(params, inputs, cfg: ModelConfig) -> tuple[Array, Array]:
    """inputs: (b, s) int tokens or (b, s, d) embeddings -> (logits, aux)."""
    x = _embed(params, inputs, cfg)
    x, aux = _run_layers(params, x, cfg)
    return _logits(params, x, cfg), aux


def loss_fn(params, batch: dict, cfg: ModelConfig,
            aux_weight: float = 0.01) -> tuple[Array, dict]:
    logits, aux = forward(params, batch["inputs"], cfg)
    nll = cross_entropy(logits, batch["labels"])
    loss = nll + aux_weight * aux
    return loss, {"nll": nll, "aux": aux}


# ------------------------------------------------------------------- serve --
def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window > 0:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def init_caches(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """Zeroed cache pytree sized for decoding up to seq_len positions."""
    from repro.serving import kv_quant
    S = cache_len(cfg, seq_len)
    dh, hkv = cfg.head_dim, cfg.n_kv_heads
    dtype = cfg.cdtype

    def one_kv(n):
        shape = (n, batch, hkv, S, dh)
        if cfg.kv_cache_quant:
            return kv_quant.QuantizedKV(q=jnp.zeros(shape, jnp.int8),
                                        scale=jnp.zeros(shape[:-1],
                                                        jnp.bfloat16))
        return jnp.zeros(shape, dtype)

    def kv(n):
        return {"k": one_kv(n), "v": one_kv(n)}

    def ssm_stack(n):
        one = ssm.init_cache(cfg, batch, dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), one)

    if cfg.family in ("dense", "moe"):
        return kv(cfg.num_layers)
    if cfg.family == "ssm":
        return ssm_stack(cfg.num_layers)
    ng, per, tail = hybrid_counts(cfg)
    caches = {"mamba_groups": ssm_stack(ng * per), "attn": kv(ng)}
    if tail:
        caches["mamba_tail"] = ssm_stack(tail)
    return caches


def _constrain_kv(c):
    from repro.serving import kv_quant
    axes = ("layers", "batch", "kv_heads", "seq_kv", None)

    def one(kv_like):
        if isinstance(kv_like, kv_quant.QuantizedKV):
            return kv_quant.QuantizedKV(q=constrain(kv_like.q, axes),
                                        scale=constrain(kv_like.scale,
                                                        axes[:-1]))
        return constrain(kv_like, axes)

    c = dict(c)
    c["k"] = one(c["k"])
    c["v"] = one(c["v"])
    return c


def decode_step(params, inputs, caches: dict, index: Array,
                cfg: ModelConfig) -> tuple[Array, dict]:
    """One-token serve step.

    inputs: (b, 1) token ids or (b, 1, d) embeddings; index: scalar position
    of the new token; caches as produced by init_caches / prefill.
    Returns (logits (b, 1, vocab), new caches).
    """
    x = _embed(params, inputs, cfg)

    if cfg.family in ("dense", "moe"):
        caches = _constrain_kv(caches)

        def body(x, inp):
            lp, k_c, v_c = inp
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            y, (k_c, v_c) = attention.decode_attention(
                lp["attn"], h, cfg, (k_c, v_c), index)
            x = x + y
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if cfg.family == "dense":
                x = x + swiglu(h, lp["mlp"]["w_gate"].astype(h.dtype),
                               lp["mlp"]["w_up"].astype(h.dtype),
                               lp["mlp"]["w_down"].astype(h.dtype))
            else:
                y, _ = moe.block(lp["moe"], h, cfg)
                x = x + y
            return x, (k_c, v_c)

        x, kv_new = _scan(body, x, (params["layers"], caches["k"],
                                    caches["v"]), cfg.scan_layers)
        new_caches = _constrain_kv({"k": kv_new[0], "v": kv_new[1]})
        return _logits(params, x, cfg), new_caches

    if cfg.family == "ssm":
        def body(x, inp):
            lp, c = inp
            h = rms_norm(x, lp["ln"], cfg.norm_eps)
            y, c = ssm.decode_step(lp["ssm"], h, cfg, c)
            return x + y, c

        x, new_c = _scan(body, x, (params["layers"], caches),
                         cfg.scan_layers)
        return _logits(params, x, cfg), new_c

    # hybrid
    ng, per, tail = hybrid_counts(cfg)
    shared = params["shared"]
    attn_caches = _constrain_kv(caches["attn"])

    def mamba_body(x, inp):
        lp, c = inp
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        y, c = ssm.decode_step(lp["ssm"], h, cfg, c)
        return x + y, c

    def group_body(x, inp):
        gp, gc, k_c, v_c = inp
        x, gc = _scan(mamba_body, x, (gp, gc), cfg.scan_layers)
        h = rms_norm(x, shared["ln1"], cfg.norm_eps)
        y, (k_c, v_c) = attention.decode_attention(
            shared["attn"], h, cfg, (k_c, v_c), index)
        x = x + y
        h = rms_norm(x, shared["ln2"], cfg.norm_eps)
        x = x + swiglu(h, shared["mlp"]["w_gate"].astype(h.dtype),
                       shared["mlp"]["w_up"].astype(h.dtype),
                       shared["mlp"]["w_down"].astype(h.dtype))
        return x, (gc, k_c, v_c)

    group_mamba = jax.tree.map(
        lambda a: a.reshape((ng, per) + a.shape[1:]), caches["mamba_groups"])
    x, (gc_new, k_new, v_new) = _scan(
        group_body, x,
        (params["mamba_groups"], group_mamba, attn_caches["k"],
         attn_caches["v"]), cfg.scan_layers)
    new_caches = {
        "mamba_groups": jax.tree.map(
            lambda a: a.reshape((ng * per,) + a.shape[2:]), gc_new),
        "attn": _constrain_kv({"k": k_new, "v": v_new}),
    }
    if tail:
        x, tail_new = _scan(mamba_body, x,
                            (params["mamba_tail"], caches["mamba_tail"]),
                            cfg.scan_layers)
        new_caches["mamba_tail"] = tail_new
    return _logits(params, x, cfg), new_caches


def prefill(params, inputs, cfg: ModelConfig,
            cache_seq_len: Optional[int] = None) -> tuple[Array, dict]:
    """Process a prompt; returns (last-token logits, caches at len(prompt)).

    Caches are allocated at cache_seq_len (defaults to the prompt length) so
    decode can continue in place.
    """
    if cfg.inputs_embeds:
        b, s = inputs.shape[:2]
    else:
        b, s = inputs.shape
    S = cache_seq_len or s
    x = _embed(params, inputs, cfg)

    def pad_kv(k):  # (b, hkv, s, dh) -> (b, hkv, S_cache, dh)
        Sc = cache_len(cfg, S)
        if cfg.sliding_window > 0 and s > Sc:
            # ring semantics: token p lives at slot p % Sc
            k = jnp.roll(k[:, :, s - Sc:, :], shift=s % Sc, axis=2)
        return jnp.pad(k, ((0, 0), (0, 0), (0, Sc - min(s, Sc)), (0, 0)))

    def emit_kv(k):
        k = pad_kv(k).astype(cfg.cdtype)
        if cfg.kv_cache_quant:
            from repro.serving import kv_quant
            return kv_quant.quantize(k)
        return k

    if cfg.family in ("dense", "moe"):
        def body(carry, lp):
            x = carry
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            y, (k, v) = attention.self_attention(lp["attn"], h, cfg,
                                                 return_kv=True)
            x = x + y
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if cfg.family == "dense":
                x = x + swiglu(h, lp["mlp"]["w_gate"].astype(h.dtype),
                               lp["mlp"]["w_up"].astype(h.dtype),
                               lp["mlp"]["w_down"].astype(h.dtype))
            else:
                y, _ = moe.block(lp["moe"], h, cfg)
                x = x + y
            x = constrain(x, ("batch", "seq", None))
            return x, (emit_kv(k), emit_kv(v))

        body = _remat(body, cfg)
        x, kv = _scan(body, x, params["layers"], cfg.scan_layers)
        caches = _constrain_kv({"k": kv[0], "v": kv[1]})
        return _logits(params, x[:, -1:], cfg), caches

    if cfg.family == "ssm":
        def body(x, lp):
            h = rms_norm(x, lp["ln"], cfg.norm_eps)
            y, c = ssm.block(lp["ssm"], h, cfg, return_state=True)
            return x + y, c

        body = _remat(body, cfg)
        x, states = _scan(body, x, params["layers"], cfg.scan_layers)
        return _logits(params, x[:, -1:], cfg), states

    # hybrid: collect mamba states per group + shared-attn KV per site
    ng, per, tail = hybrid_counts(cfg)
    shared = params["shared"]

    def mamba_body(x, lp):
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        y, c = ssm.block(lp["ssm"], h, cfg, return_state=True)
        return x + y, c
    mamba_body = _remat(mamba_body, cfg)

    def group_body(x, gp):
        x, states = _scan(mamba_body, x, gp, cfg.scan_layers)
        h = rms_norm(x, shared["ln1"], cfg.norm_eps)
        y, (k, v) = attention.self_attention(shared["attn"], h, cfg,
                                             return_kv=True)
        x = x + y
        h = rms_norm(x, shared["ln2"], cfg.norm_eps)
        x = x + swiglu(h, shared["mlp"]["w_gate"].astype(h.dtype),
                       shared["mlp"]["w_up"].astype(h.dtype),
                       shared["mlp"]["w_down"].astype(h.dtype))
        x = constrain(x, ("batch", "seq", None))
        return x, (states, emit_kv(k), emit_kv(v))

    x, (gstates, ks, vs) = _scan(group_body, x, params["mamba_groups"],
                                 cfg.scan_layers)
    caches = {
        "mamba_groups": jax.tree.map(
            lambda a: a.reshape((ng * per,) + a.shape[2:]), gstates),
        "attn": _constrain_kv({"k": ks, "v": vs}),
    }
    if tail:
        x, tstates = _scan(mamba_body, x, params["mamba_tail"],
                           cfg.scan_layers)
        caches["mamba_tail"] = tstates
    return _logits(params, x[:, -1:], cfg), caches
