"""Shared NN building blocks + the param-spec system.

A model is described by a nested dict of ``Spec`` leaves (shape, logical
sharding axes, init).  From one spec tree we derive:

  * ``init_params``     — materialised arrays (smoke tests / real training)
  * ``abstract_params`` — ShapeDtypeStructs w/ NamedShardings (dry-run: the
                          141B-param configs are never allocated)
  * ``axes_tree``       — logical axes for optimizer-state sharding

so shapes, shardings and initialisation can never drift apart.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "normal"    # normal | zeros | ones | ssm_a_log | ssm_dt_bias
    scale: float = 1.0      # for normal: stddev multiplier on 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, Spec)


def _materialise(key: Array, spec: Spec, dtype) -> Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "ssm_a_log":
        # A = -exp(A_log) with A_log ~ log U[1, 16]  (Mamba2 default)
        u = jax.random.uniform(key, spec.shape, minval=1.0, maxval=16.0)
        return jnp.log(u).astype(dtype)
    if spec.init == "ssm_dt_bias":
        # dt ~ U[1e-3, 1e-1] through inverse softplus
        u = jax.random.uniform(key, spec.shape, minval=math.log(1e-3),
                               maxval=math.log(1e-1))
        dt = jnp.exp(u)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale / math.sqrt(fan_in)
    return (jax.random.normal(key, spec.shape) * std).astype(dtype)


def init_params(key: Array, specs, dtype) -> dict:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_materialise(k, s, dtype) for k, s in zip(keys, leaves)])


def abstract_params(specs, dtype, with_sharding: bool = True):
    act = shd.active() if with_sharding else None

    def mk(s: Spec):
        if act is None:
            return jax.ShapeDtypeStruct(s.shape, dtype)
        return jax.ShapeDtypeStruct(s.shape, dtype,
                                    sharding=act.sharding(s.axes, s.shape))

    return jax.tree.map(mk, specs, is_leaf=_is_spec)


def axes_tree(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def param_count(specs) -> int:
    return sum(math.prod(s.shape) for s in
               jax.tree.leaves(specs, is_leaf=_is_spec))


# ---------------------------------------------------------------- NN ops ----
def rms_norm(x: Array, weight: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * weight.astype(dt)


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    g = x @ w_gate
    u = x @ w_up
    return (jax.nn.silu(g) * u) @ w_down


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (b, h, s, dh); positions: (s,) or (b, s)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                      # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, dh/2)
    if angles.ndim == 2:                               # (s, dh/2) -> bcast b,h
        angles = angles[None, None]
    else:                                              # (b, s, dh/2)
        angles = angles[:, None]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits: Array, labels: Array) -> Array:
    """Mean token NLL in fp32; logits (..., v) may be vocab-sharded (GSPMD
    inserts the model-axis reductions for the max / logsumexp)."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    gold = jnp.take_along_axis(shifted, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
