"""Mamba2 (SSD) sequence-mixing block — full-sequence and recurrent decode.

Structure (ngroups = 1, following the Mamba2 reference):

  z, x, B, C, dt = separate projections of the input        (d -> 2di+2s+h)
  x, B, C <- silu(causal depthwise conv_w4(.))
  dt      <- softplus(dt + dt_bias)           per SSM head
  a_log   <- -exp(A_log) * dt                 (log decay, <= 0)
  y       <- SSD(x * dt, a_log, B, C) + D * x
  out     <- out_proj( RMSNorm(y) * silu(z) )

Full-sequence mixing runs the chunked SSD (XLA ref or the Pallas kernel);
decode keeps O(1) state per layer: the (h, p, s) SSM state plus a
(conv_width-1)-deep conv ring — this is what makes long_500k decode feasible
for the SSM/hybrid archs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.kernels.ssd import ops as ssd_ops
from repro.models.config import ModelConfig
from repro.models.layers import Spec, rms_norm

Array = jax.Array


def specs(cfg: ModelConfig) -> dict:
    d, di, s = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h, w = cfg.n_ssm_heads, cfg.ssm_conv_width
    return {
        "wz": Spec((d, di), ("embed", "mlp")),
        "wx": Spec((d, di), ("embed", "mlp")),
        "wB": Spec((d, s), ("embed", None)),
        "wC": Spec((d, s), ("embed", None)),
        "wdt": Spec((d, h), ("embed", None)),
        "conv_x": Spec((w, di), (None, "mlp"), init="normal", scale=1.0),
        "conv_B": Spec((w, s), (None, None)),
        "conv_C": Spec((w, s), (None, None)),
        "A_log": Spec((h,), (None,), init="ssm_a_log"),
        "dt_bias": Spec((h,), (None,), init="ssm_dt_bias"),
        "D": Spec((h,), (None,), init="ones"),
        "norm": Spec((di,), ("mlp",), init="ones"),
        "wo": Spec((di, d), ("mlp", "embed")),
    }


def _causal_conv(x: Array, w: Array) -> Array:
    """Depthwise causal conv; x (b, l, c), w (width, c)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
    return out


def _conv_step(state: Array, x_new: Array, w: Array) -> tuple[Array, Array]:
    """Recurrent conv step; state (b, width-1, c), x_new (b, c)."""
    window = jnp.concatenate([state, x_new[:, None, :]], axis=1)  # (b, w, c)
    out = jnp.sum(window * w[None].astype(x_new.dtype), axis=1)
    return window[:, 1:, :], out


def _gates(p: dict, u: Array, cfg: ModelConfig):
    cd = u.dtype
    z = u @ p["wz"].astype(cd)
    x = u @ p["wx"].astype(cd)
    B = u @ p["wB"].astype(cd)
    C = u @ p["wC"].astype(cd)
    dt_raw = u @ p["wdt"].astype(cd)
    return z, x, B, C, dt_raw


def _discretise(p: dict, dt_raw: Array):
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a_log = -jnp.exp(p["A_log"].astype(jnp.float32)) * dt  # (..., h) <= 0
    return dt, a_log


def block(p: dict, u: Array, cfg: ModelConfig, return_state: bool = False):
    """Full-sequence mixing.  u: (b, l, d) -> (b, l, d) [, final cache].

    With return_state=True also computes the end-of-sequence recurrent cache
    (SSM state + conv rings) so decode can continue after a prefill:
      S_end = sum_j exp(A_total - A_cum_j) (dt_j x_j) (x) B_j   (fp32 einsum).
    """
    b, l, d = u.shape
    h, pd = cfg.n_ssm_heads, cfg.ssm_headdim
    z, x, B, C, dt_raw = _gates(p, u, cfg)
    x_pre, B_pre, C_pre = x, B, C
    x = jax.nn.silu(_causal_conv(x, p["conv_x"]))
    B = jax.nn.silu(_causal_conv(B, p["conv_B"]))
    C = jax.nn.silu(_causal_conv(C, p["conv_C"]))
    x = constrain(x, ("batch", "seq", "mlp"))
    dt, a_log = _discretise(p, dt_raw)                       # (b, l, h)
    xh = x.reshape(b, l, h, pd)
    xdt = (xh.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    y = ssd_ops.ssd(
        xdt, a_log, B, C,
        use_pallas=(cfg.attention_impl == "pallas"),
    )
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(b, l, cfg.d_inner)
    y = rms_norm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = y @ p["wo"].astype(y.dtype)
    out = constrain(out, ("batch", "seq", None))
    if not return_state:
        return out
    a_cum = jnp.cumsum(a_log, axis=1)                        # (b, l, h)
    decay = jnp.exp(a_cum[:, -1:, :] - a_cum)                # <= 1
    S_end = jnp.einsum("blh,blhp,bls->bhps",
                       decay, xdt.astype(jnp.float32),
                       B.astype(jnp.float32))
    w = cfg.ssm_conv_width
    cache = {
        "ssm": S_end,
        "conv_x": x_pre[:, l - (w - 1):, :].astype(u.dtype),
        "conv_B": B_pre[:, l - (w - 1):, :].astype(u.dtype),
        "conv_C": C_pre[:, l - (w - 1):, :].astype(u.dtype),
    }
    return out, cache


def init_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    """Per-layer recurrent state for decode."""
    h, pd, s = cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    w = cfg.ssm_conv_width
    di = cfg.d_inner
    return {
        "ssm": jnp.zeros((batch, h, pd, s), jnp.float32),
        "conv_x": jnp.zeros((batch, w - 1, di), dtype),
        "conv_B": jnp.zeros((batch, w - 1, cfg.ssm_state), dtype),
        "conv_C": jnp.zeros((batch, w - 1, cfg.ssm_state), dtype),
    }


def decode_step(p: dict, u: Array, cfg: ModelConfig, cache: dict):
    """One-token recurrent step.  u: (b, 1, d) -> (y (b,1,d), new cache)."""
    b = u.shape[0]
    h, pd = cfg.n_ssm_heads, cfg.ssm_headdim
    z, x, B, C, dt_raw = _gates(p, u[:, 0, :], cfg)
    cx, x = _conv_step(cache["conv_x"], x, p["conv_x"])
    cB, B = _conv_step(cache["conv_B"], B, p["conv_B"])
    cC, C = _conv_step(cache["conv_C"], C, p["conv_C"])
    x, B, C = jax.nn.silu(x), jax.nn.silu(B), jax.nn.silu(C)
    dt, a_log = _discretise(p, dt_raw)                       # (b, h)
    xh = x.reshape(b, h, pd).astype(jnp.float32)
    S = cache["ssm"] * jnp.exp(a_log)[..., None, None]
    S = S + jnp.einsum("bhp,bs->bhps", xh * dt[..., None],
                       B.astype(jnp.float32))
    y = jnp.einsum("bhps,bs->bhp", S, C.astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, cfg.d_inner).astype(u.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = (y @ p["wo"].astype(y.dtype)).reshape(b, 1, -1)
    new_cache = {"ssm": S, "conv_x": cx, "conv_B": cB, "conv_C": cC}
    return out, new_cache
