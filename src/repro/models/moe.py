"""Top-k routed MoE (Mixtral-style) with GShard grouped-capacity dispatch.

Tokens are split into groups of ``moe_group_size``; within each group every
expert accepts at most c = ceil(top_k * capacity_factor * g / E) tokens
(overflow dropped, standard GShard semantics).  The dispatch/combine tensors
are (G, g, E, c) one-hots, so peak memory is top_k*cf*T*g elements — linear
in tokens for fixed g — instead of the quadratic T*E*c_full of ungrouped
dispatch.  All dispatch math is einsum (MXU-friendly, GSPMD-partitionable):

  expert_in  = einsum('Ggec,Ggd->Gecd', dispatch, x)
  expert_mid = swiglu per expert                       (e sliced or TP on f)
  y          = einsum('Ggec,Gecd->Ggd', combine, expert_out)

Sharding: groups G follow the batch axis (DP); d_ff follows 'mlp' (TP).  With
8 experts on a 16-way model axis the expert dim is *not* divisible, so the
default rule keeps experts unsharded and slices d_ff ("expert-sliced TP",
DESIGN.md §5); the hillclimb evaluates the alternative factorisation.

Aux load-balance loss (Switch-style) is returned for the trainer.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import Spec

Array = jax.Array


def specs(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": Spec((d, e), ("embed", "experts")),
        "w_gate": Spec((e, d, f), ("experts", "embed", "mlp")),
        "w_up": Spec((e, d, f), ("experts", "embed", "mlp")),
        "w_down": Spec((e, f, d), ("experts", "mlp", "embed")),
    }


def block(p: dict, x: Array, cfg: ModelConfig) -> tuple[Array, Array]:
    """x: (b, s, d) -> (y, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    tokens = b * s
    g = min(cfg.moe_group_size, tokens)
    while tokens % g != 0:  # largest divisor <= moe_group_size
        g -= 1
    n_groups = tokens // g
    cap = max(1, math.ceil(k * cfg.capacity_factor * g / e))
    xt = x.reshape(n_groups, g, d)
    xt = constrain(xt, ("batch", None, None))

    logits = xt @ p["router"].astype(xt.dtype)            # (G, g, e)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                  # (G, g, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)   # renormalise top-k

    # aux load-balance loss: E * sum_e mean(frac_tokens_e * mean_prob_e)
    me = jnp.mean(probs, axis=1)                                   # (G, e)
    one_hot_all = jax.nn.one_hot(topi, e, dtype=jnp.float32)       # (G,g,k,e)
    ce = jnp.mean(jnp.sum(one_hot_all, axis=2), axis=1) / k        # (G, e)
    aux = e * jnp.mean(jnp.sum(me * ce, axis=-1))

    # position of each (token, choice) in its expert's buffer
    flat_hot = one_hot_all.reshape(n_groups, g * k, e)
    pos_in_expert = jnp.cumsum(flat_hot, axis=1) - flat_hot        # (G, gk, e)
    pos = jnp.sum(pos_in_expert * flat_hot, axis=-1).reshape(n_groups, g, k)
    keep = pos < cap                                               # capacity
    w = topw * keep.astype(topw.dtype)

    # dispatch/combine one-hots over the capacity slots
    pos_hot = jax.nn.one_hot(jnp.where(keep, pos, cap), cap,
                             dtype=jnp.float32)                    # (G,g,k,c)
    disp = jnp.einsum("Ggke,Ggkc->Ggec", one_hot_all,
                      pos_hot)                                     # (G,g,e,c)
    comb = jnp.einsum("Ggk,Ggke,Ggkc->Ggec", w, one_hot_all, pos_hot)

    cd = xt.dtype
    expert_in = jnp.einsum("Ggec,Ggd->Gecd", disp.astype(cd), xt)
    expert_in = constrain(expert_in, ("batch", "experts", None, None))
    gate = jnp.einsum("Gecd,edf->Gecf", expert_in, p["w_gate"].astype(cd))
    up = jnp.einsum("Gecd,edf->Gecf", expert_in, p["w_up"].astype(cd))
    mid = jax.nn.silu(gate) * up
    mid = constrain(mid, ("batch", "experts", None, "mlp"))
    expert_out = jnp.einsum("Gecf,efd->Gecd", mid, p["w_down"].astype(cd))
    y = jnp.einsum("Ggec,Gecd->Ggd", comb.astype(cd), expert_out)
    return y.reshape(b, s, d), aux
