"""Model / shape configuration for the assigned LM architectures.

One ModelConfig covers every family in the pool:

  dense   — GQA/MHA transformer (llama3.2, qwen3, qwen1.5, stablelm,
            musicgen backbone, llava backbone)
  moe     — dense attention + top-k routed experts (mixtral 8x7b / 8x22b)
  ssm     — Mamba2 / SSD, attention-free (mamba2-130m)
  hybrid  — Mamba2 backbone with a periodically applied *shared* attention
            block (zamba2-7b)

ShapeSpec mirrors the assigned input-shape pool (train_4k / prefill_32k /
decode_32k / long_500k).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid
    num_layers: int
    d_model: int
    vocab_size: int

    # attention (dense/moe/hybrid)
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0                # 0 -> d_model // n_heads
    qk_norm: bool = False            # qwen3-style per-head RMSNorm on q, k
    qkv_bias: bool = False           # qwen1.5-style bias on qkv projections
    rope_theta: float = 10_000.0
    sliding_window: int = -1         # >0 -> SWA (mixtral)

    # mlp
    d_ff: int = 0                    # SwiGLU hidden size (dense path)

    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 1024       # GShard dispatch group (tokens)

    # ssm (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    attn_every: int = 0              # hybrid: shared attn before every k-th layer

    # io
    inputs_embeds: bool = False      # audio/vlm stubs feed embeddings directly
    tie_embeddings: bool = True

    # numerics / execution
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"              # none | dots | full
    scan_layers: bool = True
    attention_impl: str = "xla_chunked"  # xla | xla_chunked | pallas
    attn_chunk: int = 1024           # q-chunk for xla_chunked
    norm_eps: float = 1e-5
    gather_weights: bool = False     # FSDP: all-gather weights just-in-time
    #   inside the layer body (ZeRO-3 style) instead of letting GSPMD pick —
    #   prevents partial-sum all-reduce of ACTIVATIONS when a weight's
    #   contracting dim carries the fsdp axis (§Perf cell A iteration 4)
    kv_cache_quant: bool = False     # serve KV caches as int8 + per-row
    #   absmax scales (serving/kv_quant.py): 2x cache memory, <1% attn error

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived sizes ------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a 128 multiple: TPU lane alignment + even
        16-way TP sharding (e.g. mamba2's 50280).  Padded logit columns are
        masked to -inf; labels never reference them."""
        return -(-self.vocab_size // 128) * 128

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs and docs)."""
        d, v = self.d_model, self.vocab_size
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        per_layer = 0
        if self.family in ("dense", "moe"):
            per_layer += self._attn_params() + 2 * d  # 2 norms
            if self.family == "dense":
                per_layer += 3 * d * self.d_ff
            else:
                per_layer += self.n_experts * 3 * d * self.d_ff + d * self.n_experts
            n += self.num_layers * per_layer
        elif self.family == "ssm":
            n += self.num_layers * (self._mamba_params() + d)
        elif self.family == "hybrid":
            n_attn_sites = self.num_layers // max(self.attn_every, 1)
            n_mamba = self.num_layers - n_attn_sites
            n += n_mamba * (self._mamba_params() + d)
            n += self._attn_params() + 3 * d * self.d_ff + 2 * d  # ONE shared block
        n += d  # final norm
        return n

    def _attn_params(self) -> int:
        d, dh = self.d_model, self.head_dim
        return d * dh * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * dh * d

    def _mamba_params(self) -> int:
        d, di, s = self.d_model, self.d_inner, self.ssm_state
        h = self.n_ssm_heads
        in_proj = d * (2 * di + 2 * s + h)       # z, x, B, C, dt
        conv = self.ssm_conv_width * (di + 2 * s)
        return in_proj + conv + 2 * h + di + di * d  # A_log, D, gate-norm, out

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dense_like = self.param_count() - self.num_layers * (
            self.n_experts - self.top_k) * 3 * d * self.d_ff
        return dense_like


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def supports_shape(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; (False, reason) for skips.

    long_500k requires sub-quadratic attention state: SSM (O(1)), hybrid
    (SSM + a handful of shared-attn KV slots), or sliding-window (O(window)).
    Pure full-attention archs are skipped per the brief.
    """
    if shape.name == "long_500k":
        if cfg.family in ("ssm", "hybrid"):
            return True, ""
        if cfg.sliding_window > 0:
            return True, "SWA ring cache (O(window) state)"
        return False, "pure full attention: 500k dense KV cache is quadratic-in-context state"
    return True, ""
