"""LM model stack (pure JAX; Pallas kernels optional)."""
from repro.models import attention, config, layers, model, moe, ssm  # noqa: F401
