"""GQA attention block: train/prefill self-attention + cached decode.

Three interchangeable implementations (all numerically validated against each
other in tests/test_models.py):

  xla          — whole-logits einsum (small seqs, oracle)
  xla_chunked  — q-chunked flash-style (lax.scan over q blocks, keys masked;
                 peak logits memory b*h*chunk*s instead of b*h*s*s) — the
                 default and the dry-run path
  pallas       — repro.kernels.flash_attention (TPU runtime)

GQA never materialises repeated K/V: q is reshaped to (b, kv_heads, group,
s, dh) and contracted against the (b, kv_heads, s, dh) K/V directly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.kernels.flash_attention import ops as fa_ops
from repro.models.config import ModelConfig
from repro.models.layers import Spec, apply_rope, rms_norm

Array = jax.Array
NEG_INF = -1e30


def specs(cfg: ModelConfig) -> dict:
    d, dh = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    s = {
        "wq": Spec((d, hq * dh), ("embed", "heads")),
        "wk": Spec((d, hkv * dh), ("embed", "heads")),
        "wv": Spec((d, hkv * dh), ("embed", "heads")),
        "wo": Spec((hq * dh, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = Spec((hq * dh,), ("heads",), init="zeros")
        s["bk"] = Spec((hkv * dh,), ("heads",), init="zeros")
        s["bv"] = Spec((hkv * dh,), ("heads",), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = Spec((dh,), (None,), init="ones")
        s["k_norm"] = Spec((dh,), (None,), init="ones")
    return s


def _project_qkv(p: dict, x: Array, cfg: ModelConfig, positions: Array):
    b, s, _ = x.shape
    dh, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, hq, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", "heads", "seq", None))
    k = constrain(k, ("batch", "kv_heads", "seq", None))
    v = constrain(v, ("batch", "kv_heads", "seq", None))
    return q, k, v


def _masked_logits(q: Array, k: Array, q_pos: Array, k_pos: Array,
                   scale: float, window: int) -> Array:
    """q (b,hkv,g,sq,dh), k (b,hkv,sk,dh) -> (b,hkv,g,sq,sk), causal+window."""
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(mask[None, None, None], logits, NEG_INF)


def _attend_xla(q, k, v, cfg, scale):
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, dh)
    pos = jnp.arange(sq)
    logits = _masked_logits(qg, k, pos, pos, scale, cfg.sliding_window)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, dh).astype(q.dtype)


def _attend_xla_chunked(q, k, v, cfg, scale):
    """lax.scan over q chunks; logits never exceed (b,h,chunk,s)."""
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    chunk = min(cfg.attn_chunk, sq)
    if sq % chunk != 0:  # ragged fall-back (smoke shapes)
        return _attend_xla(q, k, v, cfg, scale)
    n_chunks = sq // chunk
    qg = q.reshape(b, hkv, g, n_chunks, chunk, dh)
    qg = jnp.moveaxis(qg, 3, 0)  # (nc, b, hkv, g, chunk, dh)
    k_pos = jnp.arange(sq)

    def body(carry, inp):
        ci, q_c = inp
        q_pos = ci * chunk + jnp.arange(chunk)
        logits = _masked_logits(q_c, k, q_pos, k_pos, scale, cfg.sliding_window)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v.astype(jnp.float32))
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(body, 0, (jnp.arange(n_chunks), qg))
    outs = jnp.moveaxis(outs, 0, 3)  # (b,hkv,g,nc,chunk,dh)
    return outs.reshape(b, hkv, g, sq, dh).reshape(b, hq, sq, dh)


def self_attention(p: dict, x: Array, cfg: ModelConfig,
                   positions: Array | None = None,
                   return_kv: bool = False):
    """Full-sequence (train / prefill) attention.  x: (b, s, d)."""
    b, s, d = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _project_qkv(p, x, cfg, positions)
    scale = cfg.head_dim ** -0.5
    impl = cfg.attention_impl
    if impl == "pallas":
        out = fa_ops.attention(q, k, v, causal=True, window=cfg.sliding_window,
                               scale=scale)
    elif impl == "xla_chunked":
        out = _attend_xla_chunked(q, k, v, cfg, scale)
    else:
        out = _attend_xla(q, k, v, cfg, scale)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    y = out @ p["wo"].astype(x.dtype)
    y = constrain(y, ("batch", "seq", None))
    if return_kv:
        return y, (k, v)
    return y


def decode_attention(p: dict, x: Array, cfg: ModelConfig, cache: tuple,
                     index: Array):
    """One-token decode.  x: (b, 1, d); cache (k, v): (b, hkv, S, dh) ring
    buffers (SWA archs allocate S = window) — raw arrays or int8
    serving/kv_quant.QuantizedKV; index: current position."""
    from repro.serving import kv_quant
    b, _, d = x.shape
    k_cache, v_cache = cache
    quantized = isinstance(k_cache, kv_quant.QuantizedKV)
    S = (k_cache.q if quantized else k_cache).shape[2]
    window = cfg.sliding_window
    slot = (index % S if window > 0 else index).astype(jnp.int32)
    positions = index[None].astype(jnp.int32)  # rope uses absolute position
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)
    if quantized:
        k_cache = kv_quant.update_row(k_cache, k_new, slot)
        v_cache = kv_quant.update_row(v_cache, v_new, slot)
        k_attn = kv_quant.dequantize(k_cache, jnp.float32)
        v_attn = kv_quant.dequantize(v_cache, jnp.float32)
    else:
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_new.astype(k_cache.dtype), (0, 0, slot, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_new.astype(v_cache.dtype), (0, 0, slot, 0))
        k_cache = constrain(k_cache, ("batch", "kv_heads", "seq_kv", None))
        v_cache = constrain(v_cache, ("batch", "kv_heads", "seq_kv", None))
        k_attn, v_attn = k_cache, v_cache

    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = hq // hkv
    qg = q.reshape(b, hkv, g, 1, dh)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                        k_attn.astype(jnp.float32)) * dh ** -0.5
    # valid cache slots: ring for SWA (all written slots live), prefix else
    slot_ids = jnp.arange(S)
    if window > 0:
        valid = slot_ids < jnp.minimum(index + 1, S)
    else:
        valid = slot_ids <= index
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs,
                     v_attn.astype(jnp.float32))
    out = out.reshape(b, hq, 1, dh).transpose(0, 2, 1, 3).reshape(b, 1, hq * dh)
    y = out.astype(x.dtype) @ p["wo"].astype(x.dtype)
    return y, (k_cache, v_cache)
