"""Distribution substrate: logical-axis sharding, meshes, collectives."""
