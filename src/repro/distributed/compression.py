"""Error-feedback gradient compression for the cross-pod data-parallel
all-reduce (1-bit sign + per-tensor L1 scale, à la EF-SGD / 1-bit Adam).

At 2+ pods the inter-pod links are the scarcest bandwidth (ICI within a pod,
DCN between pods).  Compressing the pod-synchronised gradient to sign+scale
cuts cross-pod bytes 16x (bf16) while the residual buffer keeps the update
unbiased-in-the-limit.  Exposed as a ``compress_fn`` for make_train_step;
the all-reduce itself stays inside the jit (GSPMD partitions it), so the
compression simply changes WHAT is reduced.

Also provides fp32->bf16 "light" compression (2x) as a low-risk default.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: dict  # fp32, same tree as grads


def ef_init(params) -> EFState:
    return EFState(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def sign_compress(grads, state: EFState):
    """Returns (decompressed grads as seen post-allreduce, new state).

    c = sign(g + r) * mean|g + r|;  r' = (g + r) - c
    """
    def one(g, r):
        acc = g.astype(jnp.float32) + r
        scale = jnp.mean(jnp.abs(acc))
        c = jnp.sign(acc) * scale
        return c, acc - c

    out = jax.tree.map(one, grads, state.residual)
    comp = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    resid = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return comp, EFState(resid)


def bf16_compress(grads):
    """Cheap 2x: round-trip the DP all-reduce payload through bf16."""
    return jax.tree.map(
        lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
