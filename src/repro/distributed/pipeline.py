"""GPipe-style pipeline parallelism (PP) building block.

``gpipe`` runs S pipeline stages (layer stacks) sharded over a mesh axis,
streaming M microbatches with the classic (M + S - 1)-tick schedule:
stage k processes microbatch (t - k) at tick t, activations hop stage->stage
via collective_permute, and the last stage emits results.  Bubble fraction
is the usual (S-1)/(M+S-1).

Scope note (DESIGN.md §5): the assigned configs fit 256-512 chips with
DP/FSDP/TP/SP/EP, so the production launchers do not enable PP; this module
is the validated building block for the >100B-dense regime where a 'stage'
mesh axis becomes necessary.  Equivalence vs sequential execution is tested
on forced host devices (tests/test_pipeline_pp.py).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array


def gpipe(layer_fn: Callable, stage_params, x: Array, *, mesh: Mesh,
          axis: str = "stage") -> Array:
    """Apply S stages to M microbatches with pipelined execution.

    layer_fn(params_for_one_stage, h) -> h', where h is one microbatch.
    stage_params: pytree whose leaves have leading dim S (stage-stacked).
    x: (M, ...) microbatches, replicated.
    Returns (M, ...) = sequential application of all stages (tested).
    """
    S = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    M = x.shape[0]
    fwd = [(i, i + 1) for i in range(S - 1)]  # stage i -> i+1

    def _varying(v):  # mark as device-varying for the scan carry typing
        if hasattr(jax.lax, "pcast"):
            return jax.lax.pcast(v, (axis,), to="varying")
        if hasattr(jax.lax, "pvary"):
            return jax.lax.pvary(v, (axis,))
        # older jax (no varying-manual-axes typing): the scan carry needs no
        # annotation; shard_map's replication checker accepts it as-is
        return v

    def body(local_params, xs):
        lp = jax.tree.map(lambda a: a[0], local_params)  # this stage's params
        idx = jax.lax.axis_index(axis)
        buf = _varying(jnp.zeros(xs.shape[1:], xs.dtype))
        outs = _varying(jnp.zeros_like(xs))

        def tick(carry, t):
            buf, outs = carry
            inject = xs[jnp.clip(t, 0, M - 1)]
            h_in = jnp.where(idx == 0, inject, buf)
            h_out = layer_fn(lp, h_in)
            buf_next = jax.lax.ppermute(h_out, axis, fwd)
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            emit = jnp.logical_and(t >= S - 1, idx == S - 1)
            outs = jnp.where(emit, outs.at[out_idx].set(h_out), outs)
            return (buf_next, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                      jnp.arange(M + S - 1))
        # replicate the last stage's collected outputs to every stage
        outs = jax.lax.psum(
            jnp.where(idx == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    return shard_map(
        body, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), stage_params), P()),
        out_specs=P(),
    )(stage_params, x)


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)
