"""Logical-axis sharding rules (MaxText-style) for the production meshes.

Model code never names mesh axes directly; it annotates arrays with *logical*
axes ("batch", "embed", "mlp", ...) via ``constrain``.  A ShardingRules table
maps logical axes to mesh axes (or None = replicated).  ``activate(mesh,
rules)`` installs the mapping; with no active mapping every annotation is a
no-op, so the same model code runs on a laptop CPU and on a 512-chip mesh.

Default rules (single-pod (data=16, model=16); multi-pod adds a leading
"pod" axis used for batch + an extra FSDP shard of the weights):

  batch        -> (pod,) data         DP
  seq_kv       -> data                SP: long-context KV/state sharding
  vocab/mlp/heads/q_heads -> model    TP
  embed        -> data (+pod)         FSDP (ZeRO-3-style weight sharding)
  experts      -> None                expert-sliced TP (see DESIGN.md)
  layers       -> None                (scan axis)
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


DEFAULT_RULES: dict[str, tuple[str, ...] | None] = {
    "batch": ("pod", "data"),
    "rows": ("data",),      # KRR sample dim (streaming Nystrom / pipeline)
    "models": ("model",),   # independent-work dim: h/lam candidates, tenants
    "seq": None,            # activation sequence dim (sharded only for SP configs)
    "seq_kv": ("data",),    # KV-cache / SSM-state sequence dim for long decode
    "embed": ("data",),     # FSDP axis for weights' d_model dim
    "embed_pod": ("pod", "data"),  # FSDP over pods too (>=12B params)
    "vocab": ("model",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "experts": None,
    "ssm_heads": ("model",),
    "ssm_state": None,
    "conv": None,
    "layers": None,
    "norm": None,
}


def _filter(axes: Optional[tuple[str, ...]], mesh: Mesh):
    if axes is None:
        return None
    present = tuple(a for a in axes if a in mesh.axis_names)
    if not present:
        return None
    return present if len(present) > 1 else present[0]


class Activation:
    def __init__(self, mesh: Mesh, rules: dict):
        self.mesh = mesh
        self.rules = rules

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """Logical axes -> PartitionSpec.

        Each mesh axis is used at most once (first dim wins), and when
        ``shape`` is provided, mesh axes that do not evenly divide a dim are
        dropped (replicated) — explicit input shardings require divisibility.
        """
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        used: set[str] = set()
        parts = []
        for i, ax in enumerate(logical_axes):
            rule = _filter(self.rules.get(ax, None) if ax else None, self.mesh)
            if rule is None:
                parts.append(None)
                continue
            axes = (rule,) if isinstance(rule, str) else tuple(rule)
            axes = tuple(a for a in axes if a not in used)
            if shape is not None and axes:
                factor = 1
                for a in axes:
                    factor *= sizes[a]
                while axes and shape[i] % factor != 0:
                    factor //= sizes[axes[-1]]
                    axes = axes[:-1]
            if not axes:
                parts.append(None)
                continue
            used.update(axes)
            parts.append(axes if len(axes) > 1 else axes[0])
        return P(*parts)

    def sharding(self, logical_axes: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))


def active() -> Optional[Activation]:
    return getattr(_state, "activation", None)


@contextlib.contextmanager
def activate(mesh: Mesh, rules: dict | None = None):
    """Install mesh + logical rules for model code run within the context."""
    prev = getattr(_state, "activation", None)
    _state.activation = Activation(mesh, dict(DEFAULT_RULES, **(rules or {})))
    try:
        yield _state.activation
    finally:
        _state.activation = prev


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint against the active rules; no-op otherwise."""
    act = active()
    if act is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, act.sharding(logical_axes, x.shape))


def param_sharding(axes_tree, params_tree=None):
    """Map a tree of logical-axis tuples to NamedShardings (active mesh)."""
    act = active()
    if act is None:
        raise RuntimeError("param_sharding requires an active mesh (activate())")
    return jax.tree.map(
        lambda axes: act.sharding(axes),
        axes_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            a is None or isinstance(a, str) for a in t),
    )
