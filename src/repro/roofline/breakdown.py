"""Per-op-kind HLO cost breakdown — the dry-run 'profiler'.

With no TPU wall-clock, the optimization loop reasons from the compiled
HLO: this module attributes cost_analysis-style bytes to op kinds (dot,
fusion kinds, convert, copy, collectives, ...) by walking the optimized HLO
text and sizing each instruction's result + operands where printed.  It is
an approximation of cost_analysis' per-op view (XLA's python API exposes
only module totals), good enough to rank "what dominates bytes accessed".
"""

from __future__ import annotations

import collections
import re

_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_OP_RE = re.compile(r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[\d,]*\][^\s]*)\s+"
                    r"([a-z][a-z0-9\-]*)[.\d]*\(")


def _bytes_of(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for x in dims.split(","):
            n *= int(x)
    return n * _DTYPE_BYTES.get(dtype, 4)


def result_bytes_by_op(hlo_text: str, top: int = 15) -> list[tuple[str, int, int]]:
    """[(op_kind, total_result_bytes, count)] sorted by bytes desc.

    Uses each instruction's RESULT size (operands are other instructions'
    results, so summing results once approximates unique-buffer traffic;
    actual reads are >= this).  While-loop bodies are counted once — pair
    with the unrolled depth-variants for absolute numbers; ratios within one
    module are directly comparable.
    """
    sizes: dict[str, int] = collections.defaultdict(int)
    counts: dict[str, int] = collections.defaultdict(int)
    in_fusion_body = False
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # fusion sub-computations are printed as separate blocks; their inner
        # ops are NOT HBM traffic (cost_analysis is fusion-aware) — skip them
        if stripped.endswith("{") and "(" in stripped:
            in_fusion_body = ("fused" in stripped.split("(")[0]
                              or "wrapped" in stripped.split("(")[0])
            continue
        if stripped == "}":
            in_fusion_body = False
            continue
        if in_fusion_body or " = " not in stripped:
            continue
        _, rhs = stripped.split(" = ", 1)
        m = _OP_RE.search(" = " + rhs)
        if m is None:
            continue
        op = m.group(1)
        head = rhs[:rhs.find(m.group(0)) if m.group(0) in rhs else None]
        shapes = _SHAPE_RE.findall(rhs[:m.start(1)])
        b = sum(_bytes_of(d, s) for d, s in shapes)
        # annotate fusions with their metadata op_name hint when available
        if op == "fusion":
            mm = re.search(r'op_name="[^"]*?([a-zA-Z0-9_\-]+)"', stripped)
            if mm:
                op = f"fusion:{mm.group(1).split('/')[-1]}"
        sizes[op] += b
        counts[op] += 1
    rows = sorted(((k, v, counts[k]) for k, v in sizes.items()),
                  key=lambda t: -t[1])
    return rows[:top]


def fmt(rows: list[tuple[str, int, int]]) -> str:
    out = [f"{'op':<40}{'result GB':>12}{'count':>8}"]
    for op, b, c in rows:
        out.append(f"{op:<40}{b/1e9:>12.3f}{c:>8}")
    return "\n".join(out)
