"""Three-term roofline analysis from compiled dry-run artifacts.

All quantities are PER-DEVICE (the compiled module is the post-GSPMD
per-device program), so each term is directly a per-chip step-time lower
bound in seconds:

  compute    = device_flops / peak_flops          (197 TFLOP/s bf16, v5e)
  memory     = device_bytes_accessed / hbm_bw     (819 GB/s)
  collective = device_collective_bytes / link_bw  (~50 GB/s/link ICI)

device_flops / bytes come from compiled.cost_analysis(); collective bytes are
NOT in cost_analysis, so we parse the optimized HLO and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  MODEL_FLOPS = 6·N·D (6·N_active·D for MoE) measures how
much of the compiled compute is "useful" (catches remat/redundancy waste).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

# TPU v5e hardware constants (from the brief)
PEAK_FLOPS = 197e12         # bf16 FLOP/s per chip
HBM_BW = 819e9              # bytes/s per chip
LINK_BW = 50e9              # bytes/s per ICI link


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """Per-device roofline constants the tile autotuner's analytic model
    feeds on (`repro.tuning.autotune`).

    These are deliberately coarse — the model only has to RANK a small
    pow2 tile ladder well enough that the measured top-k contains the true
    optimum; the micro-benchmark settles the final choice.  `step_overhead`
    is the fixed per-scan-step cost (dispatch + loop control + slab
    pad/reshape traffic) that punishes tiny tiles; `cache_bytes` is the
    working-set size past which a slab stops fitting the fast level of the
    memory hierarchy (VMEM on TPU, last-level cache per core complex on
    CPU) and the effective compute rate degrades.
    """

    name: str
    peak_flops: float       # sustained f32 FLOP/s
    mem_bw: float           # bytes/s to main memory
    step_overhead: float    # seconds of fixed cost per streamed tile
    cache_bytes: float      # fast-memory working-set budget

    def matmul_cost(self, precision: str = "fp32") -> float:
        """Relative time per NOMINAL matmul flop under a precision mode.

        The split-precision Gram contraction (`repro.core.precision`)
        replaces one fp32 syrk by 3 (bf16x2) or 6 (bf16x3) bf16 partial
        matmuls.  Whether that wins depends on the device's bf16:f32
        matmul-rate ratio, so the autotuner's roofline scales the matmul
        share of its flop count by this factor (MATMUL_COST): on an MXU
        (bf16 at 2x the f32 rate, plus fp32 inputs skipping the
        multi-pass f32 emulation) the split modes come out BELOW 1; on
        CPU/GPU-f32 the extra partial matmuls are a plain multiplier
        ABOVE 1 — which steers joint (tile, precision) resolution to
        fp32 there.
        """
        return MATMUL_COST.get(self.name, MATMUL_COST["cpu"]).get(
            precision, 1.0)


# Relative per-nominal-flop matmul cost by (device, precision); see
# DeviceSpec.matmul_cost.  TPU: bf16 MXU runs 2x the f32 rate, so bf16x2's
# 3 partials cost ~0.75 of fp32 with operand-reuse headroom (0.375 each
# relative flop) and bf16x3's 6 partials ~0.75 net of the skipped f32
# multi-passing.  CPU: no bf16 execution units — each partial is an f32
# GEMM plus split overhead, so the modes are ~words^2-ish slowdowns.
MATMUL_COST = {
    "tpu": {"fp32": 1.0, "bf16x2": 0.375, "bf16x3": 0.75},
    "gpu": {"fp32": 1.0, "bf16x2": 1.5, "bf16x3": 3.0},
    "cpu": {"fp32": 1.0, "bf16x2": 3.2, "bf16x3": 6.4},
}


DEVICE_SPECS = {
    # v5e: f32 MXU rate is half the bf16 peak; VMEM ~128 MB but a slab
    # should leave room for double buffering.
    "tpu": DeviceSpec("tpu", PEAK_FLOPS / 2, HBM_BW, 5e-6, 64e6),
    "gpu": DeviceSpec("gpu", 3e13, 1.0e12, 1e-5, 4e7),
    # CPU under XLA: a few AVX cores of GEMM, L2/L3-bounded slabs.
    "cpu": DeviceSpec("cpu", 1e11, 3e10, 1e-4, 8e6),
}


def device_spec(device_kind: str | None = None) -> DeviceSpec:
    """Map a jax device kind string onto the coarse spec table.

    `device_kind` defaults to the first local device; unknown kinds fall
    back to the CPU spec (the conservative model: small tiles, cheap
    memory assumptions never starve the measured ladder).
    """
    if device_kind is None:
        import jax
        device_kind = jax.devices()[0].device_kind
    kind = device_kind.lower()
    if "tpu" in kind:
        return DEVICE_SPECS["tpu"]
    if "gpu" in kind or "nvidia" in kind or "cuda" in kind:
        return DEVICE_SPECS["gpu"]
    return DEVICE_SPECS["cpu"]

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind OPERAND bytes, summed over the module.

    Optimized HLO prints only the result type, so operand bytes are derived
    from it: all-gather concatenates group_size operands (operand = result /
    g); reduce-scatter consumes the pre-scatter operand (result * g);
    all-reduce / all-to-all / collective-permute are size-preserving.
    Tuple-shaped collectives contribute every element.
    """
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    op_re = re.compile(
        r"\b(" + "|".join(_COLLECTIVES) + r")(-start|-done)?[.\d]*\(")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if " = " not in stripped:
            continue
        _, rhs = stripped.split(" = ", 1)
        rhs = rhs.split("metadata=", 1)[0]  # op names recur in metadata
        m = op_re.search(rhs)
        if m is None:
            continue
        op, suffix = m.group(1), m.group(2)
        if suffix == "-done":
            continue  # counted at the matching -start
        # result type(s) = everything before the op token; tuple-typed
        # combined collectives reduce every element, so sum them all
        result_shapes = _SHAPE_RE.findall(rhs[:m.start()])
        if suffix == "-start" and len(result_shapes) >= 2:
            result_shapes = result_shapes[1:]  # (operand, results...)
        result = sum(_shape_bytes(d, s) for d, s in result_shapes)
        g = _group_size(stripped)
        if op == "all-gather":
            result = result // max(g, 1)
        elif op == "reduce-scatter":
            result = result * g
        out[op] += result
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    device_flops: float
    device_bytes: float
    device_collective_bytes: float
    collective_breakdown: dict
    model_flops_global: float
    memory_per_device: Optional[float] = None  # bytes, from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.device_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.device_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.device_collective_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.device_flops * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the step ran at the
        bound max(terms): useful_model_flops_time / achievable_step_time."""
        t_model = (self.model_flops_global / self.chips) / PEAK_FLOPS
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_model / t_bound if t_bound else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops(cfg, shape) -> float:
    """6·N·D with N = active params (MoE) and D = tokens processed.

    decode shapes process global_batch tokens per step (one new token each).
    """
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens  # forward only
    return 2.0 * n_active * shape.global_batch  # decode: 1 token per seq


def cost_dict(compiled) -> dict:
    """compiled.cost_analysis() normalized to ONE flat dict.

    The return type changed across jax versions: older releases return a
    list with one dict per executable (always length 1 for a jit'd program),
    newer ones return the dict directly, and some backends return None.
    Every consumer here wants the flat {"flops": ..., "bytes accessed": ...}
    mapping, so normalize once instead of hand-rolling `.get` on a list
    (the exact crash the seed's dry-run/lowering tests inherited).
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def achieved_throughput(cost: dict, seconds: float) -> dict:
    """Achieved GFLOP/s + bytes-moved columns from a `cost_dict` result.

    Divides the compiled program's counted flops / bytes by a MEASURED
    wall-clock, giving the attribution columns bench_pipeline records next
    to each stage's seconds: compute-bound stages show gflops_per_s near
    the device ceiling, bandwidth-bound ones show gbytes_per_s near the
    memory ceiling instead.  Zero/missing counters (backends without
    cost_analysis) degrade to zeros, never raise.
    """
    flops = float(cost.get("flops", 0.0) or 0.0)
    moved = float(cost.get("bytes accessed", 0.0) or 0.0)
    s = max(float(seconds), 1e-12)
    return {
        "gflops": flops / 1e9,
        "gflops_per_s": flops / 1e9 / s,
        "gbytes_moved": moved / 1e9,
        "gbytes_per_s": moved / 1e9 / s,
    }


def analyze(arch: str, shape, cfg, mesh_name: str, chips: int,
            compiled, hlo_text: str) -> Roofline:
    cost = cost_dict(compiled)
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = float(getattr(ma, "temp_size_in_bytes", 0) +
                        getattr(ma, "argument_size_in_bytes", 0) +
                        getattr(ma, "output_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        device_flops=flops, device_bytes=bytes_accessed,
        device_collective_bytes=float(sum(coll.values())),
        collective_breakdown=coll,
        model_flops_global=model_flops(cfg, shape),
        memory_per_device=mem,
    )


def save(r: Roofline, path: str) -> None:
    with open(path, "w") as f:
        json.dump(r.to_dict(), f, indent=1)


def fmt_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':<24}{'shape':<13}{'mesh':<7}{'t_comp(s)':>10}"
           f"{'t_mem(s)':>10}{'t_coll(s)':>10}{'bound':>11}"
           f"{'useful':>8}{'roofl%':>8}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:<24}{r['shape']:<13}{r['mesh']:<7}"
            f"{r['t_compute']:>10.3e}{r['t_memory']:>10.3e}"
            f"{r['t_collective']:>10.3e}{r['bottleneck']:>11}"
            f"{r['useful_flops_ratio']:>8.2f}"
            f"{100*r['roofline_fraction']:>7.1f}%")
    return "\n".join(lines)
