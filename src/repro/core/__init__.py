"""Core library: the paper's SA leverage-score estimator and the KRR stack.

Public API:
  kernels    — stationary kernels + spectral densities (Matern, Gaussian)
  krr        — exact KRR / exact leverage (the O(n^3) oracle)
  leverage   — SA approximation (Eq. 6): closed forms, quadrature, grid path
  kde        — density estimation substrates (binned-FFT linear time, direct)
  nystrom    — Nystrom KRR with importance-sampled landmarks
  rls        — algebraic baselines (uniform / Recursive-RLS / BLESS)
  quadrature — vectorized radial quadrature for Eq. (6)
  polylog    — -Li_s(-x) for the Gaussian closed form
  sampling   — with-replacement / Gumbel top-k landmark sampling
  streaming  — unified tile-reduction engine (plain / compensated two-float
               accumulation, row-slab tiling, mesh psum plumbing)
"""

from repro.core import (  # noqa: F401
    kde,
    kernels,
    krr,
    leverage,
    nystrom,
    polylog,
    quadrature,
    rls,
    sampling,
    streaming,
)
