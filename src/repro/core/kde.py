"""Kernel density estimation — the Õ(n) density substrate of Algorithm 1.

The paper's complexity argument leans on tree / hashing KDE (ASKIT, HBE).
Those are pointer-chasing, data-dependent structures with no TPU mapping, so
(DESIGN.md §3) we provide two TPU-native estimators with the same o(1)
relative-error contract (paper Lemma 14 shows KDE error enters the leverage
error multiplicatively, so a sub-optimal KDE rate suffices):

  * ``kde_binned``  — linear-time gridded KDE for d <= 3: cloud-in-cell
    scatter of the n points onto a regular grid (O(n 2^d)), FFT convolution
    with the exactly-evaluated Gaussian window (O(g^d log g)), multilinear
    gather back at the n query points.  Binning error is O(delta^2 / h^2).

  * ``kde_direct``  — O(n m d) tiled evaluation, MXU-dominated through the
    ||x-y||^2 = ||x||^2+||y||^2-2x.y^T expansion.  This is the reference
    oracle; on TPU the Pallas kernel `repro.kernels.kde` computes the same
    sum in VMEM tiles (use it for d > 3 or small n where grids are wasteful).

Both return *densities* (integrate to 1); bandwidth defaults to Scott's rule.
"""

from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


def scott_bandwidth(x: Array) -> Array:
    """Scott's rule h = sigma_avg * n^(-1/(d+4)) (scalar bandwidth)."""
    n, d = x.shape
    sigma = jnp.mean(jnp.std(x, axis=0))
    return sigma * n ** (-1.0 / (d + 4))


def gaussian_norm(d: int, h: float | Array) -> Array:
    return (2.0 * math.pi) ** (d / 2.0) * jnp.asarray(h) ** d


def kde_direct(query: Array, data: Array, h: float | Array) -> Array:
    """Exact Gaussian KDE, O(n_query * n_data * d)."""
    q2 = jnp.sum(query * query, axis=-1)[:, None]
    d2 = jnp.sum(data * data, axis=-1)[None, :]
    sq = jnp.maximum(q2 + d2 - 2.0 * query @ data.T, 0.0)
    kern = jnp.exp(-sq / (2.0 * jnp.asarray(h) ** 2))
    return jnp.sum(kern, axis=1) / (data.shape[0] * gaussian_norm(data.shape[1], h))


@functools.partial(jax.jit, static_argnames=("grid_size", "d"))
def _binned_grid(data: Array, lo: Array, spacing: Array, grid_size: int, d: int) -> Array:
    """Cloud-in-cell scatter of points onto a d-dim regular grid."""
    pos = (data - lo[None, :]) / spacing[None, :]            # (n, d) fractional index
    base = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, grid_size - 2)
    frac = pos - base                                          # in [0, 1)
    grid = jnp.zeros((grid_size,) * d, dtype=data.dtype)
    for corner in range(2 ** d):
        offs = jnp.array([(corner >> k) & 1 for k in range(d)], dtype=jnp.int32)
        idx = base + offs[None, :]
        w = jnp.prod(jnp.where(offs[None, :] == 1, frac, 1.0 - frac), axis=1)
        grid = grid.at[tuple(idx[:, k] for k in range(d))].add(w)
    return grid


@functools.partial(jax.jit, static_argnames=("grid_size", "d"))
def _fft_smooth(grid: Array, spacing: Array, h: Array, grid_size: int, d: int) -> Array:
    """Convolve the count grid with the exact Gaussian window via padded FFT."""
    pad = 2 * grid_size
    axes_freq = []
    for k in range(d):
        # Centered offsets on the padded circle: 0, 1, ..., pad/2, -(pad/2-1), ..., -1
        offs = jnp.arange(pad)
        offs = jnp.where(offs > pad // 2, offs - pad, offs).astype(grid.dtype)
        axes_freq.append(offs * spacing[k])
    # Separable Gaussian: product over dims of exp(-x_k^2 / (2h^2)).
    window = jnp.ones((pad,) * d, dtype=grid.dtype)
    for k in range(d):
        shape = [1] * d
        shape[k] = pad
        window = window * jnp.exp(-(axes_freq[k] ** 2) / (2.0 * h ** 2)).reshape(shape)
    padded = jnp.zeros((pad,) * d, dtype=grid.dtype)
    padded = padded.at[tuple(slice(0, grid_size) for _ in range(d))].set(grid)
    out = jnp.fft.irfftn(
        jnp.fft.rfftn(padded) * jnp.fft.rfftn(window), s=(pad,) * d
    )
    return out[tuple(slice(0, grid_size) for _ in range(d))]


def kde_binned(
    query: Array,
    data: Array,
    h: float | Array,
    grid_size: int = 256,
) -> Array:
    """Linear-time binned Gaussian KDE for d <= 3 (see module docstring)."""
    n, d = data.shape
    if d > 3:
        raise ValueError("kde_binned supports d <= 3; use kde_direct / Pallas kde")
    h = jnp.asarray(h, dtype=data.dtype)
    lo = jnp.minimum(jnp.min(data, axis=0), jnp.min(query, axis=0)) - 4.0 * h
    hi = jnp.maximum(jnp.max(data, axis=0), jnp.max(query, axis=0)) + 4.0 * h
    spacing = (hi - lo) / (grid_size - 1)
    grid = _binned_grid(data, lo, spacing, grid_size, d)
    smooth = _fft_smooth(grid, spacing, h, grid_size, d)
    # Multilinear gather at the query points.
    pos = (query - lo[None, :]) / spacing[None, :]
    base = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, grid_size - 2)
    frac = pos - base
    out = jnp.zeros(query.shape[0], dtype=data.dtype)
    for corner in range(2 ** d):
        offs = jnp.array([(corner >> k) & 1 for k in range(d)], dtype=jnp.int32)
        idx = base + offs[None, :]
        w = jnp.prod(jnp.where(offs[None, :] == 1, frac, 1.0 - frac), axis=1)
        out = out + w * smooth[tuple(idx[:, k] for k in range(d))]
    return jnp.maximum(out, 0.0) / (n * gaussian_norm(d, h))


def estimate_densities(
    x: Array,
    h: float | Array | None = None,
    method: str = "auto",
    grid_size: int | None = None,
) -> Array:
    """Self-density p_hat(x_i) for all sample points (leave-self-in, as KDE).

    method: 'binned' | 'direct' | 'auto' (binned when d <= 3 else direct).
    grid_size: binned-KDE resolution per axis; default scales with d so the
    total grid stays ~1e6 cells (1024 / 512 / 96 for d = 1 / 2 / 3) — Scott
    bandwidths are several bins wide at these resolutions (verified in
    tests/test_kde.py), so accuracy is unchanged while the d=3 FFT drops from
    256^3 = 16.8M cells to < 1M.
    """
    if h is None:
        h = scott_bandwidth(x)
    d = x.shape[1]
    if grid_size is None:
        grid_size = {1: 1024, 2: 512, 3: 96}.get(d, 96)
    if method == "auto":
        method = "binned" if d <= 3 else "direct"
    if method == "binned":
        return kde_binned(x, x, h, grid_size=grid_size)
    if method == "direct":
        return kde_direct(x, x, h)
    raise ValueError(f"unknown KDE method {method!r}")
