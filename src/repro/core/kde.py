"""Kernel density estimation — the Õ(n) density substrate of Algorithm 1.

The paper's complexity argument leans on tree / hashing KDE (ASKIT, HBE).
Those are pointer-chasing, data-dependent structures with no TPU mapping, so
(DESIGN.md §3) we provide two TPU-native estimators with the same o(1)
relative-error contract (paper Lemma 14 shows KDE error enters the leverage
error multiplicatively, so a sub-optimal KDE rate suffices):

  * ``kde_binned``  — linear-time gridded KDE for d <= 3: cloud-in-cell
    scatter of the n points onto a regular grid (O(n 2^d)), FFT convolution
    with the exactly-evaluated Gaussian window (O(g^d log g)), multilinear
    gather back at the n query points.  Binning error is O(delta^2 / h^2).

  * ``kde_direct``  — O(n m d) tiled evaluation, MXU-dominated through the
    ||x-y||^2 = ||x||^2+||y||^2-2x.y^T expansion.  This is the reference
    oracle; on TPU the Pallas kernel `repro.kernels.kde` computes the same
    sum in VMEM tiles (use it for d > 3 or small n where grids are wasteful).

The deposit stage of ``kde_binned`` streams through ``scatter_cic``: ONE
windowed scatter-add per row tile deposits each point's (2,)^d stencil in a
single update (~10x faster than the historical one-scatter-per-corner form
on CPU — the scatter loop runs n times, not n 2^d times), with a `lax.scan`
over row tiles bounding transient memory at O(tile 2^d).  On TPU the same
deposit runs as the Pallas `repro.kernels.kde_binned` kernel (VMEM-resident
grid); `repro.kernels.dispatch.binned_scatter` routes between them, and
`repro.core.distributed.kde_binned_sharded` shards the row stream over the
mesh with one grid psum.

Both estimators return *densities* (integrate to 1); bandwidth defaults to
Scott's rule.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import accstate, streaming

Array = jax.Array


def scott_bandwidth(x: Array) -> Array:
    """Scott's rule h = sigma_avg * n^(-1/(d+4)) (scalar bandwidth)."""
    n, d = x.shape
    sigma = jnp.mean(jnp.std(x, axis=0))
    return sigma * n ** (-1.0 / (d + 4))


def gaussian_norm(d: int, h: float | Array) -> Array:
    return (2.0 * math.pi) ** (d / 2.0) * jnp.asarray(h) ** d


def kde_direct(query: Array, data: Array, h: float | Array) -> Array:
    """Exact Gaussian KDE, O(n_query * n_data * d)."""
    q2 = jnp.sum(query * query, axis=-1)[:, None]
    d2 = jnp.sum(data * data, axis=-1)[None, :]
    sq = jnp.maximum(q2 + d2 - 2.0 * query @ data.T, 0.0)
    kern = jnp.exp(-sq / (2.0 * jnp.asarray(h) ** 2))
    return jnp.sum(kern, axis=1) / (data.shape[0] * gaussian_norm(data.shape[1], h))


# ------------------------------------------------------------ CIC deposit --

def cic_prep(points: Array, lo: Array, spacing: Array,
             grid_size: int) -> tuple[Array, Array]:
    """Fractional lattice coordinates -> (base cell (n, d) int32, frac (n, d)).

    Base cells are clipped to [0, grid_size - 2] so the (2,)^d stencil stays
    in bounds, and the fractional offset is clamped to [0, 1] to match: a
    point outside the grid gets the BOUNDARY cell's value (gather) and
    deposits all its mass in the boundary cells (scatter).  Without the frac
    clamp an out-of-range point keeps pos - base > 1 (or < 0), which turns
    the multilinear stencil into linear *extrapolation* — negative deposit
    weights and out-of-support query densities the `maximum(out, 0)` floor
    only partially masks.  With the +-4h grid margins of `kde_binned` both
    clips are no-ops for in-range data, so fitted-path numbers are
    unchanged; serving-time queries beyond the frozen grid bounds are where
    the clamp is load-bearing (tests/test_kde.py locks both directions).
    """
    pos = (points - lo[None, :]) / spacing[None, :]
    base = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, grid_size - 2)
    return base, jnp.clip(pos - base, 0.0, 1.0)


def _cic_stencil(frac: Array, weights: Array | None = None) -> Array:
    """(n, d) fracs -> (n, 2, ..., 2) multilinear deposit stencil."""
    n, d = frac.shape
    upd = None
    for k in range(d):
        wk = jnp.stack([1.0 - frac[:, k], frac[:, k]], axis=1)  # (n, 2)
        wk = wk.reshape((n,) + (1,) * k + (2,))
        upd = wk if upd is None else upd[..., None] * wk
    if weights is not None:
        upd = upd * weights.reshape((n,) + (1,) * d)
    return upd


@functools.partial(jax.jit, static_argnames=("grid_size", "tile",
                                             "accumulator", "finalize",
                                             "method", "return_state"))
def scatter_cic(points: Array, lo: Array, spacing: Array, grid_size: int,
                *, weights: Array | None = None,
                tile: int | None = None,
                accumulator: str = "plain", finalize: bool = True,
                method: str = "window", init_state: Any = None,
                return_state: bool = False):
    """Cloud-in-cell deposit of (weighted) points onto a (grid_size,)^d grid.

    ``method="window"`` (default): each point's whole (2,)^d stencil lands
    in ONE windowed scatter-add update (update_window_dims), so the serial
    scatter loop runs n times instead of n 2^d — on CPU this is the
    difference between the deposit dominating the KDE and disappearing into
    the FFT's shadow.  This is the historical path and stays bit-equal to
    the pre-engine loops.

    ``method="segment"``: the sort-by-cell + segment-reduce formulation
    (the XLA twin of the Pallas `repro.kernels.kde_binned` kernel): flatten
    every corner to its linear cell id, sort the corner stream, and
    `segment_sum` into the flat grid — duplicate-cell collisions reduce in
    vector registers instead of serializing the scatter.  Matches "window"
    to reduction-order tolerance (rtol 1e-5 locked in
    tests/test_kde_binned_kernel.py), not bitwise.

    With `tile` set, rows stream through the engine
    (`streaming.tile_reduce`: zero-pad + zero weights on the ragged tail,
    O(tile 2^d) transient stencil) with the deposit as the engine's
    `combine`.  ``accumulator="compensated"`` carries the grid as a
    two-float (hi, lo) pair — each tile's deposit is materialized against a
    zero grid and folded in with an error-free two-sum; ``finalize=False``
    returns the accumulator state for the mesh psum in
    `core.distributed.kde_binned_sharded_multi`.  ``init_state=`` deposits
    INTO a previously returned raw state instead of a zero grid (the
    incremental absorb of `DepositState`); ``return_state=True`` returns
    the raw state.
    """
    n, d = points.shape
    if method not in ("window", "segment"):
        raise ValueError(f"unknown scatter method {method!r}; "
                         "pick 'window' or 'segment'")
    dnums = jax.lax.ScatterDimensionNumbers(
        update_window_dims=tuple(range(1, d + 1)),
        inserted_window_dims=(),
        scatter_dims_to_operand_dims=tuple(range(d)))

    def combine_window(grid, pw):
        pts, w = pw
        base, frac = cic_prep(pts, lo, spacing, grid_size)
        return jax.lax.scatter_add(grid, base, _cic_stencil(frac, w), dnums)

    def combine_segment(grid, pw):
        pts, w = pw
        base, frac = cic_prep(pts, lo, spacing, grid_size)
        t = pts.shape[0]
        # linear cell ids of ALL 2^d corners: (t, 2^d), row-major lattice
        ids = jnp.zeros((t,), jnp.int32)
        for k in range(d):
            ids = ids * grid_size + base[:, k]
        # stencil axis k (lattice dim k) is bit d-1-k of the flat corner
        # index, and dim k's linear stride is grid_size^(d-1-k) — so the
        # offset is c's bits read as base-grid_size digits
        offs = jnp.array([sum(((c >> j) & 1) * grid_size ** j
                              for j in range(d)) for c in range(2 ** d)],
                         jnp.int32)
        flat_ids = (ids[:, None] + offs[None, :]).reshape(-1)
        vals = _cic_stencil(frac, w).reshape(-1)
        order = jnp.argsort(flat_ids)
        seg = jax.ops.segment_sum(vals[order], flat_ids[order],
                                  num_segments=grid_size ** d,
                                  indices_are_sorted=True)
        return grid + seg.reshape(grid.shape).astype(grid.dtype)

    combine = combine_window if method == "window" else combine_segment

    acc = streaming.get(accumulator)
    init = jnp.zeros((grid_size,) * d, dtype=points.dtype)
    if tile is None or tile >= n:
        # one-shot deposit: weights=None skips the stencil multiply entirely
        start = acc.init(init) if init_state is None else init_state
        state = acc.add(start, (points, weights), combine)
        if return_state:
            return state
        return acc.finalize(state) if finalize else state
    w = jnp.ones((n,), points.dtype) if weights is None else weights
    return streaming.tile_reduce(
        lambda pts, wt: (pts, wt), points, (w,), tile=tile, init=init,
        combine=combine, accumulator=accumulator, pad="zero",
        finalize=finalize, init_state=init_state, return_state=return_state)


@functools.partial(jax.jit, static_argnames=("grid_size",))
def gather_cic(grid: Array, query: Array, lo: Array, spacing: Array,
               grid_size: int) -> Array:
    """Multilinear (CIC-adjoint) interpolation of `grid` at the queries."""
    d = query.shape[1]
    base, frac = cic_prep(query, lo, spacing, grid_size)
    out = jnp.zeros(query.shape[0], dtype=grid.dtype)
    for corner in range(2 ** d):
        offs = jnp.array([(corner >> k) & 1 for k in range(d)],
                         dtype=jnp.int32)
        idx = base + offs[None, :]
        w = jnp.prod(jnp.where(offs[None, :] == 1, frac, 1.0 - frac), axis=1)
        out = out + w * grid[tuple(idx[:, k] for k in range(d))]
    return out


@functools.partial(jax.jit, static_argnames=("grid_size", "d"))
def _fft_smooth(grid: Array, spacing: Array, h: Array, grid_size: int, d: int) -> Array:
    """Convolve the count grid with the exact Gaussian window via padded FFT."""
    pad = 2 * grid_size
    axes_freq = []
    for k in range(d):
        # Centered offsets on the padded circle: 0, 1, ..., pad/2, -(pad/2-1), ..., -1
        offs = jnp.arange(pad)
        offs = jnp.where(offs > pad // 2, offs - pad, offs).astype(grid.dtype)
        axes_freq.append(offs * spacing[k])
    # Separable Gaussian: product over dims of exp(-x_k^2 / (2h^2)).
    window = jnp.ones((pad,) * d, dtype=grid.dtype)
    for k in range(d):
        shape = [1] * d
        shape[k] = pad
        window = window * jnp.exp(-(axes_freq[k] ** 2) / (2.0 * h ** 2)).reshape(shape)
    padded = jnp.zeros((pad,) * d, dtype=grid.dtype)
    padded = padded.at[tuple(slice(0, grid_size) for _ in range(d))].set(grid)
    out = jnp.fft.irfftn(
        jnp.fft.rfftn(padded) * jnp.fft.rfftn(window), s=(pad,) * d
    )
    return out[tuple(slice(0, grid_size) for _ in range(d))]


def binned_bounds(query: Array, data: Array, h: Array) -> tuple[Array, Array]:
    """Grid bounds with +-4h margins (shared by local and sharded paths)."""
    lo = jnp.minimum(jnp.min(data, axis=0), jnp.min(query, axis=0)) - 4.0 * h
    hi = jnp.maximum(jnp.max(data, axis=0), jnp.max(query, axis=0)) + 4.0 * h
    return lo, hi


def smooth_gather(grid: Array, query: Array, h: Array, *, lo: Array,
                  spacing: Array, grid_size: int, d: int, n: Array) -> Array:
    """One bandwidth's densities from a deposited count grid: FFT smooth ->
    CIC gather -> clamp + 1/(n * (2 pi h^2)^{d/2}) normalization.

    THE per-h op sequence of every binned-KDE consumer (`kde_binned_multi`,
    `densities_from_state`, `distributed.kde_binned_sharded_multi`) — kept
    in one place so bit-parity claims across those paths reduce to "same
    deposit, same grid".  Traced-h safe: `h` may be a device scalar sliced
    from a shard_map input (the model-axis-sharded bandwidth sweep), and
    every h op is jnp, so the traced program is identical regardless of
    which chip holds which bandwidth — the foundation of the 2D-vs-1D-mesh
    per-h bit equality.  `n` is the (possibly fractional, decayed)
    normalizing row count.
    """
    smooth = _fft_smooth(grid, spacing, jnp.asarray(h, grid.dtype),
                         grid_size, d)
    out = gather_cic(smooth, query, lo, spacing, grid_size)
    return jnp.maximum(out, 0.0) / (n * gaussian_norm(d, h))


# ------------------------------------------------------------ deposit state --

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DepositState:
    """First-class CIC deposit state: a mergeable count grid + its geometry.

    The deposit is bandwidth-independent on a fixed grid geometry (the
    CalibrateStage shared-deposit contract), so ONE absorbed state serves
    every bandwidth: `densities_from_state` re-runs only the O(g^d log g)
    FFT smooth + gather per query/h.  Monoid ops mirror `NormalEqState`:
    `deposit_init` / `deposit_absorb` / `deposit_merge` / `deposit_decay`
    / `deposit_finalize`.  Points outside the frozen bounds clamp to the
    boundary cells (`cic_prep`) — re-init with wider bounds if the stream
    drifts past the fitted support.
    """

    acc: accstate.AccState      # value = (grid_size,)^d grid strategy state
    lo: Array                   # (d,) grid origin
    spacing: Array              # (d,) cell size
    grid_size: int = 0          # static: cells per axis
    tile: int | None = None     # static: deposit slab rows
    accumulator: str = "plain"  # static
    method: str = "window"      # static: scatter formulation
    backend: str | None = None  # static: dispatch backend

    def tree_flatten(self):
        return ((self.acc, self.lo, self.spacing),
                (self.grid_size, self.tile, self.accumulator, self.method,
                 self.backend))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        acc, lo, spacing = leaves
        grid_size, tile, accumulator, method, backend = aux
        return cls(acc=acc, lo=lo, spacing=spacing, grid_size=grid_size,
                   tile=tile, accumulator=accumulator, method=method,
                   backend=backend)


def deposit_init(lo: Array, hi: Array, grid_size: int, *,
                 dtype=jnp.float32, tile: int | None = None,
                 accumulator: str = "plain", method: str = "window",
                 backend: str | None = None) -> DepositState:
    """Zero deposit state on the [lo, hi] grid (kde_binned_multi geometry:
    spacing = (hi - lo) / (grid_size - 1))."""
    lo = jnp.asarray(lo, dtype)
    d = lo.shape[0]
    spacing = (jnp.asarray(hi, dtype) - lo) / (grid_size - 1)
    zeros = jnp.zeros((grid_size,) * d, dtype)
    return DepositState(acc=accstate.init(accumulator, zeros), lo=lo,
                        spacing=spacing, grid_size=grid_size, tile=tile,
                        accumulator=accumulator, method=method,
                        backend=backend)


def deposit_absorb(state: DepositState, points: Array,
                   weights: Array | None = None) -> DepositState:
    """Deposit a new point chunk into the grid — O(chunk * 2^d).

    Routes through `kernels.dispatch.binned_scatter` so the backend knob
    is honored; on the XLA path the deposit lands directly in the carried
    state (a tile-aligned chain of absorbs is bit-equal to the one-shot
    deposit), on Pallas the chunk grid is built fresh and merged.
    """
    from repro.kernels import dispatch  # deferred: core -> kernels

    n = points.shape[0]
    value = dispatch.binned_scatter(
        points, state.lo, state.spacing, state.grid_size,
        backend=state.backend, weights=weights, tile=state.tile,
        accumulator=state.accumulator, method=state.method,
        init_state=state.acc.value, return_state=True)
    steps = 1 if state.tile is None else -(-n // min(state.tile, max(n, 1)))
    acc = accstate.AccState(value=value, rows=state.acc.rows + n,
                            steps=state.acc.steps + steps,
                            spec=state.acc.spec)
    return dataclasses.replace(state, acc=acc)


def deposit_merge(a: DepositState, b: DepositState) -> DepositState:
    """Combine two deposits built on the SAME grid geometry (caller's
    contract, like `nystrom.normal_eq_merge`)."""
    return dataclasses.replace(a, acc=accstate.merge(a.acc, b.acc))


def deposit_decay(state: DepositState, gamma: float) -> DepositState:
    """Exponential forgetting of the count grid ((hi, lo) domain)."""
    return dataclasses.replace(state, acc=accstate.decay(state.acc, gamma))


def deposit_finalize(state: DepositState) -> Array:
    """Collapse to the (grid_size,)^d count grid."""
    return accstate.finalize(state.acc)


def densities_from_state(state: DepositState, query: Array,
                         h: float | Array) -> Array:
    """Densities at `query` from an absorbed deposit — the online twin of
    `kde_binned` (normalized by the state's effective, possibly decayed,
    row count)."""
    d = state.lo.shape[0]
    grid = deposit_finalize(state)
    h = jnp.asarray(h, grid.dtype)
    n_eff = jnp.maximum(state.acc.rows.astype(grid.dtype), 1.0)
    return smooth_gather(grid, query, h, lo=state.lo, spacing=state.spacing,
                         grid_size=state.grid_size, d=d, n=n_eff)


def kde_binned(
    query: Array,
    data: Array,
    h: float | Array,
    grid_size: int = 256,
    *,
    lo: Array | None = None,
    hi: Array | None = None,
    backend: str | None = None,
    tile: int | None = None,
    interpret: bool | None = None,
    accumulator: str = "plain",
) -> Array:
    """Linear-time binned Gaussian KDE for d <= 3 (see module docstring).

    backend/tile/interpret/accumulator configure the deposit stage only (see
    `repro.kernels.dispatch.binned_scatter`): 'pallas' runs the tiled VMEM
    scatter kernel, 'xla' (CPU/GPU default) the windowed streaming scatter
    with `tile` rows per engine slab.  lo/hi pin the grid bounds (default:
    data bounds +-4h) — pass the bounds of a WIDER bandwidth to evaluate
    several h on one shared grid (`kde_binned_multi` parity).
    """
    return kde_binned_multi(query, data, (h,), grid_size, lo=lo, hi=hi,
                            backend=backend, tile=tile, interpret=interpret,
                            accumulator=accumulator)[0]


def kde_binned_multi(
    query: Array,
    data: Array,
    hs: "Sequence[float | Array]",
    grid_size: int = 256,
    *,
    lo: Array | None = None,
    hi: Array | None = None,
    backend: str | None = None,
    tile: int | None = None,
    interpret: bool | None = None,
    accumulator: str = "plain",
) -> Array:
    """Binned KDE for a bandwidth GRID at one deposit cost: (H, n) densities.

    The O(n 2^d) CIC deposit is bandwidth-independent once the grid geometry
    is fixed, so a bandwidth sweep scatters the points ONCE and only re-runs
    the O(g^d log g) FFT smooth + O(n 2^d) gather per h — the KDE half of
    `pipeline.stages.CalibrateStage`'s shared-expensive-work contract.  Grid
    bounds default to +-4·max(hs) margins (every candidate's support fits);
    row i is bit-equal to `kde_binned(query, data, hs[i], lo=lo, hi=hi)` on
    those bounds (same deposit, same per-h ops).
    """
    n, d = data.shape
    if d > 3:
        raise ValueError("kde_binned supports d <= 3; use kde_direct / Pallas kde")
    hs = [jnp.asarray(h, dtype=data.dtype) for h in hs]
    if (lo is None) != (hi is None):
        raise ValueError("pass both lo and hi to pin the grid bounds, or "
                         "neither for the +-4*max(h) data bounds")
    if lo is None:
        h_max = hs[0]
        for h in hs[1:]:
            h_max = jnp.maximum(h_max, h)
        lo, hi = binned_bounds(query, data, h_max)
    spacing = (hi - lo) / (grid_size - 1)
    from repro.kernels import dispatch  # deferred: core -> kernels at call time
    grid = dispatch.binned_scatter(data, lo, spacing, grid_size,
                                   backend=backend, tile=tile,
                                   interpret=interpret,
                                   accumulator=accumulator)
    return jnp.stack([smooth_gather(grid, query, h, lo=lo, spacing=spacing,
                                    grid_size=grid_size, d=d, n=n)
                      for h in hs])


def default_grid_size(d: int) -> int:
    """Binned-KDE resolution per axis: total grid stays ~1e6 cells."""
    return {1: 1024, 2: 512, 3: 96}.get(d, 96)


def estimate_densities(
    x: Array,
    h: float | Array | None = None,
    method: str = "auto",
    grid_size: int | None = None,
    *,
    backend: str | None = None,
    tile: int | None = None,
    accumulator: str = "plain",
) -> Array:
    """Self-density p_hat(x_i) for all sample points (leave-self-in, as KDE).

    method: 'binned' | 'direct' | 'auto' (binned when d <= 3 else direct).
    grid_size: binned-KDE resolution per axis; default scales with d so the
    total grid stays ~1e6 cells (1024 / 512 / 96 for d = 1 / 2 / 3) — Scott
    bandwidths are several bins wide at these resolutions (verified in
    tests/test_kde.py), so accuracy is unchanged while the d=3 FFT drops from
    256^3 = 16.8M cells to < 1M.
    backend/tile: deposit-stage execution knobs (binned path only).
    """
    if h is None:
        h = scott_bandwidth(x)
    d = x.shape[1]
    if grid_size is None:
        grid_size = default_grid_size(d)
    if method == "auto":
        method = "binned" if d <= 3 else "direct"
    if method == "binned":
        return kde_binned(x, x, h, grid_size=grid_size, backend=backend,
                          tile=tile, accumulator=accumulator)
    if method == "direct":
        return kde_direct(x, x, h)
    raise ValueError(f"unknown KDE method {method!r}")
