"""Split-precision multi-word dot products (Ozaki-style bf16 block split).

The streaming Gram pass spends almost all of its flops in one syrk-shaped
contraction per tile, ``G += K^T K``.  On MXU-class hardware that contraction
runs at full rate only for bf16 operands; fp32 inputs fall back to a slower
pass.  The classic fix (Ozaki et al.; Henry/Tang/Heinecke for bf16) is to
split each fp32 operand into a few bf16 *words* by truncate-and-subtract

    w0 = rint(r / d) * d,  r -= w0,  d /= 256,  w1 = rint(r / d) * d, ...

with ``d`` a power-of-two grid step shared by every element of a contraction
fiber (2^(floor(log2 amax) - 7), amax over the contracted axis), and rebuild
the fp32 product from the pairwise cross terms.  The FIXED-POINT grid — not
a per-element Dekker truncation — is what lands the result *below* the
plain-fp32 rounding floor:

* each slice is an integer multiple of its grid step in [-2^8, 2^8], so the
  bf16 cast is exact (<= 8 significand bits + exponent), and
* a slice x slice partial product is an integer multiple of the two steps'
  product, <= 2^16 steps — so an fp32-accumulated partial matmul over <= 256
  contracted elements stays <= 2^24 steps and rounds NOTHING, in any
  summation order.  (Longer contractions round integers, creeping back at
  ~2^-19 relative — still far below an fp32 gemm.  A per-element relative
  split does not get this: its partial gemms round like an fp32 dot and,
  empirically, with a systematic bias that survives compensated cross-tile
  summation.)

``bf16x3`` therefore carries only the dropped p+q >= 3 cross terms
(~2^-24 of the grid scale) — below a single fp32 dot's accumulation error.
``bf16x2`` drops the third word: ~2x fewer partial matmuls at a ~2^-16
relative floor — faster, but *less* accurate than fp32, so the solver widens
its truncation floor via :data:`EPS_SCALE`.  Exactness of the partials also
makes the Pallas kernel and the XLA twin agree bit-for-bit on each partial —
only the combine order differs across backends.

The same decomposition is expressed two ways:

* **XLA twin** — :func:`split_dot` builds the partials with plain
  ``lax.dot_general(..., preferred_element_type=f32)`` on bf16 operands.
  This runs (slowly) on CPU, which keeps every precision mode
  parity-testable without a TPU.
* **Pallas** — the gram kernel body calls :func:`split_words` /
  :func:`split_dot_partials` directly and folds each partial into its
  (hi, lo) VMEM accumulator.

Only the *kernel-value* tiles are ever split.  Distances keep the exact
per-coordinate ``EXACT_DIST_D`` path (see kernels/gram/kernel.py): splitting
coordinates before the difference would re-introduce exactly the
near-origin cancellation that path exists to avoid.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.streaming import two_sum

Array = jax.Array

# Supported precision modes for the Gram contraction.  "fp32" is the
# historical single dot_general — bit-identical to pre-precision code.
PRECISIONS = ("fp32", "bf16x2", "bf16x3")

# Number of bf16 words per fp32 operand.
WORDS = {"fp32": 1, "bf16x2": 2, "bf16x3": 3}

# Cross-term exponent pairs (p, q) meaning a_word[p] x b_word[q], ordered
# smallest-magnitude-first so the running sum accumulates the tail before
# the dominant w0 x w0 term lands.  bf16x2 keeps p+q <= 1 (drops w1 x w1,
# ~2^-16 relative); bf16x3 keeps p+q <= 2 (drops only terms <= 2^-24
# relative, i.e. below the fp32 product floor).
_PAIRS = {
    2: ((1, 0), (0, 1), (0, 0)),
    3: ((1, 1), (2, 0), (0, 2), (1, 0), (0, 1), (0, 0)),
}

# Multiplier on the solver's spectral truncation floor.  bf16x2 raises the
# Gram noise floor to ~2^-16 relative (256x fp32's 2^-24 product floor);
# fp32 and bf16x3 sit at or below the fp32 floor.
EPS_SCALE = {"fp32": 1.0, "bf16x2": 256.0, "bf16x3": 1.0}


def check(precision: str) -> str:
    """Validate and return a precision mode name."""
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r}; expected one of {PRECISIONS}")
    return precision


def split_words(x: Array, words: int, *, axis=None) -> tuple[Array, ...]:
    """Ozaki fixed-point slicing of fp32 ``x`` into bf16 words.

    Every element of a contraction fiber (``axis``; the whole array when
    ``None``) shares one power-of-two grid step ``2^(floor(log2 amax) - 7)``,
    so each word is an integer multiple of its step in [-2^8, 2^8] — exactly
    bf16-representable, and exactly fp32-accumulable in the partial matmuls
    (see module docstring).  Successive words refine the grid by 2^-8; the
    residual after ``words`` slices is <= half the last step
    (~``amax * 2^(-8*words - 1)`` absolute).  All slice arithmetic
    (power-of-two divide, rint, multiply, subtract) is exact in fp32.
    """
    r = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(r), axis=axis, keepdims=axis is not None)
    # floor(log2)+1 over-approximates ceil(log2) even when log2 of an exact
    # power of two comes back a hair low; zero fibers get a harmless unit step.
    e = jnp.floor(jnp.log2(jnp.where(amax > 0, amax, 1.0)))
    step = jnp.where(amax > 0, jnp.exp2(e - 7.0), 1.0).astype(jnp.float32)
    parts = []
    for _ in range(words):
        w = jnp.rint(r / step) * step
        parts.append(w.astype(jnp.bfloat16))
        r = r - w
        step = step * jnp.float32(2.0 ** -8)
    return tuple(parts)


def split_dot_partials(a: Array, b: Array, dims, precision: str,
                       acc=jnp.float32) -> tuple[Array, ...]:
    """The ordered partial products of a split-precision ``dot_general``.

    Each partial is one bf16 x bf16 ``dot_general`` accumulated in ``acc``
    (exact for contractions up to 256 elements, near-exact beyond).
    Operands are sliced on per-fiber grids over their contracted axes.
    Partials are returned smallest-magnitude-first; the caller folds them
    into its accumulator (plain sum, chained two-sum, or a (hi, lo)
    compensated store).
    """
    words = WORDS[check(precision)]
    if words == 1:
        return (jax.lax.dot_general(a, b, dims, preferred_element_type=acc),)
    (ca, cb), _ = dims
    aw = split_words(a, words, axis=tuple(ca))
    bw = split_words(b, words, axis=tuple(cb))
    return tuple(
        jax.lax.dot_general(aw[p], bw[q], dims, preferred_element_type=acc)
        for p, q in _PAIRS[words])


def split_dot(a: Array, b: Array, dims, *, precision: str = "fp32",
              acc=jnp.float32) -> Array:
    """``lax.dot_general`` with a split-precision operand decomposition.

    ``precision="fp32"`` is literally a single
    ``dot_general(a, b, dims, preferred_element_type=acc)`` — bit-identical
    to calling lax directly.  The bf16 modes combine their partials through
    a chained two-sum (running (s, e) pair, collapsed at the end) so the
    combination itself never dominates the split error.
    """
    parts = split_dot_partials(a, b, dims, precision, acc)
    if len(parts) == 1:
        return parts[0]
    s, e = parts[0], jnp.zeros_like(parts[0])
    for p in parts[1:]:
        s, err = two_sum(s, p)
        e = e + err
    return s + e
