"""SA leverage-score approximation — the paper's core contribution (Eq. 6).

Given per-point input densities p_i and a stationary kernel with spectral
density m, the rescaled statistical leverage score G_lam(x_i, x_i) is
approximated by

    K_tilde(x_i, x_i) = int_{R^d} ds / (p_i + lam / m(s)),

and the Nystrom sampling distribution is q_i = K_tilde_i / sum_j K_tilde_j.

Three evaluation paths, all O(n) after density estimation:

  * ``method='closed_form'`` — the paper's App. D.2 analytic forms:
      Matern:   K_tilde_i  ∝ p_i^{d/(2 alpha) - 1}     (alpha = nu + d/2)
      Gaussian: K_tilde_i  =  -Li_{d/2}(-p_i (2 pi sigma^2)^{d/2}/lam) /
                               (p_i (2 pi sigma^2)^{d/2})  (x const)
  * ``method='quadrature'`` — faithful fixed-order radial quadrature of the
    exact integrand (keeps the +a^2 term the closed form drops).
  * ``method='grid'`` — beyond-paper fast path: the integral depends on x_i
    only through p_i, so evaluate the quadrature on a 256-point log-spaced
    density grid and linearly interpolate all n points in log-log space.
    Cost: O(256 * order) + O(n), independent of n's quadrature cost; relative
    interpolation error < 1e-4 on the grid span (tests/test_leverage.py).

The rescaled leverage is clipped at n (since ell_i <= 1), matching the
paper's rule-of-thumb  ell_i ∝ min{1, (lam / p_i)^{1 - d/(2 alpha)}}.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kernels as K
from repro.core import polylog, quadrature

Array = jax.Array

# Tiny positive floor for density estimates.  `kde_binned` clips its FFT
# output at zero, so p_i = 0.0 is a reachable input; the closed forms divide
# by p (Gaussian) or raise it to a negative power (Matern) and the grid path
# takes log(min(p)) — all NaN/inf at zero.  Every evaluation path clamps to
# DENSITY_EPS, the hard backstop below the soft `density_floor` rescaling.
DENSITY_EPS = 1e-30


class SALeverage(NamedTuple):
    rescaled: Array   # (n,) K_tilde(x_i, x_i) ~= G_lam(x_i, x_i)
    probs: Array      # (n,) normalized sampling distribution q_i
    d_stat: Array     # scalar estimate of the statistical dimension
    densities: Array  # (n,) the densities used


def matern_closed_form(p: Array, lam: float, kernel: K.Matern, d: int) -> Array:
    """Paper App. D.2 closed form (drops the +a^2 spectral offset).

    int_0^inf r^{d-1} / (p + b r^{2 alpha}) dr
        = p^{d/(2 alpha) - 1} b^{-d/(2 alpha)} (pi/(2 alpha)) / sin(pi d/(2 alpha))

    with b = lam (4 pi^2)^alpha / C_{d,nu}; multiplied by Vol(S^{d-1}).
    Relative error vs the exact integrand is O(lam^{1/alpha}) = o(1).
    """
    alpha = kernel.alpha(d)
    if not 2.0 * alpha > d:
        raise ValueError("need 2*alpha > d for an integrable spectral tail")
    b = lam * (4.0 * math.pi ** 2) ** alpha / kernel.spectral_constant(d)
    const = (
        quadrature.sphere_surface(d)
        * (math.pi / (2.0 * alpha))
        / math.sin(math.pi * d / (2.0 * alpha))
        * b ** (-d / (2.0 * alpha))
    )
    p = jnp.maximum(jnp.asarray(p), DENSITY_EPS)  # d < 2 alpha: exponent < 0
    return const * p ** (d / (2.0 * alpha) - 1.0)


def gaussian_closed_form(p: Array, lam: float, kernel: K.Gaussian, d: int) -> Array:
    """Paper App. D.2 Gaussian closed form via the polylogarithm.

    I(p) = Vol(S^{d-1}) Gamma(d/2) / (2 c^{d/2}) * F_{d/2}(p/lam') / p,
    c = 2 pi^2 sigma^2, lam' = lam (2 pi sigma^2)^{-d/2}, F_s = -Li_s(-x).
    """
    p = jnp.maximum(jnp.asarray(p), DENSITY_EPS)  # guard the 1/p below
    sigma = kernel.sigma
    c = 2.0 * math.pi ** 2 * sigma ** 2
    lam_p = lam * (2.0 * math.pi * sigma ** 2) ** (-d / 2.0)
    const = quadrature.sphere_surface(d) * math.gamma(d / 2.0) / (2.0 * c ** (d / 2.0))
    return const * polylog.neg_polylog(d / 2.0, p / lam_p) / p


def _grid_interp(p: Array, lam: float, kernel, d: int, grid_size: int, order: int) -> Array:
    """Log-log interpolation of the radial integral over a density grid."""
    p = jnp.maximum(jnp.asarray(p), DENSITY_EPS)  # log(min(p)) below
    lo = jnp.min(p) * 0.999
    hi = jnp.max(p) * 1.001
    # Guard the degenerate all-equal case.
    hi = jnp.where(hi <= lo, lo * (1.0 + 1e-3) + 1e-30, hi)
    log_lo, log_hi = jnp.log(lo), jnp.log(hi)
    grid = jnp.exp(jnp.linspace(log_lo, log_hi, grid_size))
    vals = jnp.log(quadrature.radial_integral(grid, lam, kernel, d, order=order))
    pos = (jnp.log(p) - log_lo) / (log_hi - log_lo) * (grid_size - 1)
    idx = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, grid_size - 2)
    frac = pos - idx
    out = vals[idx] * (1.0 - frac) + vals[idx + 1] * frac
    return jnp.exp(out)


def density_floor(p: Array, floor: float) -> Array:
    """Paper App. B.3 ad-hoc stabilization for near-zero densities.

    Replaces p with (0.5 * floor + p) / 1.5 wherever p < floor.
    """
    return jnp.where(p < floor, (0.5 * floor + p) / 1.5, p)


def sa_leverage(
    densities: Array,
    lam: float,
    kernel: K.Kernel,
    d: int,
    n: int | None = None,
    method: str = "closed_form",
    quad_order: int = 256,
    grid_size: int = 256,
    floor: float | None = None,
) -> SALeverage:
    """Algorithm 1 of the paper, given per-point density estimates.

    Args:
      densities: (n,) estimated input density p(x_i) at every design point.
      lam: KRR regularization parameter.
      kernel: the stationary kernel the KRR uses.
      d: input dimension.
      n: sample size (for the <= n clip); defaults to len(densities).
      method: 'closed_form' | 'quadrature' | 'grid' (see module docstring).
      floor: optional density floor (paper App. B.3).
    """
    p = jnp.asarray(densities)
    n = int(p.shape[0]) if n is None else n
    if floor is not None:
        p = density_floor(p, floor)
    p = jnp.maximum(p, DENSITY_EPS)

    if method == "closed_form":
        if isinstance(kernel, K.Matern):
            raw = matern_closed_form(p, lam, kernel, d)
        else:
            raw = gaussian_closed_form(p, lam, kernel, d)
    elif method == "quadrature":
        raw = quadrature.radial_integral(p, lam, kernel, d, order=quad_order)
    elif method == "grid":
        raw = _grid_interp(p, lam, kernel, d, grid_size, quad_order)
    else:
        raise ValueError(f"unknown method {method!r}")

    rescaled = jnp.minimum(raw, float(n))  # G = n*ell <= n since ell <= 1
    total = jnp.sum(rescaled)
    return SALeverage(
        rescaled=rescaled,
        probs=rescaled / total,
        d_stat=total / n,
        densities=p,
    )
