"""Distributed (pjit) SA-leverage + Nyström KRR — the paper's pipeline on the
production mesh.

This is the deployment form of the paper's contribution: at n ~ 10^7-10^8 a
single host can neither hold the (n, d) design nor the (n, m) cross-kernel
matrix, so the pipeline shards the SAMPLE dimension over the whole mesh
(every chip owns n/chips rows) and keeps landmarks replicated:

  1. KDE        p_i = mean_j k_h(x_i - x_j)     — sharded queries against
                replicated source batches (TPU: the Pallas `kde` kernel per
                shard; here the fused-XLA oracle) -> no n x n materialisation;
  2. SA map     q_i ∝ p_i^{d/(2α)-1}            — elementwise (Eq. 6 closed
                form), embarrassingly parallel; the normaliser is one psum;
  3. Nyström    K_nm^T K_nm and K_nm^T y reduce over the sharded n axis
                (GSPMD inserts the all-reduce), the m x m solve is replicated;
  4. predict    sharded rows x replicated beta.

Everything is jit-able end to end; `lower_pipeline` is the dry-run/roofline
entry (abstract inputs, both production meshes), and tests check the sharded
path equals the single-device reference bit-for-bit (up to reduction order).

For the KDE source set we follow the paper's subsampled-KDE argument (App. E:
o(1) relative KDE error suffices): density is estimated against a uniform
m_kde-subsample of the data (m_kde ~ sqrt(n) keeps the KDE term o(n) compute
per chip while its error stays within the Thm-5 slack).
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kernels as K
from repro.core import leverage, streaming
from repro.distributed.sharding import constrain

Array = jax.Array


class SAPipelineOut(NamedTuple):
    probs: Array        # (n,) SA sampling distribution
    d_stat: Array       # scalar
    beta: Array         # (m,) Nyström coefficients
    fitted: Array       # (n,) in-sample predictions


def _sq_dists(x: Array, y: Array) -> Array:
    x2 = jnp.sum(x * x, axis=-1)[:, None]
    y2 = jnp.sum(y * y, axis=-1)[None, :]
    return jnp.maximum(x2 + y2 - 2.0 * (x @ y.T), 0.0)


def _sq_dists_augmented(x: Array, y: Array) -> Array:
    """||x-y||^2 as ONE (d+2)-wide GEMM (§Perf cell C iter 3).

    [ -2x | ||x||^2 | 1 ] . [ y | 1 | ||y||^2 ]^T = ||x||^2 + ||y||^2 - 2x.y
    — the broadcast+add assembly of the standard expansion disappears from
    the HLO (its bytes dominated the pipeline after iter 1).  +2 columns of
    GEMM flops, exact same math up to addition order.
    """
    n, d = x.shape
    ones_x = jnp.ones((n, 1), x.dtype)
    x_aug = jnp.concatenate([-2.0 * x, jnp.sum(x * x, -1, keepdims=True),
                             ones_x], axis=1)
    ones_y = jnp.ones((y.shape[0], 1), y.dtype)
    y_aug = jnp.concatenate([y, ones_y, jnp.sum(y * y, -1, keepdims=True)],
                            axis=1)
    return jnp.maximum(x_aug @ y_aug.T, 0.0)


def kde_sharded(x: Array, kde_sample: Array, h: float) -> Array:
    """p_hat at every x_i against the (replicated) KDE subsample.

    x is row-sharded over the mesh ('batch' rule); the (n_loc, m_kde) weight
    tile lives per chip only.
    """
    m, d = kde_sample.shape
    x = constrain(x, ("batch", None))
    sq = _sq_dists(x, kde_sample)
    w = jnp.exp(-sq / (2.0 * h * h))
    norm = 1.0 / (m * (2.0 * math.pi * h * h) ** (d / 2.0))
    return constrain(norm * jnp.sum(w, axis=1), ("batch",))


def kde_binned_sharded(x: Array, h: float, *, grid_size: int = 96,
                       lo: Array | None = None, hi: Array | None = None,
                       tile: int | None = None,
                       backend: str | None = None,
                       accumulator: str = "plain") -> Array:
    """Paper-faithful Õ(n) KDE, sharded: the §Perf replacement for the
    O(n·m_kde) direct tile (see EXPERIMENTS.md §Perf cell C).

    One-bandwidth wrapper over `kde_binned_sharded_multi` (identical ops for
    a single h — the multi body's per-h loop degenerates to the historical
    single-h body).  This is the KDE stage the pipeline
    (`repro.pipeline.stages.DensityStage`) runs under an active mesh.
    """
    return kde_binned_sharded_multi(x, (h,), grid_size=grid_size, lo=lo,
                                    hi=hi, tile=tile, backend=backend,
                                    accumulator=accumulator)[0]


def kde_binned_sharded_multi(x: Array, hs, *, grid_size: int = 96,
                             lo: Array | None = None, hi: Array | None = None,
                             tile: int | None = None,
                             backend: str | None = None,
                             accumulator: str = "plain") -> Array:
    """Sharded binned KDE for a bandwidth GRID: (H, n) at one deposit+psum.

    shard_map body: stream LOCAL rows through the CIC deposit
    (`kernels.dispatch.binned_scatter` — the engine-tiled XLA scatter or the
    Pallas `kde_binned` kernel per `backend`, O(tile 2^d) transient per
    chip) into a local copy of the (small, replicated) grid -> psum the
    accumulator STATE across the mesh (the `repro.core.streaming` strategy
    owns the collective: the compensated (hi, lo) pair crosses it
    un-collapsed) -> per-bandwidth FFT smoothing + purely local multilinear
    gather.  The deposit and the grid psum are bandwidth-independent and run
    ONCE for the whole sweep — the mesh half of the CalibrateStage contract
    (a naive sweep would psum per candidate).  Per-chip bytes stay
    O(tile + g^d); the only collective is the one grid psum.  Bounds
    (lo, hi) must be static for jit; pass data bounds or rely on the
    caller's normalisation (default [-5, 5]^d covers normalised designs).

    2D (data x model) meshes: when the active rules map the "models"
    logical axis to a mesh axis that divides H (and "rows" divides n), the
    rows shard over the DATA axes only, the grid psums over data only, and
    the H bandwidths shard over the MODEL axis — each model-chip smooths
    and gathers only its H/M candidates instead of every chip redundantly
    running the whole (H, g^d) FFT sweep (the VMEM/grid-resolution ceiling
    ROADMAP item 5 names).  The deposit is replicated across model chips
    (it is the cheap, bandwidth-independent part); per-h outputs are
    bit-equal to the 1D data-mesh path with the same data-shard count —
    the psum has the same participants and the per-h smooth/gather is the
    same op sequence on a bandwidth sliced from a device array.  On a 1D
    mesh (no "models"-mapped axis) the historical all-axes row sharding is
    unchanged.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core import kde as core_kde
    from repro.core import streaming
    from repro.distributed import sharding as shd

    n, d = x.shape
    act = shd.active()
    if (lo is None) != (hi is None):
        raise ValueError("pass both lo and hi to pin the grid bounds, or "
                         "neither for the [-5, 5]^d default")
    if lo is None:
        lo = jnp.full((d,), -5.0, x.dtype)
        hi = jnp.full((d,), 5.0, x.dtype)
    spacing = (hi - lo) / (grid_size - 1)
    acc = streaming.get(accumulator)

    def deposit(x_loc, psum_axes):
        from repro.kernels import dispatch
        state = dispatch.binned_scatter(x_loc, lo, spacing, grid_size,
                                        backend=backend, tile=tile,
                                        accumulator=accumulator,
                                        finalize=False)
        if psum_axes:   # only meaningful inside shard_map; ONE psum per sweep
            state = acc.psum(state, psum_axes)
        return acc.finalize(state)

    def smooth_gather(grid, x_loc, h):
        # the shared per-h op sequence (`core.kde.smooth_gather`): same
        # traced program whether h is a python float (1D path) or a device
        # scalar sliced from the model-sharded bandwidth array (2D path)
        return core_kde.smooth_gather(grid, x_loc, h, lo=lo, spacing=spacing,
                                      grid_size=grid_size, d=d, n=n)

    def body(x_loc, *, psum_axes=()):
        grid = deposit(x_loc, psum_axes)
        return jnp.stack([smooth_gather(grid, x_loc, h) for h in hs])

    if act is None:
        return body(x)   # single-device: no collective
    data_axes = act.spec(("rows", None), x.shape)[0]
    model_axes = act.spec(("models",), (len(hs),))[0]
    if model_axes is not None and data_axes is not None:
        # 2D path: rows over data, bandwidths over model.  The h subset is
        # an INPUT sliced by shard_map (in_spec P(model)), so each chip's
        # per-h loop runs the same traced-ops sequence as the 1D path.
        data_tuple = ((data_axes,) if isinstance(data_axes, str)
                      else tuple(data_axes))
        hs_arr = jnp.asarray(hs, x.dtype)

        def body2d(x_loc, hs_loc):
            grid = deposit(x_loc, data_tuple)
            return jnp.stack([smooth_gather(grid, x_loc, hs_loc[i])
                              for i in range(hs_loc.shape[0])])

        return shard_map(body2d, mesh=act.mesh,
                         in_specs=(P(data_axes, None), P(model_axes)),
                         out_specs=P(model_axes, data_axes))(x, hs_arr)
    if n % act.mesh.devices.size != 0:
        return body(x)   # non-dividing n: no collective
    axes = tuple(act.mesh.axis_names)
    return shard_map(functools.partial(body, psum_axes=axes), mesh=act.mesh,
                     in_specs=P(axes, None),
                     out_specs=P(None, axes))(x)


def sa_nystrom_pipeline(
    x: Array,              # (n, d)    row-sharded design
    y: Array,              # (n,)      row-sharded responses
    kde_sample: Array,     # (m_kde, d) replicated KDE source subsample
    landmark_idx: Array,   # (m,) int  landmark rows (sampled on host from q)
    *,
    kernel: K.Matern,
    lam: float,
    kde_h: float,
    kde_method: str = "direct",     # direct | binned  (§Perf cell C, iter 1)
    knm_dtype=jnp.float32,          # bf16 halves k_nm traffic (iter 2)
) -> SAPipelineOut:
    n, d = x.shape
    # 1-2) density -> SA leverage (Eq. 6 closed form) -> sampling weights
    if kde_method == "binned":
        p = kde_binned_sharded(x, kde_h)
    else:
        p = kde_sharded(x, kde_sample, kde_h)
    raw = leverage.matern_closed_form(p, lam, kernel, d)
    raw = jnp.minimum(raw, float(n))
    total = jnp.sum(raw)                       # psum over the sharded axis
    probs = raw / total

    # 3) Nyström normal equations; n-axis reduction is the big all-reduce
    xm = x[landmark_idx]                       # gather -> replicated (m, d)
    sq = _sq_dists_augmented(x.astype(knm_dtype), xm.astype(knm_dtype))
    k_nm = kernel.from_distance(jnp.sqrt(sq)).astype(knm_dtype)
    k_nm = constrain(k_nm, ("batch", None))    # (n_loc-sharded, m)
    k_mm = kernel(xm, xm)
    m = xm.shape[0]

    # Fused dense normal equations through the streaming engine: a one-step
    # (tile=None) `tile_reduce` whose emit is the pair of fp32-accumulated
    # MXU dot_generals the historical inline path ran — the scan body
    # compiles to the same fused computation (zero-init add is exact), and
    # GSPMD still turns the n-axis contraction into the one big all-reduce.
    def emit(k_tile, y_tile):
        g = jax.lax.dot_general(k_tile, k_tile, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        r = jax.lax.dot_general(k_tile, y_tile, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        return g, r

    g, rhs = streaming.tile_reduce(
        emit, k_nm, (y.astype(knm_dtype),), tile=None,
        init=(jnp.zeros((m, m), jnp.float32), jnp.zeros((m,), jnp.float32)),
        pad="zero")
    lhs = g + n * lam * k_mm
    scale = jnp.trace(lhs) / m
    lhs = lhs + (1e-6 * scale) * jnp.eye(m, dtype=lhs.dtype)
    beta = jnp.linalg.solve(lhs, rhs)          # replicated small solve

    # 4) in-sample predictions, sharded rows
    fitted = constrain((k_nm @ beta.astype(knm_dtype)).astype(jnp.float32),
                       ("batch",))
    return SAPipelineOut(probs=probs, d_stat=total / n, beta=beta,
                         fitted=fitted)


def make_pipeline_fn(kernel: K.Matern, lam: float, kde_h: float,
                     kde_method: str = "direct", knm_dtype=jnp.float32):
    return functools.partial(sa_nystrom_pipeline, kernel=kernel, lam=lam,
                             kde_h=kde_h, kde_method=kde_method,
                             knm_dtype=knm_dtype)


def abstract_inputs(n: int, d: int, m_kde: int, m: int, dtype=jnp.float32):
    """ShapeDtypeStructs for lower(): sharded x/y, replicated sample/idx."""
    from repro.distributed import sharding as shd
    act = shd.active()

    def sds(shape, dt, axes):
        if act is None:
            return jax.ShapeDtypeStruct(shape, dt)
        return jax.ShapeDtypeStruct(shape, dt,
                                    sharding=act.sharding(axes, shape))

    return (
        sds((n, d), dtype, ("batch", None)),
        sds((n,), dtype, ("batch",)),
        sds((m_kde, d), dtype, (None, None)),
        sds((m,), jnp.int32, (None,)),
    )


def lower_pipeline(mesh, *, n: int, d: int = 3, nu: float = 1.5,
                   lam: float | None = None, m_kde: int | None = None,
                   m: int | None = None, kde_method: str = "direct",
                   knm_dtype=jnp.float32):
    """Dry-run entry: lower + compile the full pipeline on `mesh`."""
    from repro.distributed import sharding as shd
    lam = lam if lam is not None else 0.075 * n ** (-2.0 / 3.0)
    m = m if m is not None else int(5 * n ** (1.0 / 3.0))
    m_kde = m_kde if m_kde is not None else max(1024, int(n ** 0.5))
    kde_h = 0.15 * n ** (-1.0 / 7.0)
    kernel = K.Matern(nu=nu)
    fn = make_pipeline_fn(kernel, lam, kde_h, kde_method, knm_dtype)
    rules = {"batch": ("pod", "data", "model")}  # pure row sharding: all chips
    with mesh, shd.activate(mesh, rules):
        args = abstract_inputs(n, d, m_kde, m)
        lowered = jax.jit(fn).lower(*args)
        return lowered, lowered.compile()
