"""Polylogarithm -Li_s(-x) for the Gaussian-kernel closed form (paper App. D.2).

For Gaussian kernels the paper reduces Eq. (6) to

    (2 / Gamma(d/2)) int_0^inf t^{d-1} / (p (2 pi sigma^2)^{d/2} + lam e^{t^2}) dt
        = -Li_{d/2}(-p (2 pi sigma^2)^{d/2} / lam) / (p (2 pi sigma^2)^{d/2}).

We implement F_s(x) := -Li_s(-x) for s > 0, x >= 0 through its Fermi-Dirac
integral representation

    F_s(x) = (1 / Gamma(s)) int_0^inf  t^{s-1} / (e^t / x + 1) dt,

with the substitution t = u^2 (removing the integrable t^{s-1} endpoint
singularity for s = d/2 with d = 1) and a fixed-order Gauss-Legendre rule on
u in [0, sqrt(log1p(x) + 40)] — beyond that point the integrand is < e^-40 of
its peak.  Fully vectorized over x; no mpmath / scipy special dependency.

Sanity anchors used by tests:
  * F_1(x) = log(1 + x) exactly (d = 2),
  * series F_s(x) = sum_{k>=1} (-1)^{k+1} x^k / k^s for x < 1,
  * agreement with quadrature.radial_integral_gaussian.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.quadrature import gauss_legendre

Array = jax.Array


def neg_polylog(s: float, x: Array, order: int = 256) -> Array:
    """F_s(x) = -Li_s(-x), elementwise over x >= 0."""
    x = jnp.asarray(x)
    u, w = gauss_legendre(order)
    u_max = jnp.sqrt(jnp.log1p(x) + 40.0)
    uu = u_max[..., None] * u  # (..., order)
    t = uu * uu
    # 1 / (e^t / x + 1) = x * e^-t / (1 + x e^-t), stable for large t.
    fd = x[..., None] * jnp.exp(-t) / (1.0 + x[..., None] * jnp.exp(-t))
    integrand = 2.0 * uu ** (2.0 * s - 1.0) * fd
    return u_max * jnp.sum(integrand * w, axis=-1) / math.gamma(s)


def neg_polylog_series(s: float, x: Array, terms: int = 64) -> Array:
    """Reference series for |x| < 1 (test oracle only)."""
    x = jnp.asarray(x)
    k = jnp.arange(1, terms + 1, dtype=x.dtype)
    signs = jnp.where(k % 2 == 1, 1.0, -1.0)
    return jnp.sum(signs * x[..., None] ** k / k ** s, axis=-1)
