"""First-class accumulator state for the streaming engine.

Every streaming reduction in this codebase (Gram + rhs normal equations,
the CIC deposit, the fused score moments) is a monoid fold over row tiles.
Historically the fold state lived only as a transient `lax.scan` carry
inside one `tile_reduce` call — so new data meant a full refit.  This
module makes that state a first-class value with an explicit monoid
contract:

  * ``init(spec, zeros)``    — the identity element;
  * absorb                   — fold more tiles in (domain-specific: callers
    re-enter `tile_reduce(..., init_state=state.value)` so the scan carry
    CONTINUES from the saved state; a tile-aligned sequence of absorbs is
    the same op sequence as the one-shot fold, hence bit-equal under the
    plain accumulator — locked by tests/test_accstate.py);
  * ``merge(a, b)``          — combine two independently-built states
    (the cross-chip psum, parallel chunk builds, window folds).  For the
    compensated strategy the merge is itself error-free: hi parts combine
    through `two_sum` and the rounding error is banked in lo;
  * ``decay(state, gamma)``  — exponential forgetting for drifting
    streams.  Applied in the (hi, lo) domain — BOTH floats scale — so the
    banked compensation survives the reweighting;
  * ``finalize(state)``      — collapse to the reduced value (identity for
    plain, hi + lo for compensated).

`AccState` carries, besides the strategy state itself, the two scalars
every consumer of a reduction needs to interpret it: ``rows`` (the
effective — possibly decayed, hence fractional — number of rows absorbed,
i.e. the `n` of the normal equations) and ``steps`` (per-chip scan steps,
the error-budget count behind `streaming.eps_scale`).  Both are array
leaves, so a state round-trips through `jax.tree` transforms, psums, and
`checkpoint.Manager` unchanged.

The strategy itself is static aux data: a ``spec`` that is either a name
(``"plain"`` / ``"compensated"``) or, for `multi_reduce` states, a tuple
of per-slot names.  Specs are plain hashable values, so AccStates of the
same spec share jit caches.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp

from repro.core import streaming

Array = jax.Array

# A strategy spec: one accumulator name, or a tuple of per-slot names for
# states produced by `multi_reduce`.
Spec = Any


def normalize_spec(accumulator: Any) -> Spec:
    """Canonical hashable spec for a strategy name or instance."""
    if isinstance(accumulator, str):
        streaming.get(accumulator)  # validate
        return accumulator
    if isinstance(accumulator, (tuple, list)):
        return tuple(normalize_spec(a) for a in accumulator)
    if isinstance(accumulator, streaming.MultiAccumulator):
        return tuple(a.name for a in accumulator.accumulators)
    name = getattr(accumulator, "name", None)
    if name in streaming.ACCUMULATORS:
        return name
    raise ValueError(f"cannot derive an AccState spec from {accumulator!r}")


def strategy(spec: Spec):
    """Strategy instance for a spec (MultiAccumulator for tuple specs).

    Tuple specs resolve with the default leafwise-add combines — enough
    for merge/decay/finalize; absorbs that need a non-additive combine
    (the CIC scatter) construct their own `MultiAccumulator` at the
    `tile_reduce` call site, which shares per-slot state layout.
    """
    if isinstance(spec, tuple):
        return streaming.MultiAccumulator(spec)
    return streaming.get(spec)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class AccState:
    """Accumulator state + row/step bookkeeping; a jax pytree."""

    value: Any                  # strategy state (tree / (hi, lo) / slots)
    rows: Array                 # scalar f32: effective rows absorbed
    steps: Array                # scalar i32: per-chip scan steps absorbed
    spec: Spec = "plain"        # static: strategy name(s)

    def tree_flatten(self):
        return (self.value, self.rows, self.steps), self.spec

    @classmethod
    def tree_unflatten(cls, spec, children):
        value, rows, steps = children
        return cls(value=value, rows=rows, steps=steps, spec=spec)

    @property
    def accumulator(self):
        return strategy(self.spec)


def init(accumulator: Any, zeros: Any, *, rows: float = 0.0,
         steps: int = 0) -> AccState:
    """The monoid identity: a zero state shaped like `zeros`."""
    spec = normalize_spec(accumulator)
    return AccState(value=strategy(spec).init(zeros),
                    rows=jnp.asarray(rows, jnp.float32),
                    steps=jnp.asarray(steps, jnp.int32),
                    spec=spec)


def wrap(accumulator: Any, value: Any, *, rows, steps) -> AccState:
    """Wrap a raw strategy state (e.g. a `tile_reduce(finalize=False)`
    result) into an AccState with explicit row/step bookkeeping."""
    return AccState(value=value,
                    rows=jnp.asarray(rows, jnp.float32),
                    steps=jnp.asarray(steps, jnp.int32),
                    spec=normalize_spec(accumulator))


def merge(a: AccState, b: AccState) -> AccState:
    """Combine two independently-built states (commutative; bit-equal
    under operand swap — IEEE addition and TwoSum are both symmetric)."""
    if normalize_spec(a.spec) != normalize_spec(b.spec):
        raise ValueError(
            f"cannot merge AccStates of specs {a.spec!r} and {b.spec!r}")
    return AccState(value=strategy(a.spec).merge(a.value, b.value),
                    rows=a.rows + b.rows, steps=a.steps + b.steps,
                    spec=a.spec)


def psum(state: AccState, axes) -> AccState:
    """All-reduce a state across the given mesh axes (inside shard_map).

    2D-mesh contract: callers pass the DATA axes only — the model axis of
    a (data, model) mesh shards independent work and must never reduce.
    Value leaves cross through the strategy's psum (the compensated
    (hi, lo) pair un-collapsed, per `streaming.CompensatedAccumulator`);
    ``rows`` sums (each chip absorbed a disjoint row slab); ``steps``
    takes the max — it is a PER-CHIP error-budget count, and summing it
    across chips would overstate the compensated floor's step budget.
    """
    return AccState(
        value=strategy(state.spec).psum(state.value, axes),
        rows=jax.lax.psum(state.rows, axes),
        steps=jax.lax.pmax(state.steps, axes),
        spec=state.spec)


def decay(state: AccState, gamma: float) -> AccState:
    """Exponential forgetting: scale every value leaf by `gamma`.

    For the compensated strategy this scales hi AND lo, so the banked
    rounding error decays with the sum it compensates instead of being
    collapsed or dropped.  ``rows`` decays identically (the effective
    sample size of the reweighted fold); ``steps`` is an error-budget
    COUNT, not a mass, and is left alone.
    """
    g = jnp.asarray(gamma, jnp.float32)
    value = jax.tree.map(lambda leaf: leaf * g.astype(leaf.dtype),
                         state.value)
    return AccState(value=value, rows=state.rows * g, steps=state.steps,
                    spec=state.spec)


def finalize(state: AccState) -> Any:
    """Collapse to the reduced value (identity / hi + lo / per-slot)."""
    return strategy(state.spec).finalize(state.value)


def rows_of(state: AccState) -> float:
    """Effective row count as a host float (blocks on the scalar)."""
    return float(jax.device_get(state.rows))


def steps_of(state: AccState) -> int:
    """Per-chip scan steps as a host int (feeds `streaming.eps_scale`)."""
    return int(jax.device_get(state.steps))


class SlidingWindow:
    """Ring buffer of per-chunk states for sliding-window absorption.

    Monoids have no inverse, so evicting the oldest chunk cannot subtract
    it; instead the window keeps the last `window` chunk states and the
    current window state is recomputed as a left fold of merges over the
    ring — O(window) merges, each O(state size).  Chunks older than the
    window fall off the deque and are garbage.

    ``merge_fn`` defaults to `accstate.merge`; pass a domain merge (e.g.
    `nystrom.normal_eq_merge`) to window richer state objects.
    """

    def __init__(self, window: int,
                 merge_fn: Callable[[Any, Any], Any] | None = None):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.window = int(window)
        self._merge = merge_fn if merge_fn is not None else merge
        self._ring: deque = deque(maxlen=self.window)

    def push(self, chunk: Any) -> None:
        self._ring.append(chunk)

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def chunks(self) -> Iterable[Any]:
        return tuple(self._ring)

    def state(self) -> Any:
        """Fold-merge of the chunks currently in the window."""
        if not self._ring:
            raise ValueError("empty window: push at least one chunk first")
        it = iter(self._ring)
        state = next(it)
        for chunk in it:
            state = self._merge(state, chunk)
        return state
