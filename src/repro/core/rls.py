"""Algebraic leverage-score baselines the paper compares against (§4.1).

  * ``uniform``        — "Vanilla": q_i = 1/n (no leverage information).
  * ``recursive_rls``  — Musco & Musco (2017) Recursive-RLS: recursively halve
    the data, estimate ridge leverage on the half, race-sample a sketch
    (Gumbel top-k at the Bernoulli inclusion rates — deterministic sketch
    size, see `_race_sketch`), refine.  O(n d_stat^2) kernel evaluations.
  * ``bless``          — Rudi et al. (2018) bottom-up path following: start at
    a huge ridge (where uniform sampling is provably fine) and geometrically
    anneal it down to n*lam, race-resampling a sketch at every step.

All share the weighted projection estimator of the ridge leverage scores:
with sketch S (indices), importance weights w (expected inverse inclusion),
absolute ridge mu = n * lam,

    l_hat_i = (1/mu) * ( K_ii - k_iS W^{1/2} (W^{1/2} K_SS W^{1/2} + mu I)^{-1}
                                 W^{1/2} k_Si )

which is exact when S = [n], w = 1 (then l_hat = diag(K (K + mu)^{-1})).
These are *host-recursive* drivers (dynamic sketch sizes) around jit-able
dense linear algebra.  The inner K_{:,S} blocks go through
`repro.kernels.dispatch.kernel_matrix`, which resolves to the Pallas
`pairwise` kernel on TPU and the fused-XLA reference elsewhere
(override with backend= or the REPRO_KERNEL_BACKEND env var).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels import Kernel

Array = jax.Array


def kernel_matrix(kernel: Kernel, x: Array, y: Array | None = None,
                  backend: str | None = None) -> Array:
    """Backend-dispatched kernel matrix (Pallas `pairwise` on TPU)."""
    from repro.kernels import dispatch
    return dispatch.kernel_matrix(kernel, x, y, backend=backend)


class RLSResult(NamedTuple):
    leverage: Array   # (n,) approximate statistical leverage scores
    probs: Array      # (n,) normalized sampling distribution
    sketch_size: int  # size of the final sketch used


def uniform(n: int) -> RLSResult:
    p = jnp.full((n,), 1.0 / n)
    return RLSResult(leverage=p * n, probs=p, sketch_size=0)


def projection_leverage(
    kernel: Kernel,
    x: Array,
    sketch_x: Array,
    weights: Array,
    mu: float,
    jitter: float = 1e-6,
    backend: str | None = None,
) -> Array:
    """Weighted projection estimate of ridge leverage for all n points.

    `weights` are inverse inclusion probabilities of the sketch points (the
    Bernoulli sketches of Recursive-RLS/BLESS and the Gumbel top-k threshold
    weights from `sampling.sample_weighted_without_replacement` both follow
    this convention).  The K_{:,S} blocks go through `kernels.dispatch`, so
    the Pallas `pairwise` backend serves them on TPU (`backend` overrides).
    """
    k_ns = kernel_matrix(kernel, x, sketch_x, backend=backend)   # (n, m)
    k_ss = kernel_matrix(kernel, sketch_x, backend=backend)      # (m, m)
    w_half = jnp.sqrt(weights)
    mat = w_half[:, None] * k_ss * w_half[None, :]
    m = sketch_x.shape[0]
    chol = jnp.linalg.cholesky(mat + (mu + jitter) * jnp.eye(m, dtype=mat.dtype))
    rhs = (k_ns * w_half[None, :]).T                     # (m, n)
    solved = jax.scipy.linalg.cho_solve((chol, True), rhs)
    quad = jnp.sum(rhs * solved, axis=0)                 # k_iS W^.5 (..)^-1 W^.5 k_Si
    k_diag = jnp.ones(x.shape[0], dtype=k_ns.dtype)      # stationary: K_ii = K(0) = 1
    lev = (k_diag - quad) / mu
    return jnp.clip(lev, 1e-12, 1.0)


def from_sketch(
    kernel: Kernel,
    x: Array,
    lam: float,
    sketch_idx: Array,
    weights: Array | None = None,
    jitter: float = 1e-6,
    backend: str | None = None,
) -> RLSResult:
    """Projection leverage from an index sketch — the SA-sampled entry point.

    Feeds the pipeline's Gumbel top-k landmarks (`state.fit.landmark_idx`)
    and their recorded importance weights (`state.sample_weights`) into the
    same weighted projection estimator the algebraic baselines use, turning
    the sampled landmark set into full-design leverage estimates at
    O(n m) kernel evaluations.  weights=None means uniform (w = 1).
    """
    n = x.shape[0]
    sketch_x = jnp.take(x, jnp.asarray(sketch_idx, jnp.int32), axis=0)
    w = (jnp.ones(sketch_x.shape[0], dtype=x.dtype) if weights is None
         else jnp.asarray(weights))
    lev = projection_leverage(kernel, x, sketch_x, w, mu=n * lam,
                              jitter=jitter, backend=backend)
    return RLSResult(leverage=lev, probs=lev / jnp.sum(lev),
                     sketch_size=int(sketch_x.shape[0]))


def _race_sketch(rng: np.random.Generator, inclusion: np.ndarray):
    """Deterministic-size sketch via a host-side exponential (Gumbel) race.

    Historically Recursive-RLS / BLESS Bernoulli-sampled their sketches at
    per-point inclusion probabilities pi_i, so the sketch SIZE was random
    (std ~ sqrt(sum pi (1 - pi)) — the `--compare` bench's sketch_size /
    d_proj rows wobbled run to run).  The race keeps the same importance
    profile (rates q = pi) but always returns k = round(sum pi) distinct
    indices: top-k on log q + Gumbel == bottom-k on arrivals E/q — the
    numpy twin of `sampling.sample_weighted_without_replacement`, with the
    same inverse-inclusion threshold weights (pi_hat = 1 - exp(-q tau),
    tau the (k+1)-th arrival)."""
    n = inclusion.shape[0]
    k = int(np.clip(round(float(inclusion.sum())), 1, n))
    q = np.maximum(inclusion, 1e-38)
    s = np.log(q) + rng.gumbel(size=n)
    order = np.argsort(-s)
    idx = order[:k]
    if k >= n:
        return idx, np.ones(k)
    tau = np.exp(-s[order[k]])
    pi_hat = -np.expm1(-q[idx] * tau)
    return idx, 1.0 / np.maximum(pi_hat, 1e-12)


def recursive_rls(
    kernel: Kernel,
    x: Array,
    lam: float,
    seed: int = 0,
    base_size: int = 256,
    oversample: float = 8.0,
) -> RLSResult:
    """Musco & Musco (2017) Recursive-RLS (host-driven recursion)."""
    n = x.shape[0]
    mu = n * lam
    rng = np.random.default_rng(seed)
    x_np = np.asarray(x)

    def recurse(indices: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Returns (sketch_indices_global, sketch_weights) for this subset."""
        m = indices.shape[0]
        if m <= base_size:
            return indices, np.ones(m)
        half = rng.permutation(indices)[: m // 2]
        sketch_idx, sketch_w = recurse(half)
        lev = np.asarray(
            projection_leverage(
                kernel, jnp.asarray(x_np[half]), jnp.asarray(x_np[sketch_idx]),
                jnp.asarray(sketch_w), mu,
            )
        )
        inclusion = np.minimum(1.0, oversample * lev * math.log(max(m, 2)))
        pick, w = _race_sketch(rng, inclusion)   # k = round(sum pi) >= 1
        return half[pick], w

    sketch_idx, sketch_w = recurse(np.arange(n))
    lev = projection_leverage(
        kernel, x, jnp.asarray(x_np[sketch_idx]), jnp.asarray(sketch_w), mu
    )
    return RLSResult(
        leverage=lev, probs=lev / jnp.sum(lev), sketch_size=int(sketch_idx.shape[0])
    )


def bless(
    kernel: Kernel,
    x: Array,
    lam: float,
    seed: int = 0,
    anneal: float = 2.0,
    oversample: float = 8.0,
    init_size: int = 64,
) -> RLSResult:
    """BLESS (Rudi et al. 2018), bottom-up ridge annealing (host-driven).

    Ridge path mu_t = n / anneal^t down to n*lam; at each step the sketch is
    Bernoulli-resampled from leverage estimates at the *current* ridge, for
    which the previous (coarser) sketch is already accurate.
    """
    n = x.shape[0]
    mu_final = n * lam
    rng = np.random.default_rng(seed)
    x_np = np.asarray(x)

    sketch_idx = rng.permutation(n)[:init_size]
    sketch_w = np.ones(init_size)
    mu = float(n)
    steps = max(1, int(math.ceil(math.log(mu / mu_final, anneal))))
    for _ in range(steps):
        mu = max(mu / anneal, mu_final)
        lev = np.asarray(
            projection_leverage(
                kernel, x, jnp.asarray(x_np[sketch_idx]), jnp.asarray(sketch_w), mu
            )
        )
        inclusion = np.minimum(1.0, oversample * lev * math.log(n))
        sketch_idx, sketch_w = _race_sketch(rng, inclusion)  # k >= 1 always
    lev = projection_leverage(
        kernel, x, jnp.asarray(x_np[sketch_idx]), jnp.asarray(sketch_w), mu_final
    )
    return RLSResult(
        leverage=lev, probs=lev / jnp.sum(lev), sketch_size=int(sketch_idx.shape[0])
    )
