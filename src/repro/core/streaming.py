"""Unified streaming tile-reduction engine with pluggable accumulation.

Every streaming loop in this codebase reduces (or maps) row slabs of an
(n, ...) array through a per-tile computation: the Nystrom normal equations
(`nystrom.scan_normal_eq` and its mesh-sharded wrapper), the CIC deposit of
the binned KDE (`kde.scatter_cic` and its per-chip twin in
`core.distributed.kde_binned_sharded_multi`), and the batched predicts
(`nystrom.predict_streaming[_multi]`).  Historically each re-implemented
tiling, ragged-tail padding, sharding and accumulation by hand — every
numerics fix (e.g. EXACT_DIST_D) had to land in four places.  This module
owns that plumbing once:

  * `tile_reduce`  — row-slab tiling, ragged-tail padding (sentinel rows for
    kernel maps, zero rows + zero weights for deposits), a `lax.scan` over
    the slabs, and a pluggable accumulator strategy;
  * `multi_reduce` — one tile scan driving N pluggable accumulators at once
    (fused Gram+rhs+score-moment passes; `MultiAccumulator` composes per-slot
    strategies tuple-wise and is bit-equal to sequential passes per slot);
  * `tile_map`     — the same tiling for per-row outputs (predict);
  * `mesh_reduce` / `mesh_map` — optional shard_map execution over the
    "rows" logical axis (`repro.distributed.sharding`), with the accumulator
    STATE — not the finalized value — crossing the psum.

Accumulator strategies (`get(name)`):

  * ``plain``       — the historical fp32 running sum.  Bit-equal to the
    pre-engine hand-rolled loops (locked by tests/test_streaming_engine.py).
  * ``compensated`` — Kahan/Neumaier two-float error-carrying sum: the carry
    is a (hi, lo) pair per output leaf; each tile update is folded in with
    an error-free two-sum and the rounding error is banked in lo.  The pair
    survives the cross-chip psum (hi and lo reduce separately) and is only
    collapsed by `finalize`.  Cross-tile accumulation error drops from
    O(steps) * eps to the within-tile floor, which lets
    `nystrom.solve_normal_eq` lower its spectral noise-floor cutoff by
    `EPS_SCALE["compensated"]` and keep whitened directions that plain fp32
    must truncate (ROADMAP: the fp32 scale ceiling).

The same strategy runs inside the Pallas `gram` kernel body as a two-float
VMEM accumulator (`repro.kernels.gram`), so the TPU path shares the lower
noise floor; `repro.kernels.dispatch` threads ``accumulator=`` through both
backends.
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.kernels import pad_rows_sentinel, round_up

Array = jax.Array

ACCUMULATORS = ("plain", "compensated")

# Residual accumulation-noise scale, relative to eps(dtype), that
# `nystrom.solve_normal_eq` may assume for a Gram built by each strategy.
# Plain fp32 accumulation noise sits at ~eps * lambda_max(G); the
# compensated sum removes the cross-tile term, leaving the within-tile dot
# rounding — measured ≳30x below the plain floor on the n ≥ 1e5 streams the
# regression tests lock (tests/test_streaming_engine.py), so 1/32 is the
# conservative factor by which the spectral truncation floor recedes.
EPS_SCALE = {"plain": 1.0, "compensated": 1.0 / 32.0}


def two_sum(a: Array, b: Array) -> tuple[Array, Array]:
    """Error-free transformation: s fl= a + b, e = (a + b) - s exactly.

    Knuth's branch-free TwoSum (6 flops); valid for any rounding direction
    and magnitudes.  XLA does not reassociate float arithmetic, so the
    cancellation pattern survives compilation on every backend.
    """
    s = a + b
    bb = s - a
    err = (a - (s - bb)) + (b - bb)
    return s, err


def _tree_add(acc, update):
    return jax.tree.map(jnp.add, acc, update)


class PlainAccumulator:
    """The historical running sum; `state` IS the value."""

    name = "plain"

    def init(self, zeros):
        return zeros

    def add(self, state, update, combine):
        return combine(state, update)

    def merge(self, a, b):
        # IEEE addition is commutative (not associative), so merge order
        # of a PAIR is bit-stable; chains of merges are order-sensitive
        # like any float sum.
        return _tree_add(a, b)

    def psum(self, state, axes):
        return jax.lax.psum(state, axes)

    def finalize(self, state):
        return state


class CompensatedAccumulator:
    """Kahan/Neumaier two-float sum; `state` is a (hi, lo) pair of trees.

    Non-additive `combine`s (the CIC scatter) are folded in by materializing
    the tile's dense delta against a zero value first — `combine` must
    therefore satisfy combine(0, u) == the additive delta of u, which every
    scatter/segment-sum update does.
    """

    name = "compensated"

    def init(self, zeros):
        return (zeros, jax.tree.map(jnp.zeros_like, zeros))

    def add(self, state, update, combine):
        hi, lo = state
        if combine is _tree_add:
            delta = update
        else:
            delta = combine(jax.tree.map(jnp.zeros_like, hi), update)
        s = jax.tree.map(jnp.add, hi, delta)
        err = jax.tree.map(
            lambda h, d, ss: (h - (ss - (ss - h))) + (d - (ss - h)), hi,
            delta, s)
        return (s, jax.tree.map(jnp.add, lo, err))

    def merge(self, a, b):
        # Error-free pair merge: the hi parts combine through TwoSum and
        # the rounding error lands in lo alongside both carried errors —
        # merging two compensated states loses nothing beyond what a
        # continued single stream would have lost.
        hi_a, lo_a = a
        hi_b, lo_b = b
        s = jax.tree.map(lambda x, y: two_sum(x, y)[0], hi_a, hi_b)
        err = jax.tree.map(lambda x, y: two_sum(x, y)[1], hi_a, hi_b)
        lo = jax.tree.map(lambda la, lb, e: la + lb + e, lo_a, lo_b, err)
        return (s, lo)

    def psum(self, state, axes):
        # hi and lo reduce SEPARATELY: the pair crosses the collective
        # un-collapsed, so per-chip compensation is not thrown away at the
        # all-reduce (finalize folds the psummed lo back in).
        return jax.lax.psum(state, axes)

    def finalize(self, state):
        hi, lo = state
        return jax.tree.map(jnp.add, hi, lo)


class MultiAccumulator:
    """Tuple-of-slots composition: slot i runs its own strategy + combine.

    State is a tuple of per-slot accumulator states, so one tile scan can
    drive N independent reductions (Gram + rhs + predict moments + ...) off
    a single pass over x.  Each slot's arithmetic is the *same op sequence*
    it would run in its own `tile_reduce` — XLA never reassociates floats —
    so a fused plain-slot reduction is bit-equal to the sequential one
    (locked by tests/test_multi_reduce.py).  Compensated slots keep their
    (hi, lo) pair per slot; `psum`/`finalize` delegate slot-wise, which is
    what lets the pair survive a fused cross-chip reduction un-collapsed.
    """

    def __init__(self, accumulators: Sequence[str | Any],
                 combines: Sequence[Callable | None] | None = None):
        self.accumulators = tuple(get(a) for a in accumulators)
        if combines is None:
            combines = (None,) * len(self.accumulators)
        if len(combines) != len(self.accumulators):
            raise ValueError("combines and accumulators length mismatch")
        self.combines = tuple(c if c is not None else _tree_add
                              for c in combines)
        self.name = "multi(" + ",".join(
            a.name for a in self.accumulators) + ")"

    def init(self, zeros):
        if len(zeros) != len(self.accumulators):
            raise ValueError(
                f"init expects {len(self.accumulators)} slot zeros, "
                f"got {len(zeros)}")
        return tuple(a.init(z) for a, z in zip(self.accumulators, zeros))

    def add(self, state, update, combine):
        del combine  # per-slot combines are fixed at construction
        return tuple(
            a.add(s, u, c) for a, s, u, c in
            zip(self.accumulators, state, update, self.combines))

    def merge(self, a, b):
        return tuple(
            acc.merge(sa, sb) for acc, sa, sb in
            zip(self.accumulators, a, b))

    def psum(self, state, axes):
        return tuple(
            a.psum(s, axes) for a, s in zip(self.accumulators, state))

    def finalize(self, state):
        return tuple(
            a.finalize(s) for a, s in zip(self.accumulators, state))


_STRATEGIES = {"plain": PlainAccumulator(), "compensated": CompensatedAccumulator()}


def get(accumulator: str | Any) -> Any:
    """Resolve an accumulator name ('plain' | 'compensated') or instance."""
    if isinstance(accumulator, str):
        try:
            return _STRATEGIES[accumulator]
        except KeyError:
            raise ValueError(f"unknown accumulator {accumulator!r}; "
                             f"pick from {ACCUMULATORS}") from None
    return accumulator


def eps_scale(accumulator: str | Any, steps: int | None = None) -> float:
    """Noise-floor scale for `nystrom.solve_normal_eq` (see EPS_SCALE).

    Compensation removes only the CROSS-TILE accumulation error, so the
    floor may recede by at most the number of scan steps the stream ran:
    with `steps` given, the compensated scale is max(1/32, 1/steps) — a
    single-tile stream (steps == 1) has nothing to compensate and keeps the
    plain floor (lowering it there would retain directions whose fp32
    content is within-dot / kernel-eval noise the two-float sum never
    touched — empirically WORSE than plain at small n).
    """
    name = accumulator if isinstance(accumulator, str) else accumulator.name
    scale = EPS_SCALE.get(name, 1.0)
    if steps is not None and scale < 1.0:
        scale = max(scale, 1.0 / max(int(steps), 1))
    return scale


# ------------------------------------------------------------------ tiling --

def _pad_rows(x: Array, rows: int, pad: str) -> Array:
    if pad == "sentinel":
        return pad_rows_sentinel(x, rows)
    if pad == "zero":
        return jnp.pad(x, ((0, rows - x.shape[0]),) + ((0, 0),) * (x.ndim - 1))
    raise ValueError(f"unknown pad mode {pad!r}; pick 'sentinel' or 'zero'")


def _tiles(x: Array, t: int, np_: int, pad: str) -> Array:
    return _pad_rows(x, np_, pad).reshape((np_ // t, t) + x.shape[1:])


def tile_reduce(
    emit: Callable[..., Any],
    x: Array,
    aux: Sequence[Array] = (),
    *,
    tile: int | None,
    init: Any,
    combine: Callable[[Any, Any], Any] | None = None,
    accumulator: str | Any = "plain",
    pad: str = "sentinel",
    finalize: bool = True,
    init_state: Any = None,
    return_state: bool = False,
) -> Any:
    """Reduce `tile`-row slabs of x (+ row-aligned aux arrays) into `init`.

    ``emit(x_tile, *aux_tiles)`` produces the tile's update;
    ``combine(value, update)`` folds it into the running value (default:
    leafwise add — the Gram case; pass a scatter for deposits).  Ragged
    tails are padded per ``pad`` ("sentinel" parks extra rows at the
    ROW_SENTINEL coordinate so kernel maps evaluate to exactly 0; "zero"
    zero-pads — deposits must then carry a zero-padded weight aux so padded
    rows deposit nothing).  aux arrays are always zero-padded.

    ``accumulator`` picks the strategy (module docstring).  With
    ``finalize=False`` (or ``return_state=True``) the raw accumulator
    state is returned — the form `mesh_reduce` psums across chips and
    `repro.core.accstate` wraps as a first-class value.  With
    ``init_state=`` the scan carry STARTS from a previously returned raw
    state instead of `acc.init(init)`: absorbing a stream in tile-aligned
    chunks is then the same op sequence as one uninterrupted fold, so a
    chained absorb is bit-equal to the one-shot reduction (the incremental
    `partial_fit` contract).  A whole-array slab still runs as a one-step
    `lax.scan`: the scan body is compiled as one fused computation exactly
    like the historical hand-rolled loops, which is what makes plain mode
    bit-equal to them (an eager shortcut would round FMA-fused
    subexpressions differently on CPU).
    """
    acc = get(accumulator)
    combine = combine if combine is not None else _tree_add
    n = x.shape[0]
    t = min(tile, n) if tile else n
    state = acc.init(init) if init_state is None else init_state
    np_ = round_up(n, t)
    slabs = (_tiles(x, t, np_, pad),) + tuple(
        _tiles(a, t, np_, "zero") for a in aux)

    def step(carry, slab):
        return acc.add(carry, emit(*slab), combine), None

    state, _ = jax.lax.scan(step, state, slabs)
    if return_state:
        return state
    return acc.finalize(state) if finalize else state


def multi_reduce(
    emit: Callable[..., Any],
    x: Array,
    aux: Sequence[Array] = (),
    *,
    tile: int | None,
    inits: Sequence[Any],
    accumulators: Sequence[str | Any] | None = None,
    combines: Sequence[Callable | None] | None = None,
    pad: str = "sentinel",
    finalize: bool = True,
    init_state: Any = None,
    return_state: bool = False,
) -> Any:
    """One tile scan driving N pluggable accumulators at once.

    ``emit(x_tile, *aux_tiles)`` returns a TUPLE of per-slot updates —
    typically sharing expensive intermediates (the kernel tile) across
    slots.  Slot i is accumulated by ``accumulators[i]`` (default: all
    plain) folding with ``combines[i]`` (default: leafwise add) into
    ``inits[i]``.  Everything else (padding, scan, finalize semantics,
    ``init_state=``/``return_state=`` state threading) matches
    `tile_reduce`; with ``finalize=False`` the returned state is a tuple
    of per-slot states — the form `mesh_reduce` psums when given the same
    `MultiAccumulator` instance.
    """
    accs = tuple(accumulators) if accumulators is not None else (
        ("plain",) * len(tuple(inits)))
    multi = MultiAccumulator(accs, combines)
    return tile_reduce(emit, x, aux, tile=tile, init=tuple(inits),
                       accumulator=multi, pad=pad, finalize=finalize,
                       init_state=init_state, return_state=return_state)


def tile_map(
    fn: Callable[[Array], Array],
    x: Array,
    *,
    tile: int,
    pad: str = "sentinel",
) -> Array:
    """Map `fn` over `tile`-row slabs of x; returns the stacked (n, ...) out.

    The (tile, ...) slab output dies with each `lax.map` step, so peak
    transient memory is O(tile * out_cols) regardless of n — the predict
    contract (`nystrom.predict_streaming`).
    """
    n = x.shape[0]
    t = min(tile, n)
    np_ = round_up(n, t)
    out = jax.lax.map(fn, _tiles(x, t, np_, pad))
    return out.reshape((np_,) + out.shape[2:])[:n]


# -------------------------------------------------------------------- mesh --

def _active_axes(rule: str, shape):
    """(mesh, axes) when the active mesh's `rule` divides dim 0 of `shape`.

    The generic resolver behind both logical stream axes this engine knows:
    "rows" (the data/sample dim — reductions PSUM over it) and "models" (the
    independent-work dim — h/lam candidates, per-tenant models — which
    SHARDS, never reduces).  Under a 1D ("data",) mesh the "models" rule
    resolves to None and every model-axis path degenerates to the
    replicated 1D behavior.
    """
    from repro.distributed import sharding as shd
    act = shd.active()
    if act is None:
        return None, None
    axes = act.spec((rule,) + (None,) * (len(shape) - 1), shape)[0]
    return (act.mesh, axes) if axes is not None else (None, None)


def _active_rows(shape):
    """(mesh, rows_axes) when the active mesh's "rows" rule divides dim 0."""
    return _active_axes("rows", shape)


def _row_spec(axes, ndim: int):
    from jax.sharding import PartitionSpec as P
    return P(axes, *([None] * (ndim - 1)))


def _axes_tuple(axes) -> tuple:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def _mesh_axes_count(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    count = 1
    for a in _axes_tuple(axes):
        count *= sizes[a]
    return count


def row_shard_count(shape) -> int:
    """How many chips the "rows" rule splits dim 0 of `shape` across (1
    with no active mesh / non-dividing axis).  Callers sizing per-chip work
    — e.g. the scan-step count behind `eps_scale` — must divide by this:
    each chip streams only n/C rows, so a stream that is one tile PER CHIP
    has no cross-tile error to compensate even when the global n spans
    several tiles.  Counts DATA-axis shards only: under a 2D (data, model)
    mesh the model axis replicates (or shards independent work), it never
    splits the row stream, so it must not inflate the step budget."""
    mesh, axes = _active_rows(shape)
    if mesh is None:
        return 1
    return _mesh_axes_count(mesh, axes)


def model_shard_count(num_models: int) -> int:
    """How many chips the "models" rule splits an independent-work axis of
    length `num_models` across (1 with no active mesh, a 1D data mesh, or a
    non-dividing model axis)."""
    mesh, axes = _active_axes("models", (num_models,))
    if mesh is None:
        return 1
    return _mesh_axes_count(mesh, axes)


def mesh_reduce(
    local: Callable[..., Any],
    row_args: Sequence[Array],
    rep_args: Sequence[Array] = (),
    *,
    model_args: Sequence[Array] = (),
    row_model_args: Sequence[Array] = (),
    accumulator: str | Any = "plain",
    finalize: bool = True,
    init_state: Any = None,
    return_state: bool = False,
) -> Any:
    """Row-sharded reduction: psum `local`'s accumulator state across chips.

    ``local(*row_slabs, *row_model_slabs, *model_slabs, *rep_args)`` must
    return accumulator STATE (i.e. it ran its own `tile_reduce`/backend
    kernel with ``finalize=False``).  Under an active mesh whose "rows"
    rule divides the leading dim, each device reduces its local row slab
    and the state is psum-reduced — for "compensated" the (hi, lo) pair
    crosses the collective un-collapsed.  Otherwise `local` runs once on
    the full arrays (transparent no-op).

    2D (data x model) meshes: the psum covers the DATA axes only — the
    model axis shards independent work instead of reducing.  ``model_args``
    are sharded over the "models" rule on their leading dim (per-model
    landmark sets, per-model scalars); ``row_model_args`` are (rows,
    models)-shaped and shard BOTH ways (per-tenant responses riding the
    shared row stream).  When model args are present every leaf of the
    returned state must carry the model axis as its LEADING dim — the
    output stays model-sharded (out spec P(model_axes)) and assembles to
    the full (models, ...) stack, already psummed over data.  With no
    "models"-mapped mesh axis (a 1D data mesh) the model args are simply
    replicated and `local` computes every model — the transparent-fallback
    contract the bit-parity tests lock.

    ``init_state=`` is a prior raw state merged in AFTER the collective
    (threading it through the psum would multiply the replicated prior by
    the chip count); ``return_state=True`` returns the raw merged state.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    acc = get(accumulator)
    mesh, axes = _active_rows(row_args[0].shape)
    model_axes = None
    if model_args or row_model_args:
        probe = model_args[0].shape if model_args else (
            row_model_args[0].shape[1],)
        m_mesh, model_axes = _active_axes("models", tuple(probe[:1]))
        if mesh is None and m_mesh is not None:
            mesh = m_mesh      # model-only sharding (rows fell back local)
    if mesh is None:
        state = local(*row_args, *row_model_args, *model_args, *rep_args)
    else:
        ax_tuple = _axes_tuple(axes) if axes is not None else ()

        def body(*args):
            out = local(*args)
            return acc.psum(out, ax_tuple) if ax_tuple else out

        in_specs = (
            tuple(_row_spec(axes, a.ndim) for a in row_args)
            + tuple(P(axes, model_axes) for a in row_model_args)
            + tuple(_row_spec(model_axes, a.ndim) for a in model_args)
            + tuple(P(*([None] * a.ndim)) for a in rep_args))
        out_specs = P(model_axes) if model_axes is not None else P()
        state = shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)(
            *row_args, *row_model_args, *model_args, *rep_args)
    if init_state is not None:
        state = acc.merge(init_state, state)
    if return_state:
        return state
    return acc.finalize(state) if finalize else state


def mesh_map(
    local: Callable[..., Array],
    x: Array,
    rep_args: Sequence[Array] = (),
    *,
    model_args: Sequence[Array] = (),
    out_rank: int = 1,
) -> Array:
    """Row-sharded map: `local(x_loc, *rep_args)` -> (n_loc, ...) per chip.

    Embarrassingly row-parallel (no collective); `out_rank` is the rank of
    local's output, whose leading dim stays row-sharded.  With no active
    mesh (or a non-dividing axis) this is `local(x, *rep_args)`.

    With ``model_args`` (leading-dim model-sharded, like `mesh_reduce`) the
    call signature becomes ``local(x_loc, *model_slabs, *rep_args)`` and
    the output is (models_loc, rows_loc, ...): dim 0 rides the model axis,
    dim 1 the rows — the batched-predict layout.  On a 1D data mesh the
    model args replicate and dim 0 is the full model axis.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh, axes = _active_rows(x.shape)
    model_axes = None
    if model_args:
        m_mesh, model_axes = _active_axes("models", model_args[0].shape)
        if mesh is None and m_mesh is not None:
            mesh = m_mesh
    if mesh is None:
        return local(x, *model_args, *rep_args)
    in_specs = ((_row_spec(axes, x.ndim),)
                + tuple(_row_spec(model_axes, a.ndim) for a in model_args)
                + tuple(P(*([None] * a.ndim)) for a in rep_args))
    if model_args:
        out_specs = P(model_axes, axes, *([None] * (out_rank - 2)))
    else:
        out_specs = _row_spec(axes, out_rank)
    return shard_map(local, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs)(x, *model_args, *rep_args)
