"""Exact kernel ridge regression: the O(n^3) reference the paper accelerates.

Implements (paper §2.1, Eq. 2):

    f_hat(x)   = K(x, X_n) (K_n + n lam I)^{-1} Y_n
    G_lam(x_i, x_i) = n * [K_n (K_n + n lam I)^{-1}]_{ii}   (rescaled leverage)
    d_stat     = Tr(K_n (K_n + n lam I)^{-1})               (Eq. 4)

Everything is expressed with Cholesky solves so that it jits cleanly; the
symmetric eigendecomposition variant is provided for the leverage scores so a
single factorization serves both the diagonal and the trace.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernels import Kernel, kernel_matrix

Array = jax.Array

# fp32 LU on (K + n lam I) loses ~cond * eps ~ (lammax(K) / (n lam)) * eps
# relative accuracy, and the fp32 *kernel matrix* itself carries O(eps)
# eigenvalue noise (observed ~1e-6 negative tail eigenvalues at n=200) — so
# once lam drops below ~sqrt(eps_f32) the fp32 path first stalls above the
# noise floor and then explodes (lam=1e-9: training MSE 1.8e3 vs f64 0.10).
# Below this threshold the eager path re-solves in f64.
_F64_FALLBACK_LAM = float(np.sqrt(np.finfo(np.float32).eps))


class KRRFit(NamedTuple):
    """Solution state of an exact KRR fit."""

    coef: Array        # (n,)  alpha = (K_n + n lam I)^{-1} y
    x_train: Array     # (n, d)
    fitted: Array      # (n,)  in-sample predictions K_n alpha
    lam: float


def _fit_f64(kernel: Kernel, x: Array, y: Array, lam: float) -> KRRFit:
    """Eager f64 solve (kernel matrix recomputed in f64 — the fp32 K_n's
    rounding already swamps ridges this small).  The fp32-stabilizing jitter
    is dropped: it would rival n*lam at these ridges, and f64 LU handles the
    conditioning without it.  Results cast back to the caller's dtype so
    downstream code sees the usual fp32 arrays."""
    from jax.experimental import enable_x64

    dtype = jnp.result_type(x.dtype, jnp.float32)
    n = x.shape[0]
    with enable_x64():
        x64 = jnp.asarray(np.asarray(x), jnp.float64)
        y64 = jnp.asarray(np.asarray(y), jnp.float64)
        k_n = kernel_matrix(kernel, x64)
        coef = jnp.linalg.solve(k_n + n * lam * jnp.eye(n, dtype=jnp.float64),
                                y64)
        fitted = k_n @ coef
    return KRRFit(coef=jnp.asarray(np.asarray(coef), dtype), x_train=x,
                  fitted=jnp.asarray(np.asarray(fitted), dtype), lam=lam)


def fit(kernel: Kernel, x: Array, y: Array, lam: float, jitter: float = 1e-6) -> KRRFit:
    """Solve the exact KRR system (LU solve — robust at fp32 conditioning).

    Ridges below sqrt(eps_f32) sit under the fp32 kernel matrix's own noise
    floor; eager fp32 calls fall back to a full f64 solve there (tracing
    callers keep the fp32 path — the fallback re-enters the kernel matrix
    eagerly, which a jit trace cannot).
    """
    n = x.shape[0]
    if (lam < _F64_FALLBACK_LAM
            and jnp.result_type(x.dtype, jnp.float32) == jnp.float32
            and not isinstance(jnp.asarray(x), jax.core.Tracer)):
        return _fit_f64(kernel, x, y, lam)
    k_n = kernel_matrix(kernel, x)
    reg = (n * lam + jitter) * jnp.eye(n, dtype=k_n.dtype)
    coef = jnp.linalg.solve(k_n + reg, y)
    return KRRFit(coef=coef, x_train=x, fitted=k_n @ coef, lam=lam)


def predict(kernel: Kernel, fit_: KRRFit, x_new: Array) -> Array:
    return kernel_matrix(kernel, x_new, fit_.x_train) @ fit_.coef


class LeverageResult(NamedTuple):
    leverage: Array       # (n,) statistical leverage scores ell_i in (0, 1]
    rescaled: Array       # (n,) G_lam(x_i, x_i) = n * ell_i
    d_stat: Array         # scalar, Tr(K (K + n lam)^{-1}) = sum(ell)
    probs: Array          # (n,) normalized sampling distribution q_i


def exact_leverage(kernel: Kernel, x: Array, lam: float) -> LeverageResult:
    """Exact statistical leverage scores via symmetric eigendecomposition.

    ell_i = [K (K + n lam I)^{-1}]_{ii} = sum_j (e_j / (e_j + n lam)) U_{ij}^2
    with K = U diag(e) U^T.  O(n^3) time, O(n^2) space — this is the cost the
    paper's SA estimator removes; it stays here as the ground-truth oracle for
    tests and the R-ACC benchmark (paper Table 1).
    """
    n = x.shape[0]
    k_n = kernel_matrix(kernel, x)
    evals, evecs = jnp.linalg.eigh(k_n)
    evals = jnp.maximum(evals, 0.0)
    shrink = evals / (evals + n * lam)
    lev = jnp.sum(evecs * evecs * shrink[None, :], axis=1)
    lev = jnp.clip(lev, 1e-12, 1.0)
    return LeverageResult(
        leverage=lev,
        rescaled=n * lev,
        d_stat=jnp.sum(lev),
        probs=lev / jnp.sum(lev),
    )


def in_sample_risk(fitted: Array, f_star: Array) -> Array:
    """R_n(f) = ||f - f*||_n^2 (paper §2.3)."""
    diff = fitted - f_star
    return jnp.mean(diff * diff)
