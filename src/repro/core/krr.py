"""Exact kernel ridge regression: the O(n^3) reference the paper accelerates.

Implements (paper §2.1, Eq. 2):

    f_hat(x)   = K(x, X_n) (K_n + n lam I)^{-1} Y_n
    G_lam(x_i, x_i) = n * [K_n (K_n + n lam I)^{-1}]_{ii}   (rescaled leverage)
    d_stat     = Tr(K_n (K_n + n lam I)^{-1})               (Eq. 4)

Everything is expressed with Cholesky solves so that it jits cleanly; the
symmetric eigendecomposition variant is provided for the leverage scores so a
single factorization serves both the diagonal and the trace.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.kernels import Kernel, kernel_matrix

Array = jax.Array


class KRRFit(NamedTuple):
    """Solution state of an exact KRR fit."""

    coef: Array        # (n,)  alpha = (K_n + n lam I)^{-1} y
    x_train: Array     # (n, d)
    fitted: Array      # (n,)  in-sample predictions K_n alpha
    lam: float


def fit(kernel: Kernel, x: Array, y: Array, lam: float, jitter: float = 1e-6) -> KRRFit:
    """Solve the exact KRR system (LU solve — robust at fp32 conditioning)."""
    n = x.shape[0]
    k_n = kernel_matrix(kernel, x)
    reg = (n * lam + jitter) * jnp.eye(n, dtype=k_n.dtype)
    coef = jnp.linalg.solve(k_n + reg, y)
    return KRRFit(coef=coef, x_train=x, fitted=k_n @ coef, lam=lam)


def predict(kernel: Kernel, fit_: KRRFit, x_new: Array) -> Array:
    return kernel_matrix(kernel, x_new, fit_.x_train) @ fit_.coef


class LeverageResult(NamedTuple):
    leverage: Array       # (n,) statistical leverage scores ell_i in (0, 1]
    rescaled: Array       # (n,) G_lam(x_i, x_i) = n * ell_i
    d_stat: Array         # scalar, Tr(K (K + n lam)^{-1}) = sum(ell)
    probs: Array          # (n,) normalized sampling distribution q_i


def exact_leverage(kernel: Kernel, x: Array, lam: float) -> LeverageResult:
    """Exact statistical leverage scores via symmetric eigendecomposition.

    ell_i = [K (K + n lam I)^{-1}]_{ii} = sum_j (e_j / (e_j + n lam)) U_{ij}^2
    with K = U diag(e) U^T.  O(n^3) time, O(n^2) space — this is the cost the
    paper's SA estimator removes; it stays here as the ground-truth oracle for
    tests and the R-ACC benchmark (paper Table 1).
    """
    n = x.shape[0]
    k_n = kernel_matrix(kernel, x)
    evals, evecs = jnp.linalg.eigh(k_n)
    evals = jnp.maximum(evals, 0.0)
    shrink = evals / (evals + n * lam)
    lev = jnp.sum(evecs * evecs * shrink[None, :], axis=1)
    lev = jnp.clip(lev, 1e-12, 1.0)
    return LeverageResult(
        leverage=lev,
        rescaled=n * lev,
        d_stat=jnp.sum(lev),
        probs=lev / jnp.sum(lev),
    )


def in_sample_risk(fitted: Array, f_star: Array) -> Array:
    """R_n(f) = ||f - f*||_n^2 (paper §2.3)."""
    diff = fitted - f_star
    return jnp.mean(diff * diff)
