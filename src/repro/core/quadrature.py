"""Vectorized numerical quadrature for the paper's Eq. (6) radial integral.

The d-dimensional integral

    I(p) = \\int_{R^d} ds / (p + lambda / m(s))

reduces, for isotropic m, to the 1-D radial integral (paper App. D.1)

    I(p) = Vol(S^{d-1}) \\int_0^inf r^{d-1} / (p + lambda / m(r)) dr,
    Vol(S^{d-1}) = 2 pi^{d/2} / Gamma(d/2).

TPU adaptation (DESIGN.md §3): the paper uses adaptive QUADPACK per point.  We
instead use a *fixed-order* Gauss-Legendre rule after a scale-aware rational
substitution, which vectorizes over all n query points with one fused einsum —
adaptive subdivision has no efficient TPU mapping, fixed-order batched
quadrature does.

The substitution r = r_scale * t / (1 - t), t in [0, 1) maps the half-line to
the unit interval.  For a Matern kernel the integrand decays like
r^{d-1-2*alpha}; the transformed integrand behaves like (1-t)^{2*nu-1} near
t = 1, which is bounded for nu >= 1/2, so Gauss-Legendre converges fast.  The
scale r_scale is chosen per-point at the knee of the integrand,
(p C / lambda)^{1/(2 alpha)} / (2 pi), so one rule order works across the whole
density range.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kernels as K

Array = jax.Array


@functools.lru_cache(maxsize=None)
def gauss_legendre(order: int, a: float = 0.0, b: float = 1.0):
    """Cached Gauss-Legendre nodes/weights on [a, b] (host numpy, fp64)."""
    x, w = np.polynomial.legendre.leggauss(order)
    x = 0.5 * (b - a) * (x + 1.0) + a
    w = 0.5 * (b - a) * w
    return jnp.asarray(x), jnp.asarray(w)


def sphere_surface(d: int) -> float:
    """Surface area of the unit (d-1)-sphere embedded in R^d."""
    return 2.0 * math.pi ** (d / 2.0) / math.gamma(d / 2.0)


def radial_integral_matern(
    p: Array,
    lam: float,
    kernel: K.Matern,
    d: int,
    order: int = 256,
) -> Array:
    """Eq. (6) for a Matern kernel, exact integrand, vectorized over p.

    Returns I(p_i) = Vol(S^{d-1}) * int_0^inf r^{d-1} / (p_i + lam/m(r)) dr for
    every entry of ``p``.  This is the *faithful* numerical path; the closed
    form in ``leverage.matern_closed_form`` drops the +a^2 term (paper App.
    D.2) and is validated against this.
    """
    alpha = kernel.alpha(d)
    c_spec = kernel.spectral_constant(d)
    t, w = gauss_legendre(order)
    p = jnp.asarray(p)
    # Knee of the integrand: where p ~ lam/m(r)  =>  (4 pi^2) r^2 ~ (pC/lam)^(1/alpha)
    r_scale = jnp.maximum((p * c_spec / lam) ** (1.0 / (2.0 * alpha)), kernel.a) / (
        2.0 * math.pi
    )
    r = r_scale[..., None] * t / (1.0 - t)  # (..., order)
    dr = r_scale[..., None] * (1.0 / (1.0 - t) ** 2)
    inv_m = (kernel.a ** 2 + 4.0 * math.pi ** 2 * r ** 2) ** alpha / c_spec
    integrand = r ** (d - 1) / (p[..., None] + lam * inv_m)
    return sphere_surface(d) * jnp.sum(integrand * dr * w, axis=-1)


def radial_integral_gaussian(
    p: Array,
    lam: float,
    kernel: K.Gaussian,
    d: int,
    order: int = 256,
) -> Array:
    """Eq. (6) for a Gaussian kernel via direct radial quadrature.

    The integrand r^{d-1} / (p + lam' e^{c r^2}) lives on r in
    [0, ~sqrt(log((1+p/lam')/eps)/c)]; we substitute r = r_max * t and use a
    fixed GL rule.  Cross-validated against the polylog closed form
    (leverage.gaussian_closed_form) in tests.
    """
    sigma = kernel.sigma
    lam_p = lam * (2.0 * math.pi * sigma ** 2) ** (-d / 2.0)
    c = 2.0 * math.pi ** 2 * sigma ** 2
    p = jnp.asarray(p)
    t, w = gauss_legendre(order)
    # Beyond r_max the integrand is < e^-40 of its plateau value.
    r_max = jnp.sqrt((jnp.log1p(p / lam_p) + 40.0) / c)
    r = r_max[..., None] * t
    integrand = r ** (d - 1) / (p[..., None] + lam_p * jnp.exp(c * r * r))
    return sphere_surface(d) * r_max * jnp.sum(integrand * w, axis=-1)


def radial_integral(p, lam, kernel, d, order: int = 256):
    """Dispatch Eq. (6) on the kernel family."""
    if isinstance(kernel, K.Matern):
        return radial_integral_matern(p, lam, kernel, d, order)
    if isinstance(kernel, K.Gaussian):
        return radial_integral_gaussian(p, lam, kernel, d, order)
    raise TypeError(f"no radial integral for {type(kernel)}")
