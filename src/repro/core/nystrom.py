"""Nystrom-approximated KRR (paper §2.3).

With landmark columns S, the Nystrom approximation L = K S (S^T K S)^+ S^T K
substituted into the KRR solution gives (Woodbury; derivation in DESIGN
history) the subset-of-regressors form

    f_L(x) = K(x, X_S) beta,
    beta   = (K_nm^T K_nm + n lam K_mm)^{-1} K_nm^T y,

which needs O(n m) kernel evaluations and an O(m^3) solve — the  O(n d_stat^2)
downstream cost that leverage estimation must not exceed.  L is invariant to
positive rescaling of S's columns, so with-replacement sampling needs no
1/sqrt(m q_i) reweighting here (duplicates are absorbed by the jitter).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.kernels import Kernel, kernel_matrix
from repro.core.sampling import sample_with_replacement

Array = jax.Array


class NystromFit(NamedTuple):
    beta: Array          # (m,)
    landmarks: Array     # (m, d) landmark inputs
    landmark_idx: Array  # (m,) indices into the training set
    lam: float


def fit_from_landmarks(
    kernel: Kernel,
    x: Array,
    y: Array,
    lam: float,
    landmark_idx: Array,
    jitter: float = 1e-6,
) -> NystromFit:
    n = x.shape[0]
    xm = x[landmark_idx]
    k_nm = kernel_matrix(kernel, x, xm)                   # (n, m)
    k_mm = kernel_matrix(kernel, xm)                      # (m, m)
    m = xm.shape[0]
    lhs = k_nm.T @ k_nm + n * lam * k_mm
    # Relative jitter: with-replacement sampling duplicates landmark columns,
    # which makes lhs exactly singular — regularize at the matrix's own scale
    # so it also survives fp32.
    scale = jnp.trace(lhs) / m
    lhs = lhs + (jitter * scale) * jnp.eye(m, dtype=k_nm.dtype)
    beta = jnp.linalg.solve(lhs, k_nm.T @ y)
    return NystromFit(beta=beta, landmarks=xm, landmark_idx=landmark_idx, lam=lam)


def fit(
    key: jax.Array,
    kernel: Kernel,
    x: Array,
    y: Array,
    lam: float,
    num_landmarks: int,
    probs: Array,
    jitter: float = 1e-6,
) -> NystromFit:
    """Sample landmarks ~ probs (with replacement, paper Thm 2) and solve."""
    idx = sample_with_replacement(key, probs, num_landmarks)
    return fit_from_landmarks(kernel, x, y, lam, idx, jitter=jitter)


def predict(kernel: Kernel, fit_: NystromFit, x_new: Array) -> Array:
    return kernel_matrix(kernel, x_new, fit_.landmarks) @ fit_.beta


def fitted(kernel: Kernel, fit_: NystromFit, x_train: Array) -> Array:
    """In-sample predictions f_L(x_i) (for the paper's R_n risk metric)."""
    return predict(kernel, fit_, x_train)
