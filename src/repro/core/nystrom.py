"""Nystrom-approximated KRR (paper §2.3), dense and streaming.

With landmark columns S, the Nystrom approximation L = K S (S^T K S)^+ S^T K
substituted into the KRR solution gives (Woodbury; derivation in DESIGN
history) the subset-of-regressors form

    f_L(x) = K(x, X_S) beta,
    beta   = (K_nm^T K_nm + n lam K_mm)^{-1} K_nm^T y,

which needs O(n m) kernel evaluations and an O(m^3) solve — the  O(n d_stat^2)
downstream cost that leverage estimation must not exceed.  L is invariant to
positive rescaling of S's columns, so with-replacement sampling needs no
1/sqrt(m q_i) reweighting here (duplicate columns land in the truncated
eigenspace of K_mm — see ``solve_normal_eq``).

Two solve paths:

  * ``fit_from_landmarks`` / ``fit`` — dense: materializes K_nm.  Simple and
    fine up to n ~ 1e5; it is also the parity oracle for the streaming path.
  * ``fit_streaming`` — accumulates G = K_nm^T K_nm and rhs = K_nm^T y over
    row tiles (lax.scan on the XLA backend, the fused Pallas ``gram`` kernel
    on TPU — see `repro.kernels.dispatch`), so peak memory is O(tile * m)
    regardless of n.  Under an active mesh (`repro.distributed.sharding`)
    the row stream is sharded over the "rows" logical axis (mesh axis
    "data" by default) and G/rhs are psum-reduced — a transparent no-op on
    a single device.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accstate
from repro.core import precision as precision_mod
from repro.core import streaming
from repro.core.kernels import Kernel, kernel_matrix, sentinel_is_safe
from repro.core.sampling import sample_with_replacement

Array = jax.Array


def _require_sentinel_safe(kernel: Kernel) -> None:
    """Reject kernels whose bandwidth defeats sentinel-row padding.

    Checked eagerly (kernel parameters are static); under a jit trace the
    concrete check is impossible, so it is skipped — the public entry points
    below are eager, which is where it matters.
    """
    try:
        ok = sentinel_is_safe(kernel)
    except (jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError):
        # array-conversion and float()-on-tracer raise different types
        return
    if not ok:
        raise ValueError(
            f"{kernel!r} does not vanish at the padding sentinel distance "
            "(~1e6); the streaming tile padding would corrupt the Gram. "
            "Normalize the inputs or shrink the kernel bandwidth.")


class NystromFit(NamedTuple):
    beta: Array          # (m,)
    landmarks: Array     # (m, d) landmark inputs
    landmark_idx: Array  # (m,) indices into the training set
    lam: float


def weighted_normal_eq(g: Array, rhs: Array, k_mm: Array,
                       weights: Array) -> tuple[Array, Array, Array]:
    """Rescale the SoR normal equations by landmark column weights.

    With W = diag(w), the weighted subset-of-regressors system uses columns
    Z = K_nm W:  (W G W + n lam W K_mm W) gamma = W rhs, beta = W gamma —
    the without-replacement path's importance correction
    (`sampling.sample_weighted_without_replacement`).  In exact arithmetic
    the SoR predictor is invariant to any positive column rescaling (beta
    absorbs W^{-1} twice), so this changes results only through fp32
    whitening/truncation order — a property the test suite locks in
    (test_sampling_weights.py::test_sor_solve_invariant_to_weight_rescaling).
    Returns the reweighted (G, rhs, K_mm); callers multiply the solved gamma
    by w to recover beta in the unweighted basis.
    """
    w = weights.astype(g.dtype)
    return (w[:, None] * g * w[None, :], w * rhs,
            w[:, None] * k_mm.astype(g.dtype) * w[None, :])


def _whitened_solve(g: Array, rhs: Array, evals: Array, evecs: Array,
                    g_max: Array, n: int, lam: float, jitter: float,
                    eps_scale: float = 1.0) -> Array:
    """The per-lam tail of `solve_normal_eq`: truncate + whiten + solve.

    Takes the lam-INDEPENDENT eigendecomposition of K_mm (and the trace
    upper bound on lambda_max(G)) as inputs, so a lam sweep pays the O(m^3)
    eigh once and only re-runs this O(m^3-but-tiny) tail per candidate —
    the op sequence per lam is identical to the single-lam solve, so the
    sweep is bit-equal to per-lam solves (locked in tests/test_calibrate.py).
    `eps_scale` shrinks the dtype-noise-floor term for Grams accumulated
    with less noise than a plain fp32 running sum (streaming.EPS_SCALE).
    """
    m = evals.shape[0]
    eps = float(jnp.finfo(g.dtype).eps) * eps_scale
    tau = jnp.maximum(jitter * evals[-1], eps * g_max / (n * lam))
    inv_sqrt = jnp.where(evals > tau, 1.0 / jnp.sqrt(jnp.maximum(evals, tau)),
                         0.0)
    w = evecs * inv_sqrt[None, :]                         # (m, m) whitener
    a = w.T @ g @ w
    b = w.T @ rhs
    gamma = jnp.linalg.solve(a + n * lam * jnp.eye(m, dtype=a.dtype), b)
    return w @ gamma


def _whitened_solve_nlam(g: Array, rhs: Array, evals: Array, evecs: Array,
                         g_max: Array, nlam: Array, jitter: float,
                         eps: float) -> Array:
    """`_whitened_solve` with the n*lam product passed as a DEVICE scalar.

    The python-float path computes n*lam on the host and lets weak-type
    promotion round it to the array dtype at the two use sites; passing
    ``nlam = jnp.asarray(n * lam, g.dtype)`` reproduces exactly that
    rounding, so this variant is bit-equal to `_whitened_solve` while being
    traceable over lam — the form the model-sharded lam sweep
    (`solve_normal_eq_multi`) and the vmapped many-model solve
    (`solve_normal_eq_batched`) need.  ``eps`` is the already-scaled
    noise-floor factor (float(finfo.eps) * eps_scale).
    """
    m = evals.shape[0]
    tau = jnp.maximum(jitter * evals[-1], eps * g_max / nlam)
    inv_sqrt = jnp.where(evals > tau, 1.0 / jnp.sqrt(jnp.maximum(evals, tau)),
                         0.0)
    w = evecs * inv_sqrt[None, :]                         # (m, m) whitener
    a = w.T @ g @ w
    b = w.T @ rhs
    gamma = jnp.linalg.solve(a + nlam * jnp.eye(m, dtype=a.dtype), b)
    return w @ gamma


def solve_normal_eq(g: Array, rhs: Array, k_mm: Array, n: int, lam: float,
                    jitter: float = 1e-6, eps_scale: float = 1.0) -> Array:
    """beta = (G + n lam K_mm)^{-1} rhs via spectrally-truncated whitening.

    The plain normal equations are numerically hopeless at scale: K_mm's
    eigenvalues decay to ~0 (smooth kernels, duplicated with-replacement
    landmarks), and accumulation noise in G — eps * lambda_max(G), which
    GROWS with n — is amplified by 1/eig through those directions until it
    swamps the n*lam regularizer.  Instead eigendecompose K_mm = U E U^T,
    whiten with W = U E^{-1/2} on the eigenspaces above a cutoff tau, and
    solve the well-conditioned (W^T G W + n lam I) gamma = W^T rhs,
    beta = W gamma.  tau is the larger of

      * jitter * lambda_max(K_mm)          — the usual relative floor, and
      * eps(dtype) * lambda_max(G)/(n lam) — the dtype's noise floor: below
        it the whitened G carries no signal, only amplified rounding error,

    so in fp32 at n = 1e6 the solve sheds exactly the directions fp32 cannot
    represent (matching the f64 solve's risk to ~1e-4), while in f64 the
    cutoff recedes and the solve is the textbook one.  Truncated directions
    are zeroed via masks, keeping every shape static (jit-safe).

    ``eps_scale`` (default 1.0: the plain fp32 floor) lowers the noise-floor
    term for Grams with sub-eps accumulation noise — the compensated
    two-float stream passes `streaming.EPS_SCALE["compensated"]` so the
    solve KEEPS the directions whose signal the better accumulation
    preserved (regression-tested in tests/test_streaming_engine.py).
    """
    evals, evecs = jnp.linalg.eigh(k_mm)
    # trace >= lambda_max for PSD G, and is tight here (G's spectrum is
    # dominated by the near-constant kernel component) — O(m) vs an O(m^3)
    # eigendecomposition for a quantity that only needs an upper bound.
    g_max = jnp.trace(g)
    return _whitened_solve(g, rhs, evals, evecs, g_max, n, lam, jitter,
                           eps_scale)


def solve_normal_eq_multi(g: Array, rhs: Array, k_mm: Array, n: int,
                          lams: Sequence[float],
                          jitter: float = 1e-6,
                          eps_scale: float = 1.0) -> Array:
    """`solve_normal_eq` over a lam grid, sharing the eigendecomposition.

    The truncation cutoff tau depends on lam, so each candidate gets its own
    whitener — but the K_mm eigh and the G trace are lam-independent and run
    once.  Returns the (L, m) stack of betas, row i bit-equal to
    `solve_normal_eq(g, rhs, k_mm, n, lams[i])` (same op sequence).

    Under an active 2D (data, model) mesh whose "models" rule divides L
    (`repro.distributed.sharding`), the per-lam tails SHARD across the
    model axis: the eigendecomposition stays replicated, each chip column
    solves its L / M slice of the grid, and the stack reassembles
    model-sharded — bit-equal per row to the 1D-data-mesh sweep because
    the n*lam products are pre-rounded host-side (`_whitened_solve_nlam`)
    and the per-lam op chain does not depend on how many lams a chip
    holds.  (Mesh execution compiles the tail, so mesh runs differ from
    the eager no-mesh loop by FMA-fusion rounding only.)
    """
    from repro.distributed import sharding as shd

    evals, evecs = jnp.linalg.eigh(k_mm)
    g_max = jnp.trace(g)
    lam_list = [float(lam) for lam in lams]
    act = shd.active()
    if act is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        # ANY active mesh routes through shard_map — a 1D data mesh runs
        # the body replicated (model_axes None), so adding a model axis
        # changes only how many lams each chip column holds, never the
        # per-lam op chain: the 2D sweep is bit-equal per row to the 1D
        # mesh sweep (locked in tests/test_mesh2d.py).  The NO-mesh path
        # below keeps the historical eager loop (and its bit-locks against
        # the single-lam solve, tests/test_calibrate.py).
        model_axes = act.spec(("models",), (len(lam_list),))[0]
        eps = float(jnp.finfo(g.dtype).eps) * eps_scale
        # host-f64 n*lam products, rounded ONCE to the array dtype — the
        # same values weak promotion produces inside `_whitened_solve`.
        nlams = jnp.asarray([n * lam for lam in lam_list], g.dtype)

        def body(nlams_loc, g_, rhs_, evals_, evecs_, g_max_):
            return jnp.stack([
                _whitened_solve_nlam(g_, rhs_, evals_, evecs_, g_max_,
                                     nlams_loc[i], jitter, eps)
                for i in range(nlams_loc.shape[0])])

        rep = (g, rhs, evals, evecs, g_max)
        return shard_map(
            body, mesh=act.mesh,
            in_specs=(P(model_axes),) + tuple(
                P(*([None] * a.ndim)) for a in rep),
            out_specs=P(model_axes) if model_axes is not None else P())(
            nlams, *rep)
    return jnp.stack([
        _whitened_solve(g, rhs, evals, evecs, g_max, n, lam, jitter,
                        eps_scale)
        for lam in lam_list])


def fit_from_landmarks(
    kernel: Kernel,
    x: Array,
    y: Array,
    lam: float,
    landmark_idx: Array,
    jitter: float = 1e-6,
    weights: Array | None = None,
) -> NystromFit:
    n = x.shape[0]
    xm = x[landmark_idx]
    k_nm = kernel_matrix(kernel, x, xm)                   # (n, m)
    k_mm = kernel_matrix(kernel, xm)                      # (m, m)
    g = jax.lax.dot_general(k_nm, k_nm, (((0,), (0,)), ((), ())),
                            preferred_element_type=k_nm.dtype)
    rhs = k_nm.T @ y
    if weights is not None:
        g, rhs, k_mm = weighted_normal_eq(g, rhs, k_mm, weights)
    beta = solve_normal_eq(g, rhs, k_mm, n, lam, jitter=jitter)
    if weights is not None:
        beta = weights.astype(beta.dtype) * beta
    return NystromFit(beta=beta, landmarks=xm, landmark_idx=landmark_idx, lam=lam)


def fit(
    key: jax.Array,
    kernel: Kernel,
    x: Array,
    y: Array,
    lam: float,
    num_landmarks: int,
    probs: Array,
    jitter: float = 1e-6,
) -> NystromFit:
    """Sample landmarks ~ probs (with replacement, paper Thm 2) and solve."""
    idx = sample_with_replacement(key, probs, num_landmarks)
    return fit_from_landmarks(kernel, x, y, lam, idx, jitter=jitter)


def predict(kernel: Kernel, fit_: NystromFit, x_new: Array) -> Array:
    return kernel_matrix(kernel, x_new, fit_.landmarks) @ fit_.beta


def fitted(kernel: Kernel, fit_: NystromFit, x_train: Array) -> Array:
    """In-sample predictions f_L(x_i) (for the paper's R_n risk metric)."""
    return predict(kernel, fit_, x_train)


# ---------------------------------------------------------------- streaming --

def _scan_steps(n: int, tile: int, x: Array,
                backend: str | None = None) -> int:
    """Accumulation steps the Gram stream ran PER CHIP — the budget
    `eps_scale` may lower the compensated truncation floor by.  Under an
    active mesh the stream is row-sharded, so each chip saw only
    n / row_shard_count rows (a one-tile-per-chip stream has no cross-tile
    error to compensate even when the global n spans several tiles).  The
    fold granularity is backend-dependent: the XLA engine compensates
    across `tile`-row scan steps, the Pallas gram kernel across its bm-row
    (<= 256) VMEM tile folds — so the TPU path earns its lower floor even
    when n fits one XLA-sized slab."""
    from repro.kernels import dispatch
    n_loc = max(1, n // streaming.row_shard_count(x.shape))
    grain = 256 if dispatch.resolve(backend) == "pallas" else tile
    return -(-n_loc // min(grain, n_loc))


def _resolve_gram_exec(tile: int | None, precision: str | None, x: Array,
                       xm: Array, backend: str | None, accumulator: str,
                       num_models: int = 1) -> tuple[int | None, str]:
    """Resolve the Gram stream's (tile, precision) execution pair.

    ``tile=None`` -> the autotuned XLA engine tile (`repro.tuning` via
    `dispatch.resolve_plan`); explicit tiles pass through untouched, and the
    Pallas gram path keeps None (it tunes bm/bn inside dispatch instead).
    ``precision=None`` resolves from the same plan — the autotuner picks
    the (tile, precision) pair JOINTLY — EXCEPT when the caller pinned the
    tile explicitly: an explicit-tile call never consults the planner, so
    its precision defaults to the historical "fp32" (bit parity with
    pre-precision code).  Resolution is per-chip: under an active mesh each
    device streams only n / row_shard_count rows, which is the stream the
    tile must fit."""
    from repro.kernels import dispatch
    n_loc = max(1, x.shape[0] // streaming.row_shard_count(x.shape))
    if dispatch.resolve(backend) == "pallas":
        if precision is None:
            precision = dispatch.resolve_plan(
                "gram", n_loc, xm.shape[0], x.shape[1], dtype=x.dtype,
                backend="pallas", accumulator=accumulator,
                precision=None, num_models=num_models).precision
        return tile, precision
    if tile is None:
        plan = dispatch.resolve_plan("gram", n_loc, xm.shape[0], x.shape[1],
                                     dtype=x.dtype, backend="xla",
                                     accumulator=accumulator,
                                     precision=precision,
                                     num_models=num_models)
        return plan.tile, (precision or plan.precision)
    return tile, (precision or "fp32")


def _eff_eps_scale(accumulator: str, steps: int, precision: str) -> float:
    """Solve truncation-floor scale: accumulation strategy x precision mode.

    `streaming.eps_scale` covers the cross-tile accumulation term; the
    precision mode multiplies in its within-tile product floor
    (`precision.EPS_SCALE` — bf16x2 RAISES the floor 256x, fp32/bf16x3
    leave it untouched)."""
    return streaming.eps_scale(accumulator, steps) * \
        precision_mod.EPS_SCALE.get(precision or "fp32", 1.0)


def _apply_beta(k: Array, beta: Array, precision: str | None) -> Array:
    """Predict-side (tile, m) x (m[, L]) matmul under a precision mode.

    fp32 keeps the historical ``@`` (bit parity with the dense oracle);
    the bf16 modes route through the same split-word decomposition as the
    Gram contraction."""
    if precision in (None, "fp32"):
        return k @ beta
    dims = (((1,), (0,)), ((), ()))
    return precision_mod.split_dot(k, beta, dims, precision=precision,
                                   acc=k.dtype)


def _resolve_predict_tile(tile: int | None, x_new: Array, xm: Array,
                          backend: str | None, num_models: int = 1) -> int:
    """``tile=None`` -> the autotuned predict row tile (per-chip, like the
    gram resolution above; the tile slabs `streaming.tile_map` on every
    backend)."""
    from repro.kernels import dispatch
    if tile is not None:
        return tile
    n_loc = max(1, x_new.shape[0] // streaming.row_shard_count(x_new.shape))
    return dispatch.resolve_tile("predict", n_loc, xm.shape[0],
                                 x_new.shape[1], dtype=x_new.dtype,
                                 backend=backend, num_models=num_models)


def _gram_normal_eq(kernel: Kernel, x: Array, y: Array, xm: Array, *,
                    tile: int | None, autotuned: bool, backend: str | None,
                    interpret: bool | None, accumulator: str,
                    precision: str = "fp32",
                    finalize: bool = True) -> tuple[Array, Array]:
    """The (G, rhs) accumulation behind `fit_streaming[_multi]`.

    When the tile came from the autotuner (`autotuned=True`, i.e. the caller
    passed ``tile=None``) and the call is a plain single-device XLA stream
    made outside any trace, the accumulation runs through a plan-keyed
    compiled executable (`tuning.cached_executable`) — repeated fits at one
    shape skip re-tracing the scan, which costs as much as the tile choice
    saves.  The jitted scan lowers to the same HLO as the eager one, so the
    result stays bit-equal to the explicit-tile call (locked in
    tests/test_autotune.py); explicit tiles always take the eager path.
    """
    from repro.kernels import dispatch

    if (autotuned and tile is not None
            and dispatch.resolve(backend) == "xla"
            and streaming.row_shard_count(x.shape) == 1
            and jax.core.trace_state_clean()):
        from repro import tuning
        key = ("gram_normal_eq", kernel, x.shape, y.shape, xm.shape,
               str(x.dtype), str(y.dtype), tile, accumulator, precision,
               finalize)
        try:
            hash(key)
        except TypeError:   # kernel with array-valued params: stay eager
            pass
        else:
            fn = tuning.cached_executable(
                key,
                lambda: lambda x_, y_, xm_: streaming_normal_eq(
                    kernel, x_, y_, xm_, tile=tile, backend=backend,
                    interpret=interpret, accumulator=accumulator,
                    precision=precision, finalize=finalize))
            return fn(x, y, xm)
    return streaming_normal_eq(kernel, x, y, xm, tile=tile, backend=backend,
                               interpret=interpret, accumulator=accumulator,
                               precision=precision, finalize=finalize)


def scan_normal_eq(kernel: Kernel, x: Array, xm: Array, w: Array,
                   *, tile: int | None = None, accumulator: str = "plain",
                   finalize: bool = True, init_state: Any = None,
                   return_state: bool = False,
                   precision: str | None = "fp32") -> tuple[Array, Array]:
    """(K_nm^T K_nm, K_nm^T w) accumulated over `tile`-row slabs.

    The (tile, m) kernel slab is rebuilt in registers each step and dies
    there; peak memory is O(tile * m + m^2), independent of n.  Tiling,
    padding and the accumulation strategy live in `repro.core.streaming`
    ("plain" is bit-equal to the historical hand-rolled lax.scan,
    "compensated" carries a two-float error sum across tiles).  This is the
    XLA backend of `repro.kernels.dispatch.gram_accumulate`; the Pallas
    `gram` kernel computes the same quantity tile-fused on TPU.
    ``tile=None`` autotunes the slab size (`repro.tuning` — same numbers
    as passing the resolved integer explicitly).  `finalize=False` returns
    the raw accumulator state for a mesh psum; ``init_state=`` continues
    the scan carry from a previously returned raw state (the incremental
    absorb — a tile-aligned chain of these is bit-equal to one
    uninterrupted fold, see `streaming.tile_reduce`).

    ``w`` may be (n,) or (n, k) — extra response columns ride the same
    pass (rhs matches: (m,) or (m, k)).  ``precision`` picks the
    G-contraction mode (`repro.core.precision`): "fp32" is literally the
    historical single dot_general; the bf16 modes are the XLA split-dot
    twin of the Pallas bf16 kernel (slow on CPU, parity-testable anywhere).
    """
    tile, precision = _resolve_gram_exec(tile, precision, x, xm, "xla",
                                         accumulator)
    m = xm.shape[0]
    acc = jnp.promote_types(x.dtype, jnp.float32)  # f64 under enable_x64

    def emit(xi, wi):
        k = kernel_matrix(kernel, xi, xm).astype(acc)  # (tile, m)
        return (precision_mod.split_dot(k, k, (((0,), (0,)), ((), ())),
                                        precision=precision, acc=acc),
                jax.lax.dot_general(k, wi, (((0,), (0,)), ((), ())),
                                    preferred_element_type=acc))

    init = (jnp.zeros((m, m), acc), jnp.zeros((m,) + w.shape[1:], acc))
    return streaming.tile_reduce(emit, x, (w.astype(acc),), tile=tile,
                                 init=init, accumulator=accumulator,
                                 pad="sentinel", finalize=finalize,
                                 init_state=init_state,
                                 return_state=return_state)


def streaming_normal_eq(kernel: Kernel, x: Array, y: Array, xm: Array,
                        *, tile: int | None = None,
                        backend: str | None = None,
                        interpret: bool | None = None,
                        accumulator: str = "plain",
                        finalize: bool = True,
                        precision: str | None = None) -> tuple[Array, Array]:
    """Mesh-aware (G, rhs): shards rows over the "rows" logical axis.

    With an active `repro.distributed.sharding` mesh whose "rows" rule maps
    to a mesh axis that divides n, each device accumulates its local row
    slab and the accumulator state is psum-reduced (`streaming.mesh_reduce`
    — the compensated (hi, lo) pair crosses the collective un-collapsed).
    Otherwise (no mesh, or indivisible n) this is exactly the single-device
    accumulation.  ``tile=None`` autotunes — resolved HERE, eagerly, so
    any tuning micro-benchmark runs outside the shard_map trace and every
    chip executes the same plan.
    """
    from repro.kernels import dispatch

    tile, precision = _resolve_gram_exec(tile, precision, x, xm, backend,
                                         accumulator)

    def local(x_loc, w_loc, xm_rep):
        return dispatch.gram_accumulate(kernel, x_loc, xm_rep, w_loc,
                                        backend=backend, tile=tile,
                                        interpret=interpret,
                                        accumulator=accumulator,
                                        finalize=False, precision=precision)

    return streaming.mesh_reduce(local, (x, y), (xm,),
                                 accumulator=accumulator, finalize=finalize)


# ------------------------------------------------------- first-class state --

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class NormalEqState:
    """First-class normal-equation accumulator state (a jax pytree).

    Bundles the raw (G, rhs) strategy state (`accstate.AccState` — rows
    and per-chip scan steps ride along), the landmark set it was built
    against, and the cached O(m^2) K_mm, plus the static execution knobs
    every later absorb must reuse.  Monoid ops: `normal_eq_init` /
    `normal_eq_absorb` / `normal_eq_merge` / `normal_eq_decay` /
    `solve_from_state`.  All array members are leaves, so the state
    round-trips through `checkpoint.Manager` and psums unchanged; the
    exec knobs are static aux data.
    """

    acc: accstate.AccState      # value = raw ((m,m), (m,[k])) strategy state
    landmarks: Array            # (m, d)
    landmark_idx: Array         # (m,) indices into the ORIGINAL training set
    k_mm: Array                 # (m, m) kernel Gram of the landmarks
    tile: int | None = None
    backend: str | None = None
    interpret: bool | None = None
    accumulator: str = "plain"
    precision: str | None = "fp32"

    def tree_flatten(self):
        leaves = (self.acc, self.landmarks, self.landmark_idx, self.k_mm)
        aux = (self.tile, self.backend, self.interpret, self.accumulator,
               self.precision)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        acc, landmarks, landmark_idx, k_mm = leaves
        tile, backend, interpret, accumulator, precision = aux
        return cls(acc=acc, landmarks=landmarks, landmark_idx=landmark_idx,
                   k_mm=k_mm, tile=tile, backend=backend, interpret=interpret,
                   accumulator=accumulator, precision=precision)


def normal_eq_init(kernel: Kernel, landmarks: Array,
                   landmark_idx: Array | None = None, *,
                   rhs_cols: int | None = None,
                   dtype=jnp.float32,
                   tile: int | None = None,
                   backend: str | None = None,
                   interpret: bool | None = None,
                   accumulator: str = "plain",
                   precision: str | None = "fp32") -> NormalEqState:
    """The identity state for a fixed landmark set (zero G/rhs, zero rows).

    ``rhs_cols=None`` sizes rhs as (m,) — the single-response fit;
    an integer widens it to (m, rhs_cols) (the scored-moment stream).
    ``tile=None`` defers tile resolution to each absorb (resolved per
    chunk shape); pass the resolved integer to pin one plan for the
    stream's lifetime — what `fit_streaming(..., return_state=True)` does.
    """
    _require_sentinel_safe(kernel)
    m = landmarks.shape[0]
    acc_dtype = jnp.promote_types(dtype, jnp.float32)
    rhs_shape = (m,) if rhs_cols is None else (m, int(rhs_cols))
    zeros = (jnp.zeros((m, m), acc_dtype), jnp.zeros(rhs_shape, acc_dtype))
    if landmark_idx is None:
        landmark_idx = jnp.arange(m)
    return NormalEqState(
        acc=accstate.init(accumulator, zeros),
        landmarks=landmarks, landmark_idx=landmark_idx,
        k_mm=kernel_matrix(kernel, landmarks).astype(acc_dtype),
        tile=tile, backend=backend, interpret=interpret,
        accumulator=accumulator, precision=precision)


def normal_eq_absorb(kernel: Kernel, state: NormalEqState, x: Array,
                     y: Array) -> NormalEqState:
    """Fold a new (x, y) chunk into the state — O(chunk * m), old tiles
    untouched.

    On a single-device XLA stream the scan carry CONTINUES from the saved
    state (`tile_reduce(init_state=...)`), so absorbing a stream in
    tile-aligned chunks is bit-equal to one uninterrupted fold.  Under an
    active mesh (or the Pallas backend, whose VMEM accumulator cannot be
    seeded) the chunk is reduced fresh and merged in — same monoid, merge
    tolerance instead of bit equality.
    """
    from repro.kernels import dispatch

    _require_sentinel_safe(kernel)
    xm = state.landmarks
    tile, precision = _resolve_gram_exec(state.tile, state.precision, x, xm,
                                         state.backend, state.accumulator)
    n = x.shape[0]
    single = (streaming.row_shard_count(x.shape) == 1
              and dispatch.resolve(state.backend) == "xla")
    if single:
        value = scan_normal_eq(kernel, x, xm, y, tile=tile,
                               accumulator=state.accumulator,
                               precision=precision,
                               init_state=state.acc.value, return_state=True)
    else:
        fresh = streaming_normal_eq(kernel, x, y, xm, tile=tile,
                                    backend=state.backend,
                                    interpret=state.interpret,
                                    accumulator=state.accumulator,
                                    finalize=False, precision=precision)
        value = streaming.get(state.accumulator).merge(state.acc.value, fresh)
    acc = accstate.AccState(
        value=value, rows=state.acc.rows + n,
        steps=state.acc.steps + _scan_steps(n, tile, x, state.backend),
        spec=state.acc.spec)
    return dataclasses.replace(state, acc=acc)


def normal_eq_merge(a: NormalEqState, b: NormalEqState) -> NormalEqState:
    """Combine two states built against the SAME landmark set (caller's
    contract — landmark identity is not re-verified here, it would block
    on device scalars inside hot loops)."""
    return dataclasses.replace(a, acc=accstate.merge(a.acc, b.acc))


def normal_eq_decay(state: NormalEqState, gamma: float) -> NormalEqState:
    """Exponential forgetting in the (hi, lo) domain (`accstate.decay`)."""
    return dataclasses.replace(state, acc=accstate.decay(state.acc, gamma))


def solve_from_state(state: NormalEqState, lam: float, *,
                     jitter: float = 1e-6,
                     weights: Array | None = None) -> NystromFit:
    """Finalize the accumulated (G, rhs) and re-run the O(m^3) solve.

    This is the ONLY per-update cost a `partial_fit` pays beyond absorbing
    the new tiles: n is the state's effective (possibly decayed) row count
    and the truncation floor uses the absorbed per-chip step count, so a
    state built by one uninterrupted fold solves bit-equal to the one-shot
    `fit_streaming`.
    """
    g, rhs = accstate.finalize(state.acc)
    if rhs.ndim != 1:
        rhs = rhs[:, 0]
    n = accstate.rows_of(state.acc)
    steps = accstate.steps_of(state.acc)
    k_mm = state.k_mm
    if weights is not None:
        g, rhs, k_mm = weighted_normal_eq(g, rhs, k_mm, weights)
    beta = solve_normal_eq(g, rhs, k_mm, n, lam, jitter=jitter,
                           eps_scale=_eff_eps_scale(
                               state.accumulator, steps, state.precision))
    if weights is not None:
        beta = weights.astype(beta.dtype) * beta
    return NystromFit(beta=beta, landmarks=state.landmarks,
                      landmark_idx=state.landmark_idx, lam=lam)


def fit_streaming(
    kernel: Kernel,
    x: Array,
    y: Array,
    lam: float,
    landmark_idx: Array,
    *,
    tile: int | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
    jitter: float = 1e-6,
    weights: Array | None = None,
    accumulator: str = "plain",
    precision: str | None = None,
    return_state: bool = False,
) -> NystromFit:
    """`fit_from_landmarks` without ever materializing K_nm.

    Matches the dense solve to fp32 reduction-order tolerance
    (tests/test_streaming_nystrom.py: <= 1e-4 relative on beta).
    `weights` applies the without-replacement importance correction as a
    post-accumulation O(m^2) column rescaling (`weighted_normal_eq`) — the
    row stream itself is weight-free, so the Pallas/XLA accumulation kernels
    are untouched.  `accumulator="compensated"` streams the Gram through the
    two-float error-carrying sum (`repro.core.streaming`) and lowers the
    solve's spectral noise floor to match — fp32 then keeps whitened
    directions the plain accumulation must truncate.  ``precision`` picks
    the Gram-contraction mode (`repro.core.precision`; None resolves it
    jointly with an autotuned tile, or to "fp32" when the tile is pinned)
    and scales the solve's truncation floor by `precision.EPS_SCALE`.

    Internally this IS init + absorb + solve over a `NormalEqState`: the
    one pass over x lands in first-class accumulator state (through the
    same plan-keyed cached executable as before — the raw state is the
    pre-finalize scan carry, and finalizing it outside is the identical
    elementwise op), and the solve is `solve_from_state`.  Pass
    ``return_state=True`` to also get the state back for incremental
    `normal_eq_absorb` / `normal_eq_decay` updates — (fit, state) then.
    """
    _require_sentinel_safe(kernel)
    n = x.shape[0]
    xm = jnp.take(x, landmark_idx, axis=0)
    autotuned = tile is None
    tile, precision = _resolve_gram_exec(tile, precision, x, xm, backend,
                                         accumulator)
    raw = _gram_normal_eq(kernel, x, y, xm, tile=tile,
                          autotuned=autotuned, backend=backend,
                          interpret=interpret, accumulator=accumulator,
                          precision=precision, finalize=False)
    acc_dtype = jnp.promote_types(x.dtype, jnp.float32)
    state = NormalEqState(
        acc=accstate.wrap(accumulator, raw, rows=n,
                          steps=_scan_steps(n, tile, x, backend)),
        landmarks=xm, landmark_idx=landmark_idx,
        # k_mm is O(m^2) work — the core path keeps it in the input dtype,
        # which the dense solve also uses (dtype parity beats MXU here).
        k_mm=kernel_matrix(kernel, xm).astype(acc_dtype),
        tile=tile, backend=backend, interpret=interpret,
        accumulator=accumulator, precision=precision)
    fit_ = solve_from_state(state, lam, jitter=jitter, weights=weights)
    return (fit_, state) if return_state else fit_


def fit_streaming_multi(
    kernel: Kernel,
    x: Array,
    y: Array,
    lams: Sequence[float],
    landmark_idx: Array,
    *,
    tile: int | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
    jitter: float = 1e-6,
    weights: Array | None = None,
    accumulator: str = "plain",
    precision: str | None = None,
) -> list[NystromFit]:
    """`fit_streaming` over a lam grid at ONE Gram-accumulation cost.

    G = K_nm^T K_nm and rhs = K_nm^T y are lam-independent, and so is the
    weighted column rescaling — only the O(m^3) whitened solve depends on
    lam.  So the sweep streams the rows once (one psum per array under an
    active mesh, exactly like the single-lam fit) and re-solves per lam via
    `solve_normal_eq_multi`.  Fit i is bit-equal to
    `fit_streaming(kernel, x, y, lams[i], landmark_idx, ...)`; cost is
    O(n m (d + m)) + L·O(m^3) instead of L·O(n m (d + m)) — the whole point
    of `pipeline.stages.CalibrateStage`.
    """
    _require_sentinel_safe(kernel)
    n = x.shape[0]
    xm = jnp.take(x, landmark_idx, axis=0)
    autotuned = tile is None
    tile, precision = _resolve_gram_exec(tile, precision, x, xm, backend,
                                         accumulator)
    g, rhs = _gram_normal_eq(kernel, x, y, xm, tile=tile,
                             autotuned=autotuned, backend=backend,
                             interpret=interpret, accumulator=accumulator,
                             precision=precision)
    k_mm = kernel_matrix(kernel, xm).astype(g.dtype)
    if weights is not None:
        g, rhs, k_mm = weighted_normal_eq(g, rhs, k_mm, weights)
    betas = solve_normal_eq_multi(
        g, rhs, k_mm, n, lams, jitter=jitter,
        eps_scale=_eff_eps_scale(accumulator,
                                 _scan_steps(n, tile, x, backend), precision))
    if weights is not None:
        betas = weights.astype(betas.dtype)[None, :] * betas
    return [NystromFit(beta=betas[i], landmarks=xm, landmark_idx=landmark_idx,
                       lam=float(lam)) for i, lam in enumerate(lams)]


def fit_streaming_scored(
    kernel: Kernel,
    x: Array,
    y: Array,
    lam: float,
    landmark_idx: Array,
    *,
    f_star: Array | None = None,
    tile: int | None = None,
    backend: str | None = None,
    interpret: bool | None = None,
    jitter: float = 1e-6,
    weights: Array | None = None,
    accumulator: str = "plain",
    precision: str | None = None,
    return_state: bool = False,
) -> tuple[NystromFit, dict]:
    """`fit_streaming` + the in-sample score moments in ONE pass over x.

    The in-sample MSE and risk are quadratic forms in quantities the Gram
    stream already touches:

        sum_i (f(x_i) - t_i)^2 = beta^T G beta - 2 beta^T (K_nm^T t) + t^T t

    for targets t = y (MSE) or t = f_star (risk), with G = K_nm^T K_nm.
    So instead of a separate predict pass, the extra responses ride the rhs
    slot of the normal-equation stream as additional columns of w — one
    widened (n, 1+r) aux through the SAME tile scan (`multi_reduce`
    semantics via the widened rhs; Pallas carries the columns in its rhs
    block).  Returns ``(fit, moments)`` where moments holds the
    *unweighted* G, the per-target K_nm^T t columns, and the host-f64
    t^T t scalars — `pipeline.stages.ScoreStage` assembles the scores in
    f64 (the two big terms cancel to ~n * mse, so f64 assembly keeps ~7
    significant digits of the score; locked at rtol 2e-3 against the
    predict-pass scores in tests/test_multi_reduce.py).

    Eager-only (host-computes t^T t); the pipeline's `evaluate()` is the
    intended caller.  ``return_state=True`` appends a single-response
    `NormalEqState` — the widened raw state with the extra score columns
    sliced away — as a third element: (fit, moments, state).
    """
    _require_sentinel_safe(kernel)
    n = x.shape[0]
    xm = jnp.take(x, landmark_idx, axis=0)
    autotuned = tile is None
    tile, precision = _resolve_gram_exec(tile, precision, x, xm, backend,
                                         accumulator)
    cols = [jnp.asarray(y, x.dtype)]
    if f_star is not None:
        cols.append(jnp.asarray(f_star, x.dtype))
    wmat = jnp.stack(cols, axis=1)                       # (n, 1 + r)
    raw = _gram_normal_eq(kernel, x, wmat, xm, tile=tile,
                          autotuned=autotuned, backend=backend,
                          interpret=interpret, accumulator=accumulator,
                          precision=precision, finalize=False)
    g, rr = streaming.get(accumulator).finalize(raw)
    rhs = rr[:, 0]
    y64 = np.asarray(y, np.float64)
    moments = {"g": g, "rhs_y": rr[:, 0], "n_eval": int(n),
               "y_sq": float(y64 @ y64),
               "rhs_f": None, "f_sq": None}
    if f_star is not None:
        f64 = np.asarray(f_star, np.float64)
        moments["rhs_f"] = rr[:, 1]
        moments["f_sq"] = float(f64 @ f64)
    k_mm = kernel_matrix(kernel, xm).astype(g.dtype)
    g_s, rhs_s, k_mm_s = g, rhs, k_mm
    if weights is not None:
        g_s, rhs_s, k_mm_s = weighted_normal_eq(g, rhs, k_mm, weights)
    beta = solve_normal_eq(g_s, rhs_s, k_mm_s, n, lam, jitter=jitter,
                           eps_scale=_eff_eps_scale(
                               accumulator, _scan_steps(n, tile, x, backend),
                               precision))
    if weights is not None:
        beta = weights.astype(beta.dtype) * beta
    fit_ = NystromFit(beta=beta, landmarks=xm, landmark_idx=landmark_idx,
                      lam=lam)
    if not return_state:
        return fit_, moments

    def _col0(tree):
        g_, rr_ = tree
        return (g_, rr_[:, 0])

    if streaming.get(accumulator).name == "compensated":
        hi, lo = raw
        value = (_col0(hi), _col0(lo))
    else:
        value = _col0(raw)
    state = NormalEqState(
        acc=accstate.wrap(accumulator, value, rows=n,
                          steps=_scan_steps(n, tile, x, backend)),
        landmarks=xm, landmark_idx=landmark_idx, k_mm=k_mm,
        tile=tile, backend=backend, interpret=interpret,
        accumulator=accumulator, precision=precision)
    return fit_, moments, state


def val_mse_streaming_multi(kernels: Sequence[Kernel],
                            fits_by_h: Sequence[Sequence[NystromFit]],
                            x_val: Array, y_val: Array, *,
                            tile: int | None = None,
                            backend: str | None = None,
                            precision: str | None = None) -> Array:
    """Validation MSE for H bandwidth candidates x L lams in ONE x_val pass.

    The calibration sweep historically re-streamed x_val once per bandwidth
    (H `predict_streaming_multi` calls).  The per-h predictions only meet
    at the end (a mean of squared errors), so the H reductions fuse into a
    single `streaming.multi_reduce` scan: slot h builds its kernel tile
    K_h(x_tile, X_m^h) once, applies all L betas as one matmul, and emits
    the per-lam squared-error sums.  Returns the (H, L) val-MSE matrix;
    each entry matches the sequential predict-then-mean to fp32
    reduction-order tolerance (same products, tile-major instead of
    row-major summation).  Mesh behavior: row slabs of x_val reduce
    locally, sums psum across chips (`streaming.mesh_reduce`).
    """
    from repro.kernels import dispatch

    if len(kernels) != len(fits_by_h):
        raise ValueError("one kernel per bandwidth candidate required")
    for k in kernels:
        _require_sentinel_safe(k)
    n_val = x_val.shape[0]
    big = len(fits_by_h)
    xms = tuple(fits[0].landmarks for fits in fits_by_h)
    betas = tuple(jnp.stack([f.beta for f in fits], axis=1)
                  for fits in fits_by_h)                  # (m, L) each
    acc = jnp.promote_types(x_val.dtype, jnp.float32)
    tile = _resolve_predict_tile(tile, x_val, xms[0], backend)
    multi = streaming.MultiAccumulator(("plain",) * big)
    rep: list[Array] = []
    for xm, b in zip(xms, betas):
        rep += [xm, b]

    def local(xv, yv, *rep_args):
        def emit(xt, yt):
            outs = []
            for h in range(big):
                xm, b = rep_args[2 * h], rep_args[2 * h + 1]
                k = dispatch.kernel_matrix(kernels[h], xt, xm,
                                           backend=backend).astype(acc)
                # sentinel rows give k == 0 and carry zero-padded y, so
                # padded errors are exactly (0 - 0)^2 = 0.
                e = _apply_beta(k, b.astype(acc), precision) \
                    - yt[:, None].astype(acc)
                outs.append(jnp.sum(e * e, axis=0))       # (L,)
            return tuple(outs)

        inits = tuple(jnp.zeros((b.shape[1],), acc) for b in betas)
        return streaming.multi_reduce(emit, xv, (yv,), tile=tile,
                                      inits=inits, pad="sentinel",
                                      finalize=False)

    sums = streaming.mesh_reduce(local, (x_val, y_val), tuple(rep),
                                 accumulator=multi, finalize=True)
    return jnp.stack(sums) / n_val


def predict_streaming_multi(kernel: Kernel, fits: Sequence[NystromFit],
                            x_new: Array, *, tile: int | None = None,
                            backend: str | None = None,
                            precision: str | None = None) -> Array:
    """Batched predict for several fits SHARING one landmark set: (L, n_new).

    The kernel tile K(x_tile, X_m) is the expensive part of a predict and is
    beta-independent, so a lam sweep evaluates it once per tile and applies
    all betas as one (tile, m) x (m, L) matmul.  All fits must share
    `landmarks` (the CalibrateStage invariant); mesh behavior matches
    `predict_streaming` (purely local row slabs, `streaming.mesh_map`).
    """
    from repro.kernels import dispatch

    _require_sentinel_safe(kernel)
    betas = jnp.stack([f.beta for f in fits], axis=1)     # (m, L)
    xm = fits[0].landmarks
    tile = _resolve_predict_tile(tile, x_new, xm, backend)

    def local(x_loc, xm, betas):
        def one(xt):
            k = dispatch.kernel_matrix(kernel, xt, xm, backend=backend)
            return _apply_beta(k, betas, precision)       # (t, L)

        return streaming.tile_map(one, x_loc, tile=tile)

    return streaming.mesh_map(local, x_new, (xm, betas), out_rank=2).T


def predict_streaming(kernel: Kernel, fit_: NystromFit, x_new: Array,
                      *, tile: int | None = None,
                      backend: str | None = None,
                      precision: str | None = None) -> Array:
    """Batched predict: O(tile * m) memory, any n_new.

    ``tile=None`` autotunes the slab size (`repro.tuning` via
    `dispatch.resolve_tile`) — pure shape plumbing, identical numbers to
    passing the resolved tile explicitly.

    Mesh-aware like the solve: under an active `repro.distributed.sharding`
    mesh whose "rows" rule maps to a mesh axis that divides n_new, each
    device predicts its local row slab against the replicated landmarks and
    beta (no collective — predict is embarrassingly row-parallel,
    `streaming.mesh_map`).  Otherwise this is exactly the single-device
    batched predict (`streaming.tile_map` row slabs).
    """
    from repro.kernels import dispatch

    _require_sentinel_safe(kernel)
    tile = _resolve_predict_tile(tile, x_new, fit_.landmarks, backend)

    def local(x_loc, xm, beta):
        def one(xt):
            k = dispatch.kernel_matrix(kernel, xt, xm, backend=backend)
            return _apply_beta(k, beta, precision)

        return streaming.tile_map(one, x_loc, tile=tile)

    return streaming.mesh_map(local, x_new, (fit_.landmarks, fit_.beta),
                              out_rank=1)


# ------------------------------------------------- many-model batched fits --

class BatchedNystromFit(NamedTuple):
    """B independent KRR models fit in one program (the many-tenant case).

    Model b is the subset-of-regressors fit for (landmark set b, lam b,
    response column b) — exactly what B separate `fit_streaming` calls
    would produce, batched along a leading model axis that the "models"
    sharding rule may split across a 2D mesh's model axis.
    """

    beta: Array          # (B, m)
    landmarks: Array     # (B, m, d) per-model landmark inputs
    landmark_idx: Array  # (B, m) indices into the training set
    lams: Array          # (B,) per-model regularizers


def _models_per_chip(num_models: int) -> int:
    """Locally held model count once the "models" rule sharded the batch —
    the `num_models` the tile planner must budget for
    (`dispatch.resolve_plan(num_models=...)`: each tile step holds one
    (tile, m) kernel slab per local model)."""
    return max(1, num_models // streaming.model_shard_count(num_models))


def solve_normal_eq_batched(gs: Array, rhss: Array, k_mms: Array, n: int,
                            lams: Array, jitter: float = 1e-6,
                            eps_scale: float = 1.0) -> Array:
    """Per-model whitened solves over a leading model axis: (B, m) betas.

    Unlike the lam sweep (`solve_normal_eq_multi`, which shares one
    eigendecomposition), every model here owns its landmark set, so each
    gets its own O(m^3) eigh — vmapped into one batched LAPACK/XLA call.
    Under an active mesh whose "models" rule divides B the batch SHARDS
    over the model axis (each chip column decomposes and solves only its
    B / M models); otherwise the vmap runs replicated.  Model b matches
    `solve_normal_eq(gs[b], rhss[b], k_mms[b], n, lams[b])` to reduction-
    order tolerance (the n*lam product rounds on device here).
    """
    eps = float(jnp.finfo(gs.dtype).eps) * eps_scale
    # n * lam in HOST float64, rounded once to the Gram dtype — the same
    # rounding the scalar path's weak promotion applies (`_whitened_solve`
    # with a python lam).  A device-side f32 product rounds differently at
    # the ulp level, which is enough to flip the truncation threshold tau
    # on near-threshold eigendirections and blow the per-model beta parity
    import numpy as _np
    nlams = jnp.asarray(
        _np.asarray(n, _np.float64) * _np.asarray(jax.device_get(lams),
                                                  _np.float64),
        gs.dtype)

    def batch(g_b, rhs_b, k_b, nlam_b):
        def one(g, rhs, k_mm, nlam):
            evals, evecs = jnp.linalg.eigh(k_mm)
            return _whitened_solve_nlam(g, rhs, evals, evecs, jnp.trace(g),
                                        nlam, jitter, eps)

        return jax.vmap(one)(g_b, rhs_b, k_b, nlam_b)

    mesh, model_axes = streaming._active_axes("models", (gs.shape[0],))
    if mesh is None:
        return batch(gs, rhss, k_mms, nlams)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    return shard_map(
        batch, mesh=mesh,
        in_specs=(P(model_axes), P(model_axes), P(model_axes),
                  P(model_axes)),
        out_specs=P(model_axes))(gs, rhss, k_mms, nlams)


def fit_streaming_batched(
    kernel: Kernel,
    x: Array,
    ys: Array,
    lams: Array | Sequence[float] | float,
    landmark_sets: Array,
    *,
    tile: int | None = None,
    backend: str | None = None,
    jitter: float = 1e-6,
    weights: Array | None = None,
    accumulator: str = "plain",
    precision: str | None = None,
) -> BatchedNystromFit:
    """Fit B independent KRR models in ONE pass over the shared row stream.

    The many-tenant shape: every model shares the input rows x but owns its
    response column (``ys``: (B, n), or (n,) broadcast to all models), its
    landmark set (``landmark_sets``: (B, m) indices into x) and its
    regularizer (``lams``: scalar or (B,)).  A python loop over
    `fit_streaming` would stream x B times; here the per-model normal
    equations G_b = K_nm(b)^T K_nm(b), rhs_b = K_nm(b)^T y_b accumulate
    through ONE `streaming.mesh_reduce` tile scan — each tile's rows are
    loaded once and contracted against all locally held models (vmap over
    the model axis inside the scan body), so the stream cost is paid once
    and the arithmetic scales as B times the per-model contraction only.

    Mesh semantics (`repro.distributed.sharding`): rows shard over "rows"
    (data axis, psummed), models over "models" (model axis, independent) —
    on a 2D (data, model) mesh a B=256 batch on M=16 model shards holds 16
    models per chip column.  ``ys`` is the dual-sharded (rows, models)
    operand (transposed internally); landmark sets and the per-model solves
    (`solve_normal_eq_batched`) shard over the model axis.  With no mesh
    (or a 1D data mesh) everything model-wise is replicated and only the
    rows shard — the transparent-fallback contract.

    ``weights`` ((B, m), optional) applies the without-replacement
    importance correction per model (`weighted_normal_eq`).  The batched
    stream always runs on the XLA engine (the Pallas gram kernel is
    single-model; ``backend`` still steers tile planning), with the tile
    planned against the widened m * B_loc slab
    (`dispatch.resolve_plan(num_models=...)`).
    Model b matches `fit_streaming(kernel, x, ys[b], lams[b],
    landmark_sets[b])` to fp32 reduction-order tolerance (locked in
    tests/test_mesh2d.py, benched in bench_pipeline --multimodel).
    """
    _require_sentinel_safe(kernel)
    n, d = x.shape
    landmark_sets = jnp.asarray(landmark_sets)
    if landmark_sets.ndim != 2:
        raise ValueError(f"landmark_sets must be (B, m) indices, got shape "
                         f"{landmark_sets.shape}")
    big, m = landmark_sets.shape
    ys = jnp.asarray(ys, x.dtype)
    if ys.ndim == 1:
        ys = jnp.broadcast_to(ys[None, :], (big, n))
    if ys.shape != (big, n):
        raise ValueError(f"ys must be (B={big}, n={n}) or (n,), got "
                         f"{ys.shape}")
    lams = jnp.broadcast_to(jnp.asarray(lams, jnp.float32), (big,))
    xms = jnp.take(x, landmark_sets, axis=0)              # (B, m, d)
    acc_dtype = jnp.promote_types(x.dtype, jnp.float32)

    tile, precision = _resolve_gram_exec(tile, precision, x, xms[0], "xla",
                                         accumulator,
                                         num_models=_models_per_chip(big))
    ys_t = ys.T.astype(acc_dtype)                         # (n, B) dual-shard

    def local(x_loc, yst_loc, xms_loc):
        b_loc = xms_loc.shape[0]

        def emit(xt, yt):                                 # (t, d), (t, b_loc)
            def one(xm_b, y_col):
                k = kernel_matrix(kernel, xt, xm_b).astype(acc_dtype)
                g = precision_mod.split_dot(k, k, (((0,), (0,)), ((), ())),
                                            precision=precision,
                                            acc=acc_dtype)
                r = jax.lax.dot_general(k, y_col, (((0,), (0,)), ((), ())),
                                        preferred_element_type=acc_dtype)
                return g, r

            return jax.vmap(one, in_axes=(0, 1))(xms_loc, yt)

        init = (jnp.zeros((b_loc, m, m), acc_dtype),
                jnp.zeros((b_loc, m), acc_dtype))
        return streaming.tile_reduce(emit, x_loc, (yst_loc,), tile=tile,
                                     init=init, accumulator=accumulator,
                                     pad="sentinel", finalize=False)

    gs, rhss = streaming.mesh_reduce(local, (x,), model_args=(xms,),
                                     row_model_args=(ys_t,),
                                     accumulator=accumulator, finalize=True)
    k_mms = jax.vmap(lambda xm: kernel_matrix(kernel, xm))(
        xms).astype(acc_dtype)
    if weights is not None:
        gs, rhss, k_mms = jax.vmap(weighted_normal_eq)(
            gs, rhss, k_mms, weights)
    betas = solve_normal_eq_batched(
        gs, rhss, k_mms, n, lams, jitter=jitter,
        eps_scale=_eff_eps_scale(accumulator,
                                 _scan_steps(n, tile, x, "xla"), precision))
    if weights is not None:
        betas = weights.astype(betas.dtype) * betas
    return BatchedNystromFit(beta=betas, landmarks=xms,
                             landmark_idx=landmark_sets, lams=lams)


def predict_streaming_batched(kernel: Kernel, fit_: BatchedNystromFit,
                              x_new: Array, *, tile: int | None = None,
                              backend: str | None = None,
                              precision: str | None = None) -> Array:
    """Predict all B models on shared query rows in one pass: (B, n_new).

    Unlike `predict_streaming_multi` (many betas, ONE landmark set) every
    model here owns its landmarks, so each tile evaluates B_loc kernel
    slabs — rows still stream once.  Mesh layout matches the batched fit:
    rows shard over "rows", models over "models"
    (`streaming.mesh_map(model_args=...)` — output dim 0 rides the model
    axis, dim 1 the rows); no collective, predict is row-parallel per model.
    """
    _require_sentinel_safe(kernel)
    xms, betas = fit_.landmarks, fit_.beta                # (B, m, d), (B, m)
    big, m, d = xms.shape
    tile = _resolve_predict_tile(tile, x_new, xms[0], backend,
                                 num_models=_models_per_chip(big))

    def local(x_loc, xms_loc, betas_loc):
        def one(xt):                                      # (t, d) -> (t, B_loc)
            def per_model(xm_b, beta_b):
                k = kernel_matrix(kernel, xt, xm_b)
                return _apply_beta(k, beta_b, precision)  # (t,)

            return jax.vmap(per_model, in_axes=(0, 0),
                            out_axes=1)(xms_loc, betas_loc)

        return streaming.tile_map(one, x_loc, tile=tile).T

    return streaming.mesh_map(local, x_new, model_args=(xms, betas),
                              out_rank=2)
