"""Nystrom-approximated KRR (paper §2.3), dense and streaming.

With landmark columns S, the Nystrom approximation L = K S (S^T K S)^+ S^T K
substituted into the KRR solution gives (Woodbury; derivation in DESIGN
history) the subset-of-regressors form

    f_L(x) = K(x, X_S) beta,
    beta   = (K_nm^T K_nm + n lam K_mm)^{-1} K_nm^T y,

which needs O(n m) kernel evaluations and an O(m^3) solve — the  O(n d_stat^2)
downstream cost that leverage estimation must not exceed.  L is invariant to
positive rescaling of S's columns, so with-replacement sampling needs no
1/sqrt(m q_i) reweighting here (duplicate columns land in the truncated
eigenspace of K_mm — see ``solve_normal_eq``).

Two solve paths:

  * ``fit_from_landmarks`` / ``fit`` — dense: materializes K_nm.  Simple and
    fine up to n ~ 1e5; it is also the parity oracle for the streaming path.
  * ``fit_streaming`` — accumulates G = K_nm^T K_nm and rhs = K_nm^T y over
    row tiles (lax.scan on the XLA backend, the fused Pallas ``gram`` kernel
    on TPU — see `repro.kernels.dispatch`), so peak memory is O(tile * m)
    regardless of n.  Under an active mesh (`repro.distributed.sharding`)
    the row stream is sharded over the "rows" logical axis (mesh axis
    "data" by default) and G/rhs are psum-reduced — a transparent no-op on
    a single device.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.kernels import (Kernel, kernel_matrix, pad_rows_sentinel,
                                round_up, sentinel_is_safe)
from repro.core.sampling import sample_with_replacement

Array = jax.Array


def _require_sentinel_safe(kernel: Kernel) -> None:
    """Reject kernels whose bandwidth defeats sentinel-row padding.

    Checked eagerly (kernel parameters are static); under a jit trace the
    concrete check is impossible, so it is skipped — the public entry points
    below are eager, which is where it matters.
    """
    try:
        ok = sentinel_is_safe(kernel)
    except (jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError):
        # array-conversion and float()-on-tracer raise different types
        return
    if not ok:
        raise ValueError(
            f"{kernel!r} does not vanish at the padding sentinel distance "
            "(~1e6); the streaming tile padding would corrupt the Gram. "
            "Normalize the inputs or shrink the kernel bandwidth.")


class NystromFit(NamedTuple):
    beta: Array          # (m,)
    landmarks: Array     # (m, d) landmark inputs
    landmark_idx: Array  # (m,) indices into the training set
    lam: float


def weighted_normal_eq(g: Array, rhs: Array, k_mm: Array,
                       weights: Array) -> tuple[Array, Array, Array]:
    """Rescale the SoR normal equations by landmark column weights.

    With W = diag(w), the weighted subset-of-regressors system uses columns
    Z = K_nm W:  (W G W + n lam W K_mm W) gamma = W rhs, beta = W gamma —
    the without-replacement path's importance correction
    (`sampling.sample_weighted_without_replacement`).  In exact arithmetic
    the SoR predictor is invariant to any positive column rescaling (beta
    absorbs W^{-1} twice), so this changes results only through fp32
    whitening/truncation order — a property the test suite locks in
    (test_sampling_weights.py::test_sor_solve_invariant_to_weight_rescaling).
    Returns the reweighted (G, rhs, K_mm); callers multiply the solved gamma
    by w to recover beta in the unweighted basis.
    """
    w = weights.astype(g.dtype)
    return (w[:, None] * g * w[None, :], w * rhs,
            w[:, None] * k_mm.astype(g.dtype) * w[None, :])


def _whitened_solve(g: Array, rhs: Array, evals: Array, evecs: Array,
                    g_max: Array, n: int, lam: float, jitter: float) -> Array:
    """The per-lam tail of `solve_normal_eq`: truncate + whiten + solve.

    Takes the lam-INDEPENDENT eigendecomposition of K_mm (and the trace
    upper bound on lambda_max(G)) as inputs, so a lam sweep pays the O(m^3)
    eigh once and only re-runs this O(m^3-but-tiny) tail per candidate —
    the op sequence per lam is identical to the single-lam solve, so the
    sweep is bit-equal to per-lam solves (locked in tests/test_calibrate.py).
    """
    m = evals.shape[0]
    eps = jnp.finfo(g.dtype).eps
    tau = jnp.maximum(jitter * evals[-1], eps * g_max / (n * lam))
    inv_sqrt = jnp.where(evals > tau, 1.0 / jnp.sqrt(jnp.maximum(evals, tau)),
                         0.0)
    w = evecs * inv_sqrt[None, :]                         # (m, m) whitener
    a = w.T @ g @ w
    b = w.T @ rhs
    gamma = jnp.linalg.solve(a + n * lam * jnp.eye(m, dtype=a.dtype), b)
    return w @ gamma


def solve_normal_eq(g: Array, rhs: Array, k_mm: Array, n: int, lam: float,
                    jitter: float = 1e-6) -> Array:
    """beta = (G + n lam K_mm)^{-1} rhs via spectrally-truncated whitening.

    The plain normal equations are numerically hopeless at scale: K_mm's
    eigenvalues decay to ~0 (smooth kernels, duplicated with-replacement
    landmarks), and accumulation noise in G — eps * lambda_max(G), which
    GROWS with n — is amplified by 1/eig through those directions until it
    swamps the n*lam regularizer.  Instead eigendecompose K_mm = U E U^T,
    whiten with W = U E^{-1/2} on the eigenspaces above a cutoff tau, and
    solve the well-conditioned (W^T G W + n lam I) gamma = W^T rhs,
    beta = W gamma.  tau is the larger of

      * jitter * lambda_max(K_mm)          — the usual relative floor, and
      * eps(dtype) * lambda_max(G)/(n lam) — the dtype's noise floor: below
        it the whitened G carries no signal, only amplified rounding error,

    so in fp32 at n = 1e6 the solve sheds exactly the directions fp32 cannot
    represent (matching the f64 solve's risk to ~1e-4), while in f64 the
    cutoff recedes and the solve is the textbook one.  Truncated directions
    are zeroed via masks, keeping every shape static (jit-safe).
    """
    evals, evecs = jnp.linalg.eigh(k_mm)
    # trace >= lambda_max for PSD G, and is tight here (G's spectrum is
    # dominated by the near-constant kernel component) — O(m) vs an O(m^3)
    # eigendecomposition for a quantity that only needs an upper bound.
    g_max = jnp.trace(g)
    return _whitened_solve(g, rhs, evals, evecs, g_max, n, lam, jitter)


def solve_normal_eq_multi(g: Array, rhs: Array, k_mm: Array, n: int,
                          lams: Sequence[float],
                          jitter: float = 1e-6) -> Array:
    """`solve_normal_eq` over a lam grid, sharing the eigendecomposition.

    The truncation cutoff tau depends on lam, so each candidate gets its own
    whitener — but the K_mm eigh and the G trace are lam-independent and run
    once.  Returns the (L, m) stack of betas, row i bit-equal to
    `solve_normal_eq(g, rhs, k_mm, n, lams[i])` (same op sequence).
    """
    evals, evecs = jnp.linalg.eigh(k_mm)
    g_max = jnp.trace(g)
    return jnp.stack([
        _whitened_solve(g, rhs, evals, evecs, g_max, n, float(lam), jitter)
        for lam in lams])


def fit_from_landmarks(
    kernel: Kernel,
    x: Array,
    y: Array,
    lam: float,
    landmark_idx: Array,
    jitter: float = 1e-6,
    weights: Array | None = None,
) -> NystromFit:
    n = x.shape[0]
    xm = x[landmark_idx]
    k_nm = kernel_matrix(kernel, x, xm)                   # (n, m)
    k_mm = kernel_matrix(kernel, xm)                      # (m, m)
    g = jax.lax.dot_general(k_nm, k_nm, (((0,), (0,)), ((), ())),
                            preferred_element_type=k_nm.dtype)
    rhs = k_nm.T @ y
    if weights is not None:
        g, rhs, k_mm = weighted_normal_eq(g, rhs, k_mm, weights)
    beta = solve_normal_eq(g, rhs, k_mm, n, lam, jitter=jitter)
    if weights is not None:
        beta = weights.astype(beta.dtype) * beta
    return NystromFit(beta=beta, landmarks=xm, landmark_idx=landmark_idx, lam=lam)


def fit(
    key: jax.Array,
    kernel: Kernel,
    x: Array,
    y: Array,
    lam: float,
    num_landmarks: int,
    probs: Array,
    jitter: float = 1e-6,
) -> NystromFit:
    """Sample landmarks ~ probs (with replacement, paper Thm 2) and solve."""
    idx = sample_with_replacement(key, probs, num_landmarks)
    return fit_from_landmarks(kernel, x, y, lam, idx, jitter=jitter)


def predict(kernel: Kernel, fit_: NystromFit, x_new: Array) -> Array:
    return kernel_matrix(kernel, x_new, fit_.landmarks) @ fit_.beta


def fitted(kernel: Kernel, fit_: NystromFit, x_train: Array) -> Array:
    """In-sample predictions f_L(x_i) (for the paper's R_n risk metric)."""
    return predict(kernel, fit_, x_train)


# ---------------------------------------------------------------- streaming --

def scan_normal_eq(kernel: Kernel, x: Array, xm: Array, w: Array,
                   *, tile: int = 8192) -> tuple[Array, Array]:
    """(K_nm^T K_nm, K_nm^T w) accumulated over `tile`-row slabs (lax.scan).

    The (tile, m) kernel slab is rebuilt in registers each step and dies
    there; peak memory is O(tile * m + m^2), independent of n.  This is the
    XLA backend of `repro.kernels.dispatch.gram_accumulate`; the Pallas
    `gram` kernel computes the same quantity tile-fused on TPU.
    """
    n, d = x.shape
    m = xm.shape[0]
    acc = jnp.promote_types(x.dtype, jnp.float32)  # f64 under enable_x64
    tile = min(tile, n)
    np_ = round_up(n, tile)
    xt = pad_rows_sentinel(x, np_).reshape(np_ // tile, tile, d)
    wt = jnp.pad(w.astype(acc), (0, np_ - n)).reshape(np_ // tile, tile)

    def step(carry, xw):
        g, r = carry
        xi, wi = xw
        k = kernel_matrix(kernel, xi, xm).astype(acc)  # (tile, m)
        g = g + jax.lax.dot_general(k, k, (((0,), (0,)), ((), ())),
                                    preferred_element_type=acc)
        r = r + jax.lax.dot_general(k, wi, (((0,), (0,)), ((), ())),
                                    preferred_element_type=acc)
        return (g, r), None

    init = (jnp.zeros((m, m), acc), jnp.zeros((m,), acc))
    (g, r), _ = jax.lax.scan(step, init, (xt, wt))
    return g, r


def streaming_normal_eq(kernel: Kernel, x: Array, y: Array, xm: Array,
                        *, tile: int = 8192, backend: str | None = None,
                        interpret: bool | None = None) -> tuple[Array, Array]:
    """Mesh-aware (G, rhs): shards rows over the "rows" logical axis.

    With an active `repro.distributed.sharding` mesh whose "rows" rule maps
    to a mesh axis that divides n, each device accumulates its local row
    slab and the (m, m)/(m,) results are psum-reduced.  Otherwise (no mesh,
    or indivisible n) this is exactly the single-device accumulation.
    """
    from repro.distributed import sharding as shd
    from repro.kernels import dispatch

    def local(x_loc, w_loc, xm_rep):
        return dispatch.gram_accumulate(kernel, x_loc, xm_rep, w_loc,
                                        backend=backend, tile=tile,
                                        interpret=interpret)

    act = shd.active()
    if act is not None:
        row_axes = act.spec(("rows", None), x.shape)[0]
        if row_axes is not None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            axes = ((row_axes,) if isinstance(row_axes, str)
                    else tuple(row_axes))

            def body(x_loc, w_loc, xm_rep):
                g, r = local(x_loc, w_loc, xm_rep)
                return jax.lax.psum(g, axes), jax.lax.psum(r, axes)

            return shard_map(
                body, mesh=act.mesh,
                in_specs=(P(row_axes, None), P(row_axes), P(None, None)),
                out_specs=(P(None, None), P(None)),
            )(x, y, xm)
    return local(x, y, xm)


def fit_streaming(
    kernel: Kernel,
    x: Array,
    y: Array,
    lam: float,
    landmark_idx: Array,
    *,
    tile: int = 8192,
    backend: str | None = None,
    interpret: bool | None = None,
    jitter: float = 1e-6,
    weights: Array | None = None,
) -> NystromFit:
    """`fit_from_landmarks` without ever materializing K_nm.

    Matches the dense solve to fp32 reduction-order tolerance
    (tests/test_streaming_nystrom.py: <= 1e-4 relative on beta).
    `weights` applies the without-replacement importance correction as a
    post-accumulation O(m^2) column rescaling (`weighted_normal_eq`) — the
    row stream itself is weight-free, so the Pallas/XLA accumulation kernels
    are untouched.
    """
    _require_sentinel_safe(kernel)
    n = x.shape[0]
    xm = jnp.take(x, landmark_idx, axis=0)
    g, rhs = streaming_normal_eq(kernel, x, y, xm, tile=tile,
                                 backend=backend, interpret=interpret)
    # k_mm is O(m^2) work — the core path keeps it in the input dtype, which
    # the dense solve also uses (dtype parity matters more than MXU here).
    k_mm = kernel_matrix(kernel, xm).astype(g.dtype)
    if weights is not None:
        g, rhs, k_mm = weighted_normal_eq(g, rhs, k_mm, weights)
    beta = solve_normal_eq(g, rhs, k_mm, n, lam, jitter=jitter)
    if weights is not None:
        beta = weights.astype(beta.dtype) * beta
    return NystromFit(beta=beta, landmarks=xm, landmark_idx=landmark_idx,
                      lam=lam)


def fit_streaming_multi(
    kernel: Kernel,
    x: Array,
    y: Array,
    lams: Sequence[float],
    landmark_idx: Array,
    *,
    tile: int = 8192,
    backend: str | None = None,
    interpret: bool | None = None,
    jitter: float = 1e-6,
    weights: Array | None = None,
) -> list[NystromFit]:
    """`fit_streaming` over a lam grid at ONE Gram-accumulation cost.

    G = K_nm^T K_nm and rhs = K_nm^T y are lam-independent, and so is the
    weighted column rescaling — only the O(m^3) whitened solve depends on
    lam.  So the sweep streams the rows once (one psum per array under an
    active mesh, exactly like the single-lam fit) and re-solves per lam via
    `solve_normal_eq_multi`.  Fit i is bit-equal to
    `fit_streaming(kernel, x, y, lams[i], landmark_idx, ...)`; cost is
    O(n m (d + m)) + L·O(m^3) instead of L·O(n m (d + m)) — the whole point
    of `pipeline.stages.CalibrateStage`.
    """
    _require_sentinel_safe(kernel)
    n = x.shape[0]
    xm = jnp.take(x, landmark_idx, axis=0)
    g, rhs = streaming_normal_eq(kernel, x, y, xm, tile=tile,
                                 backend=backend, interpret=interpret)
    k_mm = kernel_matrix(kernel, xm).astype(g.dtype)
    if weights is not None:
        g, rhs, k_mm = weighted_normal_eq(g, rhs, k_mm, weights)
    betas = solve_normal_eq_multi(g, rhs, k_mm, n, lams, jitter=jitter)
    if weights is not None:
        betas = weights.astype(betas.dtype)[None, :] * betas
    return [NystromFit(beta=betas[i], landmarks=xm, landmark_idx=landmark_idx,
                       lam=float(lam)) for i, lam in enumerate(lams)]


def predict_streaming_multi(kernel: Kernel, fits: Sequence[NystromFit],
                            x_new: Array, *, tile: int = 8192,
                            backend: str | None = None) -> Array:
    """Batched predict for several fits SHARING one landmark set: (L, n_new).

    The kernel tile K(x_tile, X_m) is the expensive part of a predict and is
    beta-independent, so a lam sweep evaluates it once per tile and applies
    all betas as one (tile, m) x (m, L) matmul.  All fits must share
    `landmarks` (the CalibrateStage invariant); mesh behavior matches
    `predict_streaming` (purely local row slabs).
    """
    from repro.distributed import sharding as shd
    from repro.kernels import dispatch

    _require_sentinel_safe(kernel)
    n, d = x_new.shape
    betas = jnp.stack([f.beta for f in fits], axis=1)     # (m, L)
    xm = fits[0].landmarks

    def local(x_loc, xm, betas):
        n_loc = x_loc.shape[0]
        t = min(tile, n_loc)
        np_ = round_up(n_loc, t)
        tiles = pad_rows_sentinel(x_loc, np_).reshape(np_ // t, t, d)

        def one(xt):
            return dispatch.kernel_matrix(kernel, xt, xm,
                                          backend=backend) @ betas  # (t, L)

        out = jax.lax.map(one, tiles).reshape(np_, betas.shape[1])
        return out[:n_loc]

    act = shd.active()
    if act is not None:
        row_axes = act.spec(("rows", None), x_new.shape)[0]
        if row_axes is not None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            out = shard_map(
                local, mesh=act.mesh,
                in_specs=(P(row_axes, None), P(None, None), P(None, None)),
                out_specs=P(row_axes, None),
            )(x_new, xm, betas)
            return out.T
    return local(x_new, xm, betas).T


def predict_streaming(kernel: Kernel, fit_: NystromFit, x_new: Array,
                      *, tile: int = 8192,
                      backend: str | None = None) -> Array:
    """Batched predict: O(tile * m) memory, any n_new.

    Mesh-aware like the solve: under an active `repro.distributed.sharding`
    mesh whose "rows" rule maps to a mesh axis that divides n_new, each
    device predicts its local row slab against the replicated landmarks and
    beta (no collective — predict is embarrassingly row-parallel).
    Otherwise this is exactly the single-device batched predict.
    """
    from repro.distributed import sharding as shd
    from repro.kernels import dispatch

    _require_sentinel_safe(kernel)
    n, d = x_new.shape

    def local(x_loc, xm, beta):
        n_loc = x_loc.shape[0]
        t = min(tile, n_loc)
        np_ = round_up(n_loc, t)
        tiles = pad_rows_sentinel(x_loc, np_).reshape(np_ // t, t, d)

        def one(xt):
            return dispatch.kernel_matrix(kernel, xt, xm,
                                          backend=backend) @ beta

        return jax.lax.map(one, tiles).reshape(np_)[:n_loc]

    act = shd.active()
    if act is not None:
        row_axes = act.spec(("rows", None), x_new.shape)[0]
        if row_axes is not None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            return shard_map(
                local, mesh=act.mesh,
                in_specs=(P(row_axes, None), P(None, None), P(None)),
                out_specs=P(row_axes),
            )(x_new, fit_.landmarks, fit_.beta)
    return local(x_new, fit_.landmarks, fit_.beta)
