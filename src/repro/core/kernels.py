"""Stationary kernels and their spectral densities.

The paper's method (Eq. 6) needs, for a stationary PSD kernel K(x, y) = K(x - y):

  * the kernel function itself (to build empirical kernel matrices), and
  * its spectral density m(s), defined through the ordinary-frequency Fourier
    convention used throughout the paper:

        K(u) = \\int_{R^d} m(s) exp(2 pi i <u, s>) ds.

Bochner's theorem guarantees m >= 0.  All kernels here are isotropic, so both
K and m depend only on the Euclidean norm of their argument; we expose
``spectral_density(s_norm, d)`` in terms of the radial frequency ||s||.

Conventions (verified by tests/test_kernels_core.py against 1-D quadrature):

  Matern(nu, ell):   K(r) = 2^{1-nu}/Gamma(nu) (a r)^nu B_nu(a r),  a = sqrt(2 nu)/ell
                     m(s) = C_{d,nu} (a^2 + 4 pi^2 ||s||^2)^{-(nu + d/2)}
                     C_{d,nu} = 2^d pi^{d/2} Gamma(nu + d/2) a^{2 nu} / Gamma(nu)
  Gaussian(sigma):   K(r) = exp(-r^2 / (2 sigma^2))
                     m(s) = (2 pi sigma^2)^{d/2} exp(-2 pi^2 sigma^2 ||s||^2)

The Matern smoothness alias alpha = nu + d/2 (the Sobolev order of the RKHS)
is what the paper's closed-form leverage approximation uses.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Union

import jax
import jax.numpy as jnp

Array = jax.Array

# Rows parked at this coordinate are ~1e6 away from any real (O(1)-scale)
# point, so every kernel map here underflows to exactly 0.0 there — padding
# rows to a tile multiple needs no masking downstream.  Shared by the
# streaming Nystrom solve (core.nystrom) and the Pallas gram kernel
# (repro.kernels.gram); `sentinel_is_safe` checks the underflow actually
# holds for a given kernel's bandwidth.
ROW_SENTINEL = 1.0e6


def round_up(v: int, b: int) -> int:
    return -(-v // b) * b


def pad_rows_sentinel(x: Array, rows: int) -> Array:
    """Pad (n, d) to (rows, d); new rows sit at the ROW_SENTINEL coordinate."""
    n = x.shape[0]
    if rows == n:
        return x
    out = jnp.pad(x, ((0, rows - n), (0, 0)))
    return out.at[n:, 0].set(ROW_SENTINEL)


def sentinel_is_safe(kernel: "Kernel") -> bool:
    """True when k(ROW_SENTINEL / 2) underflows to exactly 0.

    The /2 margin covers data with coordinates up to ~ROW_SENTINEL/2.  False
    means the kernel's bandwidth is so wide (e.g. Gaussian sigma ~ 1e5 on
    unnormalized data) that sentinel-padded rows would contribute nonzero
    kernel values — callers must reject rather than silently corrupt sums.
    """
    return float(kernel.from_distance(jnp.asarray(ROW_SENTINEL * 0.5))) == 0.0


# Feature-count threshold below which pairwise distances are assembled from
# exact per-coordinate differences instead of the MXU expansion.  The
# expansion ||x-y||^2 = ||x||^2 + ||y||^2 - 2 x.y^T cancels catastrophically
# when x ~ y: the fp32 error is O(eps * ||x||^2), and sqrt amplifies it to
# O(||x|| * sqrt(eps)) ~ 3e-4 in the *distance* near r = 0 — fatal for
# kernels with a kink at the origin (Matern nu=0.5).  The direct form
# (x_j - y_j)^2 is exactly rounded per coordinate, and at d <= EXACT_DIST_D
# the extra VPU work (d passes over the (n, m) tile) is negligible.  Shared
# by this oracle and the Pallas `pairwise`/`gram` kernel bodies so all
# backends agree to roundoff.
EXACT_DIST_D = 4


def exact_sq_dists(x: Array, y: Array, d: int) -> Array:
    """(n, d_pad) x (m, d_pad) -> (n, m) exact squared distances over the
    first `d` feature columns (2-D broadcasts only, so Pallas tile bodies
    can share it; padded columns past `d` are all-zero and skipped).
    """
    sq = jnp.zeros((x.shape[0], y.shape[0]),
                   dtype=jnp.promote_types(x.dtype, y.dtype))
    for j in range(d):
        diff = x[:, j][:, None] - y[:, j][None, :]
        sq = sq + diff * diff
    return sq


def _sq_dists(x: Array, y: Array) -> Array:
    """Pairwise squared Euclidean distances, (n, d) x (m, d) -> (n, m).

    d <= EXACT_DIST_D accumulates exact per-coordinate squared differences
    (well-conditioned near r = 0); larger d uses the MXU-friendly expansion
    ||x - y||^2 = ||x||^2 + ||y||^2 - 2 x.y^T with a clamp at zero to absorb
    rounding.  This is the pure-jnp oracle; the Pallas `pairwise` kernel
    computes the same quantities in tiles.
    """
    d = x.shape[-1]
    if d <= EXACT_DIST_D:
        return exact_sq_dists(x, y, d)
    x2 = jnp.sum(x * x, axis=-1)[:, None]
    y2 = jnp.sum(y * y, axis=-1)[None, :]
    xy = x @ y.T
    return jnp.maximum(x2 + y2 - 2.0 * xy, 0.0)


@dataclasses.dataclass(frozen=True)
class Matern:
    """Matern kernel with half-integer smoothness nu in {0.5, 1.5, 2.5}."""

    nu: float = 1.5
    lengthscale: float = 1.0

    @property
    def a(self) -> float:
        """Inverse-scale parameter a = sqrt(2 nu) / lengthscale."""
        return math.sqrt(2.0 * self.nu) / self.lengthscale

    def alpha(self, d: int) -> float:
        """Sobolev order of the associated RKHS (paper's alpha = nu + d/2)."""
        return self.nu + 0.5 * d

    # -- kernel values -------------------------------------------------------
    def from_distance(self, r: Array) -> Array:
        ar = self.a * r
        if self.nu == 0.5:
            return jnp.exp(-ar)
        if self.nu == 1.5:
            return (1.0 + ar) * jnp.exp(-ar)
        if self.nu == 2.5:
            return (1.0 + ar + ar * ar / 3.0) * jnp.exp(-ar)
        raise ValueError(f"unsupported Matern nu={self.nu}; use 0.5 / 1.5 / 2.5")

    def __call__(self, x: Array, y: Array) -> Array:
        return self.from_distance(jnp.sqrt(_sq_dists(x, y)))

    # -- spectral density ----------------------------------------------------
    def spectral_constant(self, d: int) -> float:
        nu, a = self.nu, self.a
        return (
            (2.0 ** d)
            * math.pi ** (d / 2.0)
            * math.gamma(nu + d / 2.0)
            * a ** (2.0 * nu)
            / math.gamma(nu)
        )

    def spectral_density(self, s_norm: Array, d: int) -> Array:
        """m(s) as a function of the radial ordinary frequency ||s||."""
        alpha = self.alpha(d)
        c = self.spectral_constant(d)
        return c * (self.a ** 2 + 4.0 * math.pi ** 2 * s_norm ** 2) ** (-alpha)

    def inverse_spectral_density(self, s_norm: Array, d: int) -> Array:
        """1 / m(s), kept separate to avoid overflow at large ||s||."""
        alpha = self.alpha(d)
        c = self.spectral_constant(d)
        return (self.a ** 2 + 4.0 * math.pi ** 2 * s_norm ** 2) ** alpha / c


@dataclasses.dataclass(frozen=True)
class Gaussian:
    """Gaussian (RBF) kernel exp(-r^2 / (2 sigma^2))."""

    sigma: float = 1.0

    def alpha(self, d: int) -> float:  # effective smoothness is infinite
        return math.inf

    def from_distance(self, r: Array) -> Array:
        return jnp.exp(-(r * r) / (2.0 * self.sigma ** 2))

    def from_sq_distance(self, r2: Array) -> Array:
        return jnp.exp(-r2 / (2.0 * self.sigma ** 2))

    def __call__(self, x: Array, y: Array) -> Array:
        return self.from_sq_distance(_sq_dists(x, y))

    def spectral_density(self, s_norm: Array, d: int) -> Array:
        c = (2.0 * math.pi * self.sigma ** 2) ** (d / 2.0)
        return c * jnp.exp(-2.0 * math.pi ** 2 * self.sigma ** 2 * s_norm ** 2)

    def inverse_spectral_density(self, s_norm: Array, d: int) -> Array:
        c = (2.0 * math.pi * self.sigma ** 2) ** (d / 2.0)
        return jnp.exp(2.0 * math.pi ** 2 * self.sigma ** 2 * s_norm ** 2) / c


def Laplacian(lengthscale: float = 1.0) -> Matern:
    """Laplacian kernel exp(-r / ell) == Matern nu = 1/2 with a = 1/ell."""
    # Matern(0.5, ell') has a = sqrt(1)/ell' = 1/ell', so ell' = ell works.
    return Matern(nu=0.5, lengthscale=lengthscale)


Kernel = Union[Matern, Gaussian]


def kernel_matrix(kernel: Kernel, x: Array, y: Array | None = None) -> Array:
    """Empirical kernel matrix K(x_i, y_j); the O(n m d) hotspot.

    Pure-jnp path (used on CPU and as the Pallas oracle).  On TPU, call
    ``repro.kernels.pairwise.ops.kernel_matrix`` instead, which tiles the same
    computation through VMEM with fp32 accumulation on the MXU.
    """
    symmetric = y is None
    if symmetric:
        y = x
    sq = _sq_dists(x, y)
    if symmetric:
        # The expansion leaves O(eps * ||x||^2) noise on the self-distances;
        # pin the diagonal to exactly zero so K_ii = K(0).
        n = x.shape[0]
        sq = sq * (1.0 - jnp.eye(n, dtype=sq.dtype))
    if isinstance(kernel, Gaussian):
        return kernel.from_sq_distance(sq)
    return kernel.from_distance(jnp.sqrt(sq))


def gram_diagonal(kernel: Kernel, n: int, dtype=jnp.float32) -> Array:
    """K(x_i, x_i) for stationary kernels is K(0) = 1 for all kernels here."""
    return jnp.ones((n,), dtype=dtype)
