"""Importance-sampling utilities for landmark selection (paper Thm 2 setting)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def sample_with_replacement(key: jax.Array, probs: Array, m: int) -> Array:
    """Draw m landmark indices iid from the categorical distribution probs.

    This is the sampling model of paper Theorem 2 (columns chosen with
    replacement).  Implemented with jax.random.categorical over log-probs so
    it is vectorized and reproducible on accelerator.
    """
    logits = jnp.log(jnp.maximum(probs, 1e-38))
    return jax.random.categorical(key, logits, shape=(m,))


def sample_without_replacement(key: jax.Array, probs: Array, m: int) -> Array:
    """Gumbel top-k sampling of m distinct indices proportional to probs."""
    logits = jnp.log(jnp.maximum(probs, 1e-38))
    gumbel = jax.random.gumbel(key, logits.shape, dtype=logits.dtype)
    return jax.lax.top_k(logits + gumbel, m)[1]


def bernoulli_subset(key: jax.Array, inclusion: Array):
    """Independent Bernoulli inclusion (used by Recursive-RLS / BLESS).

    Returns a boolean mask; callers compact it host-side (the recursive
    baselines are host-driven, so dynamic sizes are fine there).
    """
    return jax.random.uniform(key, inclusion.shape) < inclusion
