"""Importance-sampling utilities for landmark selection (paper Thm 2 setting)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def sample_with_replacement(key: jax.Array, probs: Array, m: int) -> Array:
    """Draw m landmark indices iid from the categorical distribution probs.

    This is the sampling model of paper Theorem 2 (columns chosen with
    replacement).  Small problems use jax.random.categorical over log-probs
    (vectorized, reproducible on accelerator); it materializes an (m, n)
    gumbel field, so past ~16M cells we switch to inverse-CDF sampling
    (cumsum + searchsorted), which is O(n + m log n) and O(n) memory — at
    n = 1e6, m = 1024 the categorical path would allocate 4 GB.
    """
    n = probs.shape[0]
    if n * m > (1 << 24):
        cdf = jnp.cumsum(probs)
        u = jax.random.uniform(key, (m,), dtype=cdf.dtype) * cdf[-1]
        return jnp.clip(jnp.searchsorted(cdf, u), 0, n - 1).astype(jnp.int32)
    logits = jnp.log(jnp.maximum(probs, 1e-38))
    return jax.random.categorical(key, logits, shape=(m,))


def _perturbed_logits(key: jax.Array, probs: Array,
                      gumbel: Array | None = None) -> Array:
    """log q + standard Gumbel noise; `gumbel` shares one race across calls.

    Passing a precomputed noise field (shape (n,)) makes several draws with
    DIFFERENT probs share the same exponential race — candidates in a sweep
    (e.g. the CalibrateStage bandwidth grid) then differ only through their
    probs, never through sampling noise.  Drawing here from `key` with the
    logits' shape/dtype is bit-identical to the historical per-call draw.
    """
    logits = jnp.log(jnp.maximum(probs, 1e-38))
    if gumbel is None:
        gumbel = jax.random.gumbel(key, logits.shape, dtype=logits.dtype)
    return logits + gumbel.astype(logits.dtype)


def sample_without_replacement(key: jax.Array, probs: Array, m: int) -> Array:
    """Gumbel top-k sampling of m distinct indices proportional to probs."""
    return jax.lax.top_k(_perturbed_logits(key, probs), m)[1]


def sample_weighted_without_replacement(
        key: jax.Array, probs: Array, m: int, *,
        threshold: str = "race",
        gumbel: Array | None = None) -> tuple[Array, Array]:
    """Gumbel top-k landmarks + inverse-inclusion importance weights.

    With-replacement sampling at m >= 1024 wastes budget on duplicate
    landmarks whose K_mm null directions the solver truncates; Gumbel top-k
    spends every slot on a distinct point.

    The weights are 1 / pi_hat_i with pi_hat_i the inclusion probability
    from the exponential-race threshold trick (Duffield et al. priority
    sampling / Cohen-Kaplan bottom-k sketches): the perturbed logit
    log q_i + g_i equals -log t_i for an arrival time t_i = E_i / q_i,
    E_i ~ Exp(1), so top-k selection is bottom-k on arrivals.

    ``threshold`` picks how pi_hat is computed for the selected items:

      * ``"race"`` (historical default) — the shared (m+1)-th arrival tau:
        pi_hat_i = 1 - exp(-q_i tau), weight = 1 / clip(pi_hat, 1e-12, 1).
      * ``"loo"`` — the exact leave-one-out threshold: for selected item i,
        tau_i is the m-th smallest arrival among the OTHER n-1 items,
        conditioned on which i's inclusion is exactly Bernoulli
        (t_i ~ Exp(q_i) independent of the others), so
        1{i in S} / (1 - exp(-q_i tau_i)) is EXACTLY conditionally
        unbiased.  The per-item construction collapses to the shared
        threshold at no extra work: for an item inside the top m, deleting
        it promotes the (m+1)-th overall arrival to the m-th arrival of
        the rest, so tau_i == tau for every selected item (an
        order-statistics identity the tests lock).  Weights are evaluated
        clip-free via expm1, so near-certain inclusions land at exactly 1
        instead of the race mode's clipped estimate.

    Deriving "loo" shows the exponential race's shared-threshold estimator
    is already conditionally exact for every SELECTED item — the O(1/m)
    weight bias of Pareto / sequential-Poisson races (whose arrival law
    makes the threshold only approximately exponential) does not arise
    here.  Both modes are validated against exact Plackett-Luce inclusion
    enumeration in tests/test_sampling_weights.py; "loo" differs only in
    the clip-free tail (and is the contract the docs now promise).

    The convention matches the weighted projection-leverage estimator
    (`rls.projection_leverage`) and the race sketches of Recursive-RLS /
    BLESS (weights are 1/inclusion there too).  The subset-of-regressors
    Nystrom solve is invariant to positive column rescaling (see
    `nystrom.fit_streaming`), so there the weights only exercise the
    weighted code path; the projection / RLS estimators genuinely consume
    them.  Requires m <= len(probs); at m == n there is no threshold
    arrival and every weight is exactly 1.  ``gumbel`` shares one
    precomputed race across calls (see `_perturbed_logits`).
    """
    if threshold not in ("race", "loo"):
        raise ValueError(f"unknown threshold {threshold!r}; "
                         "pick 'race' or 'loo'")
    n = probs.shape[0]
    s = _perturbed_logits(key, probs, gumbel)
    if m >= n:
        return jax.lax.top_k(s, m)[1], jnp.ones((m,), dtype=probs.dtype)
    vals, idx = jax.lax.top_k(s, m + 1)
    q_sel = jnp.maximum(probs[idx[:m]], 1e-38)
    # One threshold serves both modes: arrivals ascend as
    # t_(1) <= ... <= t_(m+1), and deleting a selected item (rank <= m)
    # from the race leaves t_(m+1) as the m-th smallest arrival of the
    # REMAINING n-1 items — the exact leave-one-out threshold tau_i
    # collapses to the shared tau, so P(i in S | others) =
    # 1 - exp(-q_i tau) exactly for every selected item.
    tau = jnp.exp(-vals[m])                       # (m+1)-th arrival time
    inclusion = -jnp.expm1(-q_sel * tau)
    if threshold == "loo":
        # clip-free: expm1 keeps near-certain inclusions at exactly 1; a
        # denormal floor only guards q_i * tau underflow
        return idx[:m], 1.0 / jnp.maximum(inclusion,
                                          jnp.finfo(probs.dtype).tiny)
    return idx[:m], 1.0 / jnp.clip(inclusion, 1e-12, 1.0)


def bernoulli_subset(key: jax.Array, inclusion: Array):
    """Independent Bernoulli inclusion (used by Recursive-RLS / BLESS).

    Returns a boolean mask; callers compact it host-side (the recursive
    baselines are host-driven, so dynamic sizes are fine there).
    """
    return jax.random.uniform(key, inclusion.shape) < inclusion
