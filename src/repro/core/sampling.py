"""Importance-sampling utilities for landmark selection (paper Thm 2 setting)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def sample_with_replacement(key: jax.Array, probs: Array, m: int) -> Array:
    """Draw m landmark indices iid from the categorical distribution probs.

    This is the sampling model of paper Theorem 2 (columns chosen with
    replacement).  Small problems use jax.random.categorical over log-probs
    (vectorized, reproducible on accelerator); it materializes an (m, n)
    gumbel field, so past ~16M cells we switch to inverse-CDF sampling
    (cumsum + searchsorted), which is O(n + m log n) and O(n) memory — at
    n = 1e6, m = 1024 the categorical path would allocate 4 GB.
    """
    n = probs.shape[0]
    if n * m > (1 << 24):
        cdf = jnp.cumsum(probs)
        u = jax.random.uniform(key, (m,), dtype=cdf.dtype) * cdf[-1]
        return jnp.clip(jnp.searchsorted(cdf, u), 0, n - 1).astype(jnp.int32)
    logits = jnp.log(jnp.maximum(probs, 1e-38))
    return jax.random.categorical(key, logits, shape=(m,))


def sample_without_replacement(key: jax.Array, probs: Array, m: int) -> Array:
    """Gumbel top-k sampling of m distinct indices proportional to probs."""
    logits = jnp.log(jnp.maximum(probs, 1e-38))
    gumbel = jax.random.gumbel(key, logits.shape, dtype=logits.dtype)
    return jax.lax.top_k(logits + gumbel, m)[1]


def sample_weighted_without_replacement(
        key: jax.Array, probs: Array, m: int) -> tuple[Array, Array]:
    """Gumbel top-k landmarks + importance weights 1/sqrt(m q_i).

    With-replacement sampling at m >= 1024 wastes budget on duplicate
    landmarks whose K_mm null directions the solver truncates; Gumbel top-k
    spends every slot on a distinct point.  The returned weights are the
    usual importance correction (normalized to mean 1 for scale stability).
    The subset-of-regressors Nystrom solve is invariant to positive column
    rescaling, so the weights do not enter `nystrom.fit_streaming`; they are
    recorded for estimators that are not (projection/RLS variants) and for
    diagnostics.  Requires m <= len(probs).
    """
    idx = sample_without_replacement(key, probs, m)
    q = jnp.maximum(probs[idx], 1e-38)
    w = 1.0 / jnp.sqrt(m * q)
    return idx, w / jnp.mean(w)


def bernoulli_subset(key: jax.Array, inclusion: Array):
    """Independent Bernoulli inclusion (used by Recursive-RLS / BLESS).

    Returns a boolean mask; callers compact it host-side (the recursive
    baselines are host-driven, so dynamic sizes are fine there).
    """
    return jax.random.uniform(key, inclusion.shape) < inclusion
