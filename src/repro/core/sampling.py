"""Importance-sampling utilities for landmark selection (paper Thm 2 setting)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def sample_with_replacement(key: jax.Array, probs: Array, m: int) -> Array:
    """Draw m landmark indices iid from the categorical distribution probs.

    This is the sampling model of paper Theorem 2 (columns chosen with
    replacement).  Small problems use jax.random.categorical over log-probs
    (vectorized, reproducible on accelerator); it materializes an (m, n)
    gumbel field, so past ~16M cells we switch to inverse-CDF sampling
    (cumsum + searchsorted), which is O(n + m log n) and O(n) memory — at
    n = 1e6, m = 1024 the categorical path would allocate 4 GB.
    """
    n = probs.shape[0]
    if n * m > (1 << 24):
        cdf = jnp.cumsum(probs)
        u = jax.random.uniform(key, (m,), dtype=cdf.dtype) * cdf[-1]
        return jnp.clip(jnp.searchsorted(cdf, u), 0, n - 1).astype(jnp.int32)
    logits = jnp.log(jnp.maximum(probs, 1e-38))
    return jax.random.categorical(key, logits, shape=(m,))


def _perturbed_logits(key: jax.Array, probs: Array) -> Array:
    logits = jnp.log(jnp.maximum(probs, 1e-38))
    gumbel = jax.random.gumbel(key, logits.shape, dtype=logits.dtype)
    return logits + gumbel


def sample_without_replacement(key: jax.Array, probs: Array, m: int) -> Array:
    """Gumbel top-k sampling of m distinct indices proportional to probs."""
    return jax.lax.top_k(_perturbed_logits(key, probs), m)[1]


def sample_weighted_without_replacement(
        key: jax.Array, probs: Array, m: int) -> tuple[Array, Array]:
    """Gumbel top-k landmarks + inverse-inclusion importance weights.

    With-replacement sampling at m >= 1024 wastes budget on duplicate
    landmarks whose K_mm null directions the solver truncates; Gumbel top-k
    spends every slot on a distinct point.

    The weights are 1 / pi_hat_i with pi_hat_i the inclusion probability
    estimated by the exponential-race threshold trick (Duffield et al.
    priority sampling / Pareto sampling): the perturbed logit log q_i + g_i
    equals -log t_i for an arrival time t_i = E_i / q_i, E_i ~ Exp(1), so
    top-k selection is bottom-k on arrivals.  Conditioned on the (m+1)-th
    arrival tau, inclusions are INDEPENDENT with

        pi_hat_i = P(t_i < tau | tau) = 1 - exp(-q_i tau),

    which makes 1{i in S} / pi_hat_i an (approximately) unbiased inclusion
    estimator — the convention the weighted projection-leverage estimator
    (`rls.projection_leverage`) and the Bernoulli sketches of Recursive-RLS /
    BLESS already use (their weights are 1/inclusion too).  Certain
    inclusions get weight ~1.  The subset-of-regressors Nystrom solve is
    invariant to positive column rescaling (see `nystrom.fit_streaming`), so
    there the weights only exercise the weighted code path; the projection /
    RLS estimators genuinely consume them.  Requires m <= len(probs); at
    m == n there is no threshold arrival and every weight is exactly 1.
    """
    n = probs.shape[0]
    s = _perturbed_logits(key, probs)
    if m >= n:
        return jax.lax.top_k(s, m)[1], jnp.ones((m,), dtype=probs.dtype)
    vals, idx = jax.lax.top_k(s, m + 1)
    tau = jnp.exp(-vals[m])                       # (m+1)-th arrival time
    q_sel = jnp.maximum(probs[idx[:m]], 1e-38)
    inclusion = -jnp.expm1(-q_sel * tau)
    return idx[:m], 1.0 / jnp.clip(inclusion, 1e-12, 1.0)


def bernoulli_subset(key: jax.Array, inclusion: Array):
    """Independent Bernoulli inclusion (used by Recursive-RLS / BLESS).

    Returns a boolean mask; callers compact it host-side (the recursive
    baselines are host-driven, so dynamic sizes are fine there).
    """
    return jax.random.uniform(key, inclusion.shape) < inclusion
