"""AdamW + schedules, pure JAX (no optax in this container).

The moment tensors inherit each parameter's sharding (elementwise ops), so
with FSDP-sharded weights the optimizer state is automatically ZeRO-sharded —
no separate partitioner is needed.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    step: Array          # ()
    mu: dict             # fp32, same tree as params
    nu: dict             # fp32
    master: dict | None = None   # fp32 master weights (bf16-params training)


class Hparams(NamedTuple):
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    master_weights: bool = False  # keep fp32 masters when params are bf16


def init(params, hp: Hparams | None = None) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    master = None
    if hp is not None and hp.master_weights:
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params),
                      master=master)


def abstract_init(abstract_params, hp: Hparams | None = None) -> AdamWState:
    """ShapeDtypeStruct mirror of init() for dry-run lowering."""
    mk = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32,
                                        sharding=getattr(p, "sharding", None))
    master = None
    if hp is not None and hp.master_weights:
        master = jax.tree.map(mk, abstract_params)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      mu=jax.tree.map(mk, abstract_params),
                      nu=jax.tree.map(mk, abstract_params),
                      master=master)


def cosine_lr(step: Array, hp: Hparams) -> Array:
    step = step.astype(jnp.float32)
    warm = step / max(hp.warmup_steps, 1)
    frac = jnp.clip((step - hp.warmup_steps)
                    / max(hp.total_steps - hp.warmup_steps, 1), 0.0, 1.0)
    cos = hp.min_lr_ratio + (1 - hp.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(math.pi * frac))
    return hp.peak_lr * jnp.where(step < hp.warmup_steps, warm, cos)


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def update(grads, state: AdamWState, params, hp: Hparams):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, hp.clip_norm)
    step = state.step + 1
    lr = cosine_lr(step, hp)
    b1, b2 = hp.b1, hp.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    masters = state.master if state.master is not None else params

    def upd(p, w32, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + hp.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + hp.weight_decay * w32.astype(jnp.float32)
        new_w = w32.astype(jnp.float32) - lr * delta
        return new_w.astype(p.dtype), new_w, m, v

    out = jax.tree.map(upd, params, masters, grads, state.mu, state.nu)
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    new_params, new_master, new_mu, new_nu = (pick(0), pick(1), pick(2),
                                              pick(3))
    if state.master is None:
        new_master = None
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, AdamWState(step, new_mu, new_nu, new_master), metrics
