"""Pallas TPU kernels for the perf-critical hot spots.

Each subpackage follows the kernel.py (pl.pallas_call + BlockSpec) /
ops.py (jit'd public wrapper) / ref.py (pure-jnp oracle) structure and is
validated in interpret mode on CPU (tests/test_pallas_*.py).

  pairwise        — tiled stationary-kernel (Gram) matrix      [paper hot spot]
  gram            — fused kernel-tile + K_nm^T K_nm accumulate [streaming solve]
  kde             — tiled direct Gaussian KDE                  [paper hot spot]
  kde_binned      — tiled CIC scatter, VMEM-resident grid      [binned KDE deposit]
  flash_attention — causal GQA flash attention (+ SWA)         [LM prefill]
  ssd             — Mamba2 SSD chunked scan                    [SSM mixing]

`repro.kernels.dispatch` picks Pallas vs fused-XLA per call site ('auto' =
Pallas on TPU; override with REPRO_KERNEL_BACKEND).
"""
