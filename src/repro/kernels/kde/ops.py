"""jit'd public wrapper for the KDE Pallas kernel (padding + normalisation)."""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.kernels import round_up
from repro.kernels.kde import kernel as kk
from repro.kernels.kde import ref

Array = jax.Array


@functools.partial(
    jax.jit, static_argnames=("h", "bm", "bn", "interpret", "use_pallas")
)
def kde(
    query: Array,
    data: Array,
    *,
    h: float,
    bm: int = 256,
    bn: int = 256,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> Array:
    """Gaussian KDE density estimates at `query` from `data`, O(n m d) direct.

    Matches repro.core.kde.kde_direct / ref.kde to fp32 accuracy.
    """
    if not use_pallas:
        return ref.kde(query, data, h)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = query.shape
    m, _ = data.shape
    bm_ = min(bm, round_up(n, 8))
    bn_ = min(bn, round_up(m, 128))
    np_, mp = round_up(n, bm_), round_up(m, bn_)
    dp = round_up(d, 128) if not interpret else d
    q = jnp.pad(query, ((0, np_ - n), (0, dp - d)))
    x = jnp.pad(data, ((0, mp - m), (0, dp - d)))
    sums = kk.kde_padded(q, x, h=h, m=m, bm=bm_, bn=bn_, interpret=interpret)
    norm = 1.0 / (m * (2.0 * math.pi * h * h) ** (d / 2.0))
    return norm * sums[:n, 0]
