"""Pallas TPU kernel: tiled direct Gaussian KDE with on-chip row accumulation.

Same (bm, bn) tile structure as `pairwise`, but the (n x m) weight matrix is
never written out: each program computes a (bm, bn) tile of exp(-sq/2h^2),
reduces it over the column axis, and accumulates into the (bm, 1) output
block.  The grid's second axis is the reduction axis — the output BlockSpec
ignores it, so consecutive j-steps revisit the same output tile in VMEM
(sequential TPU grid => safe accumulation):

  j == 0:        out  = rowsum(tile)
  j  > 0:        out += rowsum(tile)

Column padding (m -> mp) is masked with the true m so padded source points
contribute no mass.  Row padding is sliced off by the wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kde_body(q_ref, x_ref, out_ref, *, inv_two_h_sq: float, m: int, bn: int):
    j = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)  # (bm, d)
    x = x_ref[...].astype(jnp.float32)  # (bn, d)
    qx = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    q2 = jnp.sum(q * q, axis=1)[:, None]
    x2 = jnp.sum(x * x, axis=1)[None, :]
    sq = jnp.maximum(q2 + x2 - 2.0 * qx, 0.0)
    w = jnp.exp(-sq * inv_two_h_sq)
    # mask padded source columns
    col = j * bn + jax.lax.broadcasted_iota(jnp.int32, w.shape, 1)
    w = jnp.where(col < m, w, 0.0)
    part = jnp.sum(w, axis=1)[:, None]  # (bm, 1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = part.astype(out_ref.dtype)

    @pl.when(j > 0)
    def _acc():
        out_ref[...] = out_ref[...] + part.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("h", "m", "bm", "bn", "interpret")
)
def kde_padded(
    query: Array,
    data: Array,
    *,
    h: float,
    m: int,
    bm: int = 256,
    bn: int = 256,
    interpret: bool = False,
) -> Array:
    """Unnormalised row sums; (np, d) x (mp, d) -> (np, 1). Shapes pre-padded."""
    np_, d = query.shape
    mp, _ = data.shape
    assert np_ % bm == 0 and mp % bn == 0, (np_, mp, bm, bn)
    grid = (np_ // bm, mp // bn)
    body = functools.partial(
        _kde_body, inv_two_h_sq=1.0 / (2.0 * float(h) ** 2), m=m, bn=bn
    )
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, 1), jnp.float32),
        interpret=interpret,
    )(query, data)
