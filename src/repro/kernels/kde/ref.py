"""Pure-jnp oracle for the tiled Gaussian-KDE Pallas kernel.

p_hat(q_i) = (1 / (n (2 pi h^2)^{d/2})) * sum_j exp(-||q_i - x_j||^2 / (2 h^2))
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jax.Array


def kde(query: Array, data: Array, h: float) -> Array:
    q = query.astype(jnp.float32)
    x = data.astype(jnp.float32)
    q2 = jnp.sum(q * q, axis=-1)[:, None]
    x2 = jnp.sum(x * x, axis=-1)[None, :]
    sq = jnp.maximum(q2 + x2 - 2.0 * (q @ x.T), 0.0)
    n, d = data.shape
    norm = 1.0 / (n * (2.0 * math.pi * h * h) ** (d / 2.0))
    return norm * jnp.sum(jnp.exp(-sq / (2.0 * h * h)), axis=1)
