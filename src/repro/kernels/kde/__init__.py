"""kde Pallas kernel package (kernel.py + ops.py + ref.py)."""
