"""Backend dispatch: route kernel-matrix hot spots through Pallas or XLA.

The core solvers (`nystrom`, `rls`) are written against an abstract
kernel-matrix contract; this module decides, once, which implementation
serves it:

  * ``pallas`` — the tiled Pallas kernels (`repro.kernels.pairwise` /
    `repro.kernels.gram`).  Native on TPU; off-TPU they run in interpret
    mode, which is correct but slow — useful for validation only.
  * ``xla``    — the fused pure-jnp references in `repro.core.kernels` and
    the lax.scan streaming path.  The right choice on CPU/GPU.
  * ``auto``   — ``pallas`` on TPU, ``xla`` elsewhere.  Overridable with the
    ``REPRO_KERNEL_BACKEND`` environment variable.

Imports of the Pallas packages are deferred to call time so `repro.core`
never depends on `repro.kernels` at import (the reverse edge already
exists: pairwise/gram ops adapt `repro.core.kernels` objects).
"""

from __future__ import annotations

import os

import jax

from repro.core import kernels as core_kernels

Array = jax.Array

BACKENDS = ("auto", "xla", "pallas")


def resolve(backend: str | None = None) -> str:
    """'auto'/None -> 'pallas' on TPU else 'xla'; explicit names pass through."""
    backend = backend or "auto"
    if backend == "auto":
        backend = os.environ.get("REPRO_KERNEL_BACKEND", "auto")
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend not in ("xla", "pallas"):
        raise ValueError(f"unknown backend {backend!r}; pick from {BACKENDS}")
    return backend


def kernel_matrix(kernel: core_kernels.Kernel, x: Array,
                  y: Array | None = None, *, backend: str | None = None,
                  **kw) -> Array:
    """K(x, y) through the resolved backend (Pallas `pairwise` on TPU)."""
    if resolve(backend) == "pallas":
        from repro.kernels.pairwise import ops as pw_ops
        return pw_ops.kernel_matrix(kernel, x, y, **kw)
    return core_kernels.kernel_matrix(kernel, x, y)


def resolve_plan(op: str, n: int, m: int, d: int, *,
                 dtype=None, backend: str | None = None,
                 accumulator: str = "plain",
                 precision: str | None = "fp32",
                 num_models: int = 1):
    """Autotuned execution plan for a streamed op (`repro.tuning`).

    This is THE boundary where ``tile=None`` (and Pallas bm/bn defaults)
    become concrete integers: the roofline-ranked, optionally
    micro-benchmarked, cache-persisted choice for (device, backend, op,
    shape bucket).  Pure shape plumbing when precision is pinned — the
    plan never perturbs numerics, so op(tile=None) is bit-equal to
    op(tile=plan.tile).  ``precision=None`` (gram only) asks the model to
    resolve the (tile, precision) pair JOINTLY: the plan's ``precision``
    field then carries the chosen Gram-contraction mode.

    ``num_models`` widens the landmark block for MANY-MODEL batched
    streams (`repro.core.nystrom.fit_streaming_batched`): each tile step
    there materializes one (tile, m) kernel slab PER locally held model —
    the same transient footprint as a single (tile, m * num_models) slab —
    so the planner must budget against the widened block or the batched
    scan would pick a tile sized for one model and blow the slab budget
    num_models-fold.  Pass the PER-CHIP model count (after model-axis
    sharding), not the global batch.
    """
    import jax.numpy as jnp

    from repro import tuning
    return tuning.plan_for(op, int(n), int(m) * max(1, int(num_models)),
                           int(d),
                           dtype=dtype if dtype is not None else jnp.float32,
                           backend=resolve(backend), accumulator=accumulator,
                           precision=precision)


def resolve_tile(op: str, n: int, m: int, d: int, *,
                 dtype=None, backend: str | None = None,
                 accumulator: str = "plain",
                 precision: str | None = "fp32",
                 num_models: int = 1) -> int:
    """`resolve_plan(...).tile` — the engine-tile shorthand the streaming
    entry points (`repro.core.nystrom`) use for their ``tile=None``."""
    return resolve_plan(op, n, m, d, dtype=dtype, backend=backend,
                        accumulator=accumulator, precision=precision,
                        num_models=num_models).tile


def gram_accumulate(kernel: core_kernels.Kernel, x: Array, y: Array,
                    w: Array, *, backend: str | None = None,
                    tile: int | None = None, interpret: bool | None = None,
                    accumulator: str = "plain", finalize: bool = True,
                    init_state=None, return_state: bool = False,
                    precision: str | None = None, **kw) -> tuple:
    """(K_nm^T K_nm, K_nm^T w) through the resolved backend.

    The Pallas path is the fused one-pass `gram` kernel (row/column blocks
    ``bm``/``bn``, autotuned through `resolve_plan` unless passed
    explicitly); the XLA path is the engine-tiled row-slab accumulation in
    `repro.core.nystrom` (`streaming.tile_reduce`) with `tile` rows per
    step — ``tile=None`` means autotune.  Neither ever materializes the
    (n, m) cross-kernel matrix.

    Both backends implement the same ``accumulator`` strategies
    (`repro.core.streaming`): "plain" (historical fp32 running sum) and
    "compensated" (two-float error-carrying sum — a two-float VMEM
    accumulator inside the Pallas body).  ``finalize=False`` returns the
    raw accumulator state for a cross-chip psum (`streaming.mesh_reduce`).

    ``precision`` picks the Gram-contraction mode on both backends
    (`repro.core.precision`: "fp32" | "bf16x2" | "bf16x3").  ``None``
    resolves from the autotune plan when the tiling is being resolved
    anyway (tile/bm/bn None) and to the historical "fp32" when the caller
    pinned the tiling explicitly — an explicit-tile call stays bit-equal
    to pre-precision code.

    ``init_state=`` continues a prior raw state (first-class accumulator
    state, `repro.core.accstate`): the XLA scan threads it through the
    carry (tile-aligned chains are bit-equal to one fold); the Pallas
    kernel's VMEM accumulator cannot be seeded, so the chunk is reduced
    fresh and merged via the strategy's `merge`.  ``return_state=True``
    returns the raw state on either backend.
    """
    from repro.core import streaming as streaming_mod

    if resolve(backend) == "pallas":
        from repro.kernels.gram import ops as gram_ops
        if "bm" not in kw or "bn" not in kw:
            plan = resolve_plan("gram", x.shape[0], y.shape[0], x.shape[1],
                                dtype=x.dtype, backend="pallas",
                                accumulator=accumulator, precision=precision)
            kw.setdefault("bm", plan.bm)
            kw.setdefault("bn", plan.bn)
            if precision is None:
                precision = plan.precision
        want_raw = return_state or not finalize or init_state is not None
        state = gram_ops.gram_matrix(kernel, x, y, w, interpret=interpret,
                                     accumulator=accumulator,
                                     finalize=not want_raw,
                                     precision=precision or "fp32", **kw)
        if init_state is not None:
            state = streaming_mod.get(accumulator).merge(init_state, state)
        if return_state or not finalize:
            return state
        return streaming_mod.get(accumulator).finalize(state) \
            if want_raw else state
    from repro.core import nystrom
    if tile is None:
        plan = resolve_plan("gram", x.shape[0], y.shape[0], x.shape[1],
                            dtype=x.dtype, backend="xla",
                            accumulator=accumulator, precision=precision)
        tile = plan.tile
        if precision is None:
            precision = plan.precision
    return nystrom.scan_normal_eq(kernel, x, y, w, tile=tile,
                                  accumulator=accumulator, finalize=finalize,
                                  init_state=init_state,
                                  return_state=return_state,
                                  precision=precision or "fp32")


def binned_scatter(data: Array, lo: Array, spacing: Array, grid_size: int,
                   *, backend: str | None = None, weights: Array | None = None,
                   tile: int | None = None, bm: int | None = None,
                   interpret: bool | None = None,
                   accumulator: str = "plain", finalize: bool = True,
                   init_state=None, return_state: bool = False,
                   method: str = "window"):
    """Cloud-in-cell deposit onto a (grid_size,)^d grid, resolved backend.

    The deposit stage of the binned KDE (`repro.core.kde.kde_binned`).  The
    Pallas path (`repro.kernels.kde_binned`) keeps the grid VMEM-resident
    and streams sorted corner chunks through a segment-reduce (``bm``
    points per chunk, autotuned via `resolve_plan` when None); the XLA
    path is the windowed scatter-add in `repro.core.kde.scatter_cic`
    (engine-tiled `tile`-row slabs via `streaming.tile_reduce`;
    ``tile=None`` means autotune).  Both match the corner-loop oracle
    `repro.kernels.kde_binned.ref.binned_grid` to reduction-order
    tolerance.

    ``accumulator="compensated"`` carries the grid as a two-float (hi, lo)
    pair across tiles on BOTH backends — the segment-reduce kernel banks
    each sorted segment's two-sum error in a VMEM lo grid, so compensated
    deposits stay on Pallas (the historical serial kernel had no tile
    delta to compensate and forced an XLA reroute).  ``finalize=False``
    returns the accumulator state — structurally identical across backends
    — for a mesh psum (`core.distributed.kde_binned_sharded_multi`).

    The deposit is bandwidth-independent (only the grid geometry enters),
    which is why `kde.kde_binned_multi` / the CalibrateStage bandwidth sweep
    call this ONCE per grid and amortize it across every h candidate — the
    contract `kde.DepositState` makes first-class (state carries geometry,
    never bandwidth).  ``init_state=``/``return_state=`` thread raw
    accumulator state exactly like `gram_accumulate`: carried through the
    XLA scan, fresh-then-merged on Pallas.  ``method`` picks the XLA
    scatter formulation (`kde.scatter_cic`; ignored on Pallas, which is
    always segment-reduce).
    """
    from repro.core import streaming as streaming_mod

    if resolve(backend) == "pallas":
        from repro.kernels.kde_binned import ops as kb_ops
        if bm is None:
            bm = resolve_plan("deposit", data.shape[0], grid_size,
                              data.shape[1], dtype=data.dtype,
                              backend="pallas", accumulator=accumulator).bm
        want_raw = return_state or not finalize or init_state is not None
        state = kb_ops.binned_scatter(data, lo, spacing, grid_size,
                                      weights=weights, bm=bm,
                                      interpret=interpret,
                                      accumulator=accumulator,
                                      finalize=not want_raw)
        if init_state is not None:
            state = streaming_mod.get(accumulator).merge(init_state, state)
        if return_state or not finalize:
            return state
        return streaming_mod.get(accumulator).finalize(state) \
            if want_raw else state
    from repro.core import kde as core_kde
    if tile is None:
        tile = resolve_tile("deposit", data.shape[0], grid_size,
                            data.shape[1], dtype=data.dtype, backend="xla",
                            accumulator=accumulator)
    return core_kde.scatter_cic(data, lo, spacing, grid_size,
                                weights=weights, tile=tile,
                                accumulator=accumulator, finalize=finalize,
                                method=method, init_state=init_state,
                                return_state=return_state)
