"""jit'd public wrapper for the fused gram Pallas kernel.

Handles padding (rows to bm multiples at the ROW_SENTINEL coordinate so they
map to kernel value 0, landmarks to bn multiples, features to lane width),
dispatches Pallas (TPU) vs interpret (CPU validation) vs the pure-XLA
reference, and adapts `repro.core.kernels` kernel objects.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import kernels as core_kernels
from repro.core.kernels import EXACT_DIST_D, pad_rows_sentinel, round_up
from repro.kernels.gram import kernel as gk
from repro.kernels.gram import ref
from repro.kernels.pairwise.ops import kernel_params  # shared adapter

Array = jax.Array


def _pad(x: Array, rows: int, cols: int) -> Array:
    """Pad to (rows, cols): new COLUMNS are zero (distances unchanged), new
    ROWS sit at ROW_SENTINEL so every kernel map underflows to exactly 0."""
    d = x.shape[1]
    return pad_rows_sentinel(jnp.pad(x, ((0, 0), (0, cols - d))), rows)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "nu", "a", "sigma", "bm", "bn", "out_dtype",
                     "interpret", "use_pallas", "accumulator", "finalize",
                     "precision"),
)
def gram(
    x: Array,
    y: Array,
    w: Array,
    *,
    kind: str = "matern",
    nu: float = 1.5,
    a: float = 1.0,
    sigma: float = 1.0,
    bm: int = 256,
    bn: int = 256,
    out_dtype=None,
    interpret: bool | None = None,
    use_pallas: bool = True,
    accumulator: str = "plain",
    finalize: bool = True,
    precision: str = "fp32",
) -> tuple:
    """(n, d), (m, d), (n,) or (n, k) -> (K_nm^T K_nm (m, m), K_nm^T w).

    rhs matches w: (m,) for a 1-D w, (m, k) for a multi-column w (fused
    score-moment passes stack extra responses as columns).

    K_nm is never materialized: the Pallas kernel streams (bm, bn) tiles
    through VMEM and MXU-accumulates the Gram in one pass.  use_pallas=False
    falls back to the dense reference (oracle; small n only); interpret=None
    resolves to True off-TPU so the Pallas path is always runnable.
    out_dtype=None accumulates in the promoted input dtype (f32 floor).

    ``accumulator="compensated"`` runs the two-float VMEM accumulator body
    (`repro.core.streaming` semantics in-kernel): the G/rhs error sums ride
    as extra output blocks.  ``finalize=False`` returns the raw accumulator
    state — plain: (g, r); compensated: ((g, r), (g_lo, r_lo)) — the form
    `streaming.mesh_reduce` psums across chips; otherwise the pair is
    collapsed to (g + g_lo, r + r_lo).

    ``precision`` picks the G-contraction mode (`repro.core.precision`):
    "fp32" is the historical MXU dot; "bf16x2"/"bf16x3" split the kernel
    tiles into bf16 words and fold the partial matmuls error-compensated
    into the accumulator.  Distances always keep the exact_d path — only
    the kernel VALUES are ever split.
    """
    from repro.core import streaming

    acc = streaming.get(accumulator)
    compensated = acc.name == "compensated"
    squeeze = w.ndim == 1
    if out_dtype is None:
        out_dtype = jnp.promote_types(x.dtype, jnp.float32)
    if not use_pallas:
        g, r = ref.gram(x, y, w, kind=kind, nu=nu, a=a, sigma=sigma,
                        out_dtype=out_dtype)
        # the dense oracle is one fused dot: no cross-tile error to carry
        state = ((g, r), (jnp.zeros_like(g), jnp.zeros_like(r))) \
            if compensated else (g, r)
        return acc.finalize(state) if finalize else state
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = x.shape
    m, _ = y.shape
    bm_ = min(bm, round_up(n, 8))
    bn_ = min(bn, round_up(m, 128 if not interpret else 8))
    np_, mp = round_up(n, bm_), round_up(m, bn_)
    dp = round_up(d, 128) if not interpret else d
    w2 = w.astype(out_dtype)
    w2 = w2[:, None] if squeeze else w2
    out = gk.gram_padded(
        _pad(x, np_, dp),
        jnp.pad(y, ((0, mp - m), (0, dp - d))),
        jnp.pad(w2, ((0, np_ - n), (0, 0))),
        kind=kind, nu=nu, a=a, sigma=sigma, bm=bm_, bn=bn_,
        out_dtype=out_dtype, interpret=interpret,
        exact_d=d if d <= EXACT_DIST_D else 0,
        compensated=compensated, precision=precision,
    )

    def _r(r):
        return r[:m, 0] if squeeze else r[:m, :]

    if compensated:
        g, r, gl, rl = out
        state = ((g[:m, :m], _r(r)), (gl[:m, :m], _r(rl)))
    else:
        g, r = out
        state = (g[:m, :m], _r(r))
    return acc.finalize(state) if finalize else state


def gram_matrix(kernel: core_kernels.Kernel, x: Array, y: Array, w: Array,
                **kw) -> tuple:
    """Adapter taking a repro.core.kernels kernel object (Pallas path)."""
    return gram(x, y, w, **kernel_params(kernel), **kw)
