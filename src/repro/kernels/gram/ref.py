"""Pure-jnp oracle for the fused gram kernel.

Given the design X (n, d), landmarks Y (m, d) and responses w (n,), the
Nystrom normal equations need

    G   = K_nm^T K_nm    (m, m)
    rhs = K_nm^T w       (m,)

with K_nm[i, j] = k(||x_i - y_j||).  The oracle materializes K_nm — it exists
only to validate the Pallas kernel (tests/test_pallas_kernels.py) and the
lax.scan streaming path at small n; production code never forms K_nm.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.pairwise import ref as pw_ref

Array = jax.Array


def gram(
    x: Array,
    y: Array,
    w: Array,
    *,
    kind: str = "matern",
    nu: float = 1.5,
    a: float = 1.0,
    sigma: float = 1.0,
    out_dtype=jnp.float32,
) -> tuple[Array, Array]:
    """(K_nm^T K_nm, K_nm^T w) via the dense (n, m) matrix (oracle only)."""
    k_nm = pw_ref.pairwise(x, y, kind=kind, nu=nu, a=a, sigma=sigma,
                           out_dtype=jnp.float32)
    g = jax.lax.dot_general(k_nm, k_nm, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    rhs = jax.lax.dot_general(k_nm, w.astype(jnp.float32),
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return g.astype(out_dtype), rhs.astype(out_dtype)
