"""Pallas TPU kernel: fused kernel-tile + Gram accumulation in one pass.

The streaming Nystrom solve needs G = K_nm^T K_nm and rhs = K_nm^T w without
ever materializing the (n, m) cross-kernel matrix.  The TPU-native
formulation fuses the stationary-kernel map (same math as `pairwise`) with
the MXU rank-bm update of the Gram block:

  * grid (m/bn, m/bn, n/bm) — the row dimension is innermost, so each (j, k)
    Gram block stays resident in VMEM while all row tiles stream through it
    (the canonical Pallas accumulation pattern: init at i == 0, += after);
  * per step, two (bm, bn) kernel tiles kj = k(X_i, Y_j), kk = k(X_i, Y_k)
    are built in VMEM from the MXU cross term and fused element-wise map —
    they die in registers/VMEM, never visiting HBM.  On the diagonal
    (j == k) the two tiles are identical, so kk is only evaluated off it
    (lax.cond), saving m/bn kernel-map evaluations per row tile;
  * the rhs accumulator rides along as a diagonal epilogue, gated on j == k
    (its block index depends on j only and each j hits the diagonal exactly
    once per row tile, so it is never multi-counted) — the gate reuses the
    tile the diagonal already has instead of spending the k == 0 pass on it;
  * VMEM per program at d=128, bm=bn=256: x (bm, d) + 2 y-tiles (bn, d)
    + 2 kernel tiles (bm, bn) + G block (bn, bn) fp32 ~= 1.1 MB — far under
    budget, so the row stream double-buffers;
  * ``compensated=True`` accumulates G and rhs as two-float (hi, lo) VMEM
    pairs (`repro.core.streaming` semantics in-kernel): each rank-bm update
    is folded in with Knuth's TwoSum and the rounding error banked in the
    lo block — the cross-tile fp32 accumulation error disappears, which is
    what lets `nystrom.solve_normal_eq` lower its spectral truncation floor
    on the TPU path exactly like the XLA engine path.

Padded rows are placed at the ROW_SENTINEL coordinate by ops.py: their
distance to any real landmark is ~1e6, and every kernel map underflows
exp(-1e6) to exactly 0.0, so they contribute nothing — no masking needed in
the body.  Padded landmark columns produce garbage only in the sliced-off
region of G/rhs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

# Shared with the core streaming solve: rows parked here are ~1e6 away from
# any real data, so all supported kernel maps underflow to exactly 0.0.
from repro.core.kernels import ROW_SENTINEL, exact_sq_dists  # noqa: E402,F401
from repro.core import precision as precision_mod


def _kernel_tile(x, y, *, kind: str, nu: float, a: float,
                 inv_two_sigma_sq: float, exact_d: int = 0):
    """(bm, d) x (bn, d) -> (bm, bn) kernel tile; same math as pairwise.

    exact_d > 0 assembles squared distances from exact per-coordinate
    differences (`core.kernels.exact_sq_dists` — the MXU expansion cancels
    catastrophically near r = 0 at small d, see core.kernels.EXACT_DIST_D);
    sentinel-padded rows still map through huge distances to exactly 0.
    """
    if exact_d > 0:
        sq = exact_sq_dists(x, y, exact_d)
    else:
        xy = jax.lax.dot_general(
            x, y, (((1,), (1,)), ((), ())), preferred_element_type=x.dtype
        )
        x2 = jnp.sum(x * x, axis=1)[:, None]
        y2 = jnp.sum(y * y, axis=1)[None, :]
        sq = jnp.maximum(x2 + y2 - 2.0 * xy, 0.0)
    if kind == "gaussian":
        return jnp.exp(-sq * inv_two_sigma_sq)
    ar = a * jnp.sqrt(sq)
    if nu == 0.5:
        return jnp.exp(-ar)
    if nu == 1.5:
        return (1.0 + ar) * jnp.exp(-ar)
    return (1.0 + ar + ar * ar * (1.0 / 3.0)) * jnp.exp(-ar)  # nu == 2.5


def _two_sum_store(hi_ref, lo_ref, update):
    """Fold `update` into the (hi, lo) two-float VMEM accumulator.

    Knuth TwoSum against the resident hi block, banking the rounding error
    in lo — the VMEM form of `repro.core.streaming.two_sum`.  Mosaic/XLA do
    not reassociate float arithmetic, so the cancellation pattern survives.
    """
    hi = hi_ref[...]
    s = hi + update
    bb = s - hi
    err = (hi - (s - bb)) + (update - bb)
    hi_ref[...] = s
    lo_ref[...] += err


def _gram_body(x_ref, yj_ref, yk_ref, w_ref, g_ref, r_ref, *refs, kind: str,
               nu: float, a: float, inv_two_sigma_sq: float, exact_d: int,
               compensated: bool, precision: str):
    gl_ref, rl_ref = refs if compensated else (None, None)
    k = pl.program_id(1)
    i = pl.program_id(2)
    # f32 compute floor; preserves f64 when fed f64 (interpret-mode parity
    # tests under enable_x64 — real TPUs only ever see f32/bf16 inputs).
    acc = jnp.promote_types(x_ref.dtype, jnp.float32)
    x = x_ref[...].astype(acc)    # (bm, d) row tile
    yj = yj_ref[...].astype(acc)  # (bn, d) landmark tile j
    yk = yk_ref[...].astype(acc)  # (bn, d) landmark tile k
    tile = functools.partial(_kernel_tile, kind=kind, nu=nu, a=a,
                             inv_two_sigma_sq=inv_two_sigma_sq,
                             exact_d=exact_d)
    j = pl.program_id(0)
    kj = tile(x, yj)                      # (bm, bn)
    kk = jax.lax.cond(j == k, lambda: kj, lambda: tile(x, yk))

    @pl.when(i == 0)
    def _():
        g_ref[...] = jnp.zeros_like(g_ref)
        if compensated:
            gl_ref[...] = jnp.zeros_like(gl_ref)

    # Rank-bm update of G[j, k].  fp32 mode is the historical single MXU
    # dot; the bf16 modes decompose the KERNEL-VALUE tiles (never the
    # coordinates — distances keep the exact_d path above) into bf16 words
    # and run the cross products as full-rate bf16 matmuls.  Partials
    # arrive smallest-magnitude-first; in compensated mode EACH partial is
    # folded through its own TwoSum so the combination error lands in the
    # lo block (error-compensated partial combination).
    g_parts = precision_mod.split_dot_partials(
        kj, kk, (((0,), (0,)), ((), ())), precision, acc)
    if compensated:
        for p in g_parts:
            _two_sum_store(g_ref, gl_ref, p.astype(g_ref.dtype))
    else:
        for p in g_parts:
            g_ref[...] += p.astype(g_ref.dtype)

    @pl.when(jnp.logical_and(i == 0, k == 0))
    def _():
        r_ref[...] = jnp.zeros_like(r_ref)
        if compensated:
            rl_ref[...] = jnp.zeros_like(rl_ref)

    @pl.when(j == k)
    def _():
        # rhs is a skinny (bm, cols) gemv-shaped product: bandwidth-bound,
        # so it stays a plain fp32 dot under every precision mode.
        w = w_ref[...].astype(acc)     # (bm, cols)
        r_up = jax.lax.dot_general(
            kj, w, (((0,), (0,)), ((), ())),
            preferred_element_type=acc,
        ).astype(r_ref.dtype)
        if compensated:
            _two_sum_store(r_ref, rl_ref, r_up)
        else:
            r_ref[...] += r_up


@functools.partial(
    jax.jit,
    static_argnames=("kind", "nu", "a", "sigma", "bm", "bn", "out_dtype",
                     "interpret", "exact_d", "compensated", "precision"),
)
def gram_padded(
    x: Array,
    y: Array,
    w: Array,
    *,
    kind: str = "matern",
    nu: float = 1.5,
    a: float = 1.0,
    sigma: float = 1.0,
    bm: int = 256,
    bn: int = 256,
    out_dtype=jnp.float32,
    interpret: bool = False,
    exact_d: int = 0,
    compensated: bool = False,
    precision: str = "fp32",
) -> tuple[Array, ...]:
    """Core pallas_call; requires n % bm == 0 and m % bn == 0 (see ops.py).

    ``compensated=True`` doubles the output blocks: (G_hi, rhs_hi, G_lo,
    rhs_lo), the two-float VMEM accumulator pair (each (j, k) hi/lo block
    pair stays resident while the row stream passes; VMEM cost is one extra
    (bn, bn) + (bn, 1) block — still far under budget at bm=bn=256).
    """
    n, d = x.shape
    m, _ = y.shape
    cols = w.shape[1]     # response columns (fused multi-rhs rides along)
    assert n % bm == 0 and m % bn == 0, (n, m, bm, bn)
    grid = (m // bn, m // bn, n // bm)
    body = functools.partial(
        _gram_body,
        kind=kind,
        nu=float(nu),
        a=float(a),
        inv_two_sigma_sq=1.0 / (2.0 * float(sigma) ** 2),
        exact_d=int(exact_d),
        compensated=compensated,
        precision=precision_mod.check(precision),
    )
    out_specs = [
        pl.BlockSpec((bn, bn), lambda j, k, i: (j, k)),      # G block
        pl.BlockSpec((bn, cols), lambda j, k, i: (j, 0)),    # rhs block
    ]
    out_shape = [
        jax.ShapeDtypeStruct((m, m), out_dtype),
        jax.ShapeDtypeStruct((m, cols), out_dtype),
    ]
    if compensated:
        out_specs = out_specs + [
            pl.BlockSpec((bn, bn), lambda j, k, i: (j, k)),  # G_lo block
            pl.BlockSpec((bn, cols), lambda j, k, i: (j, 0)),  # rhs_lo block
        ]
        out_shape = out_shape + [
            jax.ShapeDtypeStruct((m, m), out_dtype),
            jax.ShapeDtypeStruct((m, cols), out_dtype),
        ]
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda j, k, i: (i, 0)),   # row tile
            pl.BlockSpec((bn, d), lambda j, k, i: (j, 0)),   # landmarks j
            pl.BlockSpec((bn, d), lambda j, k, i: (k, 0)),   # landmarks k
            pl.BlockSpec((bm, cols), lambda j, k, i: (i, 0)),  # responses
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, y, y, w)
