"""Fused streaming Gram accumulation kernel (kernel.py / ops.py / ref.py)."""
