"""Pallas TPU kernel: Mamba2 SSD chunked scan.

Grid (batch, heads, n_chunks); the LAST axis is the sequential chunk axis, so
the inter-chunk SSM state (headdim p, dstate s) lives in fp32 VMEM scratch and
is carried across grid steps (the TPU grid is sequential).  Per chunk:

  intra-chunk   y_ij += (C_i . B_j) * exp(Acum_i - Acum_j) * x_j   (c x c MXU)
  inter-chunk   y_i  += exp(Acum_i) * C_i . S_prev^T               (c x s MXU)
  state update  S    <- exp(Acum_last) * S + (decay_out * x)^T B   (p x c MXU)

All decays are exp of non-positive sums => bounded by 1, so fp32 is safe.
The attention-like (c x c) matrix only ever exists per-tile in VMEM — this is
the "linear-attention duality" form of the scan, MXU-dominated instead of the
bandwidth-bound elementwise recurrence.

Layouts: x (b, h, l, p); a_log (b, h, l); B, C (b, l, s) shared across heads
(ngroups=1, as in Mamba2 / Zamba2 defaults).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _ssd_body(x_ref, a_ref, b_ref, c_ref, o_ref, s_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0, 0].astype(jnp.float32)   # (c, p)
    a = a_ref[0, 0].astype(jnp.float32)   # (1, c) -- kept 2D for TPU layout
    Bc = b_ref[0].astype(jnp.float32)     # (c, s)
    Cc = c_ref[0].astype(jnp.float32)     # (c, s)

    a_cum = jnp.cumsum(a[0])              # (c,) inclusive
    a_last = a_cum[chunk - 1]

    # intra-chunk: (C B^T  *  exp(segsum)) @ x
    G = jax.lax.dot_general(
        Cc, Bc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (c, c)
    diff = a_cum[:, None] - a_cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(jj <= ii, jnp.exp(diff), 0.0)
    y = jax.lax.dot_general(
        G * L, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (c, p)

    # inter-chunk: decay_in * C @ S^T
    S = s_scr[...]                        # (p, s)
    y += jnp.exp(a_cum)[:, None] * jax.lax.dot_general(
        Cc, S, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (c, p)

    o_ref[0, 0] = y.astype(o_ref.dtype)

    # state update for the next chunk
    decay_out = jnp.exp(a_last - a_cum)   # (c,)
    s_scr[...] = jnp.exp(a_last) * S + jax.lax.dot_general(
        decay_out[:, None] * x, Bc, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (p, s)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_padded(
    x: Array,
    a_log: Array,
    B: Array,
    C: Array,
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> Array:
    """x (b,h,l,p), a_log (b,h,l), B,C (b,l,s); l % chunk == 0."""
    b, h, l, p = x.shape
    s = B.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    grid = (b, h, nc)
    a3 = a_log.reshape(b, h, nc, chunk)  # blocked as (1,1,1,chunk)
    body = functools.partial(_ssd_body, chunk=chunk)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, chunk, s), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, s), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, l, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, s), jnp.float32)],
        interpret=interpret,
    )(x, a3, B, C)
