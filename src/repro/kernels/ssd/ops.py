"""jit'd public wrapper for the SSD Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd import kernel as sk
from repro.kernels.ssd import ref

Array = jax.Array


def _round_up(v: int, b: int) -> int:
    return -(-v // b) * b


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "use_pallas"))
def ssd(
    x: Array,
    a_log: Array,
    B: Array,
    C: Array,
    *,
    chunk: int = 64,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> Array:
    """Mamba2 SSD scan.  x (b,l,h,p), a_log (b,l,h), B,C (b,l,s) -> (b,l,h,p).

    Sequence is zero-padded to a chunk multiple; padded steps have a_log = 0
    (decay 1) and x = 0, so they do not perturb the state, and their outputs
    are sliced off.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, l, h, p = x.shape
    chunk = min(chunk, _round_up(l, 8))
    lp = _round_up(l, chunk)
    if lp != l:
        x = jnp.pad(x, ((0, 0), (0, lp - l), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, lp - l), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, lp - l), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, lp - l), (0, 0)))
    if not use_pallas:
        return ref.ssd_chunked(x, a_log, B, C, chunk=chunk)[:, :l]
    xh = jnp.moveaxis(x, 2, 1)        # (b,h,l,p)
    ah = jnp.moveaxis(a_log, 2, 1)    # (b,h,l)
    out = sk.ssd_padded(xh, ah, B, C, chunk=chunk, interpret=interpret)
    return jnp.moveaxis(out, 1, 2)[:, :l]
