"""Pure-jnp oracles for the Mamba2 SSD (state-space duality) kernel.

Semantics (ngroups = 1, B/C shared across heads):

  state  S_t = a_t * S_{t-1} + x_t (x) B_t      S: (headdim p, dstate s)
  output y_t = S_t . C_t                        per head

with a_t = exp(a_log_t) in (0, 1] the discretised decay (a_log = Delta*A <= 0).

Two references:
  * ``ssd_scan``    — the literal recurrence via lax.scan (ground truth);
  * ``ssd_chunked`` — the chunked/segsum SSD reformulation (Mamba2 paper,
    Listing 1), which the Pallas kernel mirrors tile-for-tile.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def ssd_scan(x: Array, a_log: Array, B: Array, C: Array) -> Array:
    """x: (b,l,h,p), a_log: (b,l,h), B,C: (b,l,s) -> y: (b,l,h,p)."""
    b, l, h, p = x.shape
    s = B.shape[-1]

    def step(S, inp):
        x_t, a_t, B_t, C_t = inp  # (b,h,p), (b,h), (b,s), (b,s)
        S = S * jnp.exp(a_t)[..., None, None] + jnp.einsum("bhp,bs->bhps", x_t, B_t)
        y = jnp.einsum("bhps,bs->bhp", S, C_t)
        return S, y

    S0 = jnp.zeros((b, h, p, s), dtype=jnp.float32)
    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(a_log, 1, 0).astype(jnp.float32),
        jnp.moveaxis(B, 1, 0).astype(jnp.float32),
        jnp.moveaxis(C, 1, 0).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (b,l,h,p)


def _segsum(a_cum: Array) -> Array:
    """L[i, j] = sum_{t=j+1..i} a_log_t for j <= i, -inf above the diagonal."""
    c = a_cum.shape[-1]
    diff = a_cum[..., :, None] - a_cum[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), dtype=bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: Array, a_log: Array, B: Array, C: Array, *,
                chunk: int = 64) -> Array:
    """Chunked SSD; identical output to ssd_scan (tested)."""
    b, l, h, p = x.shape
    s = B.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    xf = x.reshape(b, nc, chunk, h, p).astype(jnp.float32)
    af = a_log.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bf = B.reshape(b, nc, chunk, s).astype(jnp.float32)
    Cf = C.reshape(b, nc, chunk, s).astype(jnp.float32)

    a_cum = jnp.cumsum(af, axis=2)  # (b,nc,c,h) inclusive
    L = jnp.exp(_segsum(jnp.moveaxis(a_cum, 3, 2)))  # (b,nc,h,c,c)
    G = jnp.einsum("bnis,bnjs->bnij", Cf, Bf)  # (b,nc,c,c)
    y_intra = jnp.einsum("bnij,bnhij,bnjhp->bnihp", G, L, xf)

    # chunk-end states: S_n = sum_j exp(A_last - A_cum_j) x_j (x) B_j
    a_last = a_cum[:, :, -1:, :]  # (b,nc,1,h)
    decay_out = jnp.exp(a_last - a_cum)  # (b,nc,c,h)
    states = jnp.einsum("bnch,bnchp,bncs->bnhps", decay_out, xf, Bf)

    # inter-chunk recurrence over chunk index: S_prev scan
    chunk_decay = jnp.exp(a_last[:, :, 0, :])  # (b,nc,h) total decay per chunk

    def step(S, inp):
        st, dec = inp  # (b,h,p,s), (b,h)
        S_out = S  # state entering this chunk
        S = S * dec[..., None, None] + st
        return S, S_out

    S0 = jnp.zeros((b, h, p, s), dtype=jnp.float32)
    _, S_in = jax.lax.scan(
        step, S0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    S_in = jnp.moveaxis(S_in, 0, 1)  # (b,nc,h,p,s) state entering each chunk

    decay_in = jnp.exp(a_cum)  # (b,nc,c,h)
    y_inter = jnp.einsum("bnch,bncs,bnhps->bnchp", decay_in, Cf, S_in)
    y = y_intra + y_inter
    return y.reshape(b, l, h, p).astype(x.dtype)
