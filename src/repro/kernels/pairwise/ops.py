"""jit'd public wrapper for the pairwise Pallas kernel.

Handles padding to MXU-aligned tiles, dispatches Pallas (TPU) vs interpret
(CPU validation) vs the pure-XLA reference, and adapts `repro.core.kernels`
kernel objects to the static kernel-map parameters.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import kernels as core_kernels
from repro.core.kernels import EXACT_DIST_D, round_up
from repro.kernels.pairwise import kernel as pk
from repro.kernels.pairwise import ref

Array = jax.Array


def _pad_to(x: Array, rows: int, cols: int) -> Array:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def kernel_params(kernel: core_kernels.Kernel) -> dict:
    """Static kernel-map parameters from a core kernel object."""
    if isinstance(kernel, core_kernels.Gaussian):
        return dict(kind="gaussian", nu=0.0, a=1.0, sigma=float(kernel.sigma))
    if isinstance(kernel, core_kernels.Matern):
        return dict(kind="matern", nu=float(kernel.nu), a=float(kernel.a), sigma=1.0)
    raise TypeError(f"unsupported kernel {kernel!r}")


@functools.partial(
    jax.jit,
    static_argnames=("kind", "nu", "a", "sigma", "bm", "bn", "out_dtype",
                     "interpret", "use_pallas"),
)
def pairwise(
    x: Array,
    y: Array,
    *,
    kind: str = "matern",
    nu: float = 1.5,
    a: float = 1.0,
    sigma: float = 1.0,
    bm: int = 256,
    bn: int = 256,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> Array:
    """(n, d) x (m, d) -> (n, m) stationary-kernel matrix.

    use_pallas=False falls back to the fused-XLA reference (identical math);
    interpret=None resolves to True on non-TPU backends so the Pallas path is
    always runnable for validation.
    """
    if not use_pallas:
        return ref.pairwise(x, y, kind=kind, nu=nu, a=a, sigma=sigma,
                            out_dtype=out_dtype)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = x.shape
    m, _ = y.shape
    bm_ = min(bm, round_up(n, 8))
    bn_ = min(bn, round_up(m, 128))
    np_, mp = round_up(n, bm_), round_up(m, bn_)
    dp = round_up(d, 128) if not interpret else d  # zero-pad features: distances unchanged
    out = pk.pairwise_padded(
        _pad_to(x, np_, dp), _pad_to(y, mp, dp),
        kind=kind, nu=nu, a=a, sigma=sigma, bm=bm_, bn=bn_,
        out_dtype=out_dtype, interpret=interpret,
        exact_d=d if d <= EXACT_DIST_D else 0,
    )
    return out[:n, :m]


def kernel_matrix(kernel: core_kernels.Kernel, x: Array, y: Array | None = None,
                  **kw) -> Array:
    """Drop-in replacement for repro.core.kernels.kernel_matrix (Pallas path)."""
    sym = y is None
    y = x if sym else y
    out = pairwise(x, y, **kernel_params(kernel), **kw)
    if sym:
        # pin the diagonal: K(0) = 1 for every kernel we support
        n = x.shape[0]
        out = out * (1.0 - jnp.eye(n, dtype=out.dtype)) + jnp.eye(n, dtype=out.dtype)
    return out
