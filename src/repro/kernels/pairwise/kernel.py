"""Pallas TPU kernel: tiled stationary-kernel (Gram) matrix.

This is the O(n m d) hot spot of the whole paper pipeline — Nystrom needs
K(X, X_S) (n x d_sub) and K(X_S, X_S), and the direct KDE path needs the same
tile structure.  The TPU-native formulation:

  * grid (ceil(n/bm), ceil(m/bn)); each program owns one (bm, bn) output tile;
  * x-tile (bm, d) and y-tile (bn, d) live in VMEM; the cross term x.y^T runs
    on the MXU via dot_general with fp32 accumulation;
  * the squared distance assembly and the stationary-kernel map (Matern
    0.5/1.5/2.5 or Gaussian) are fused element-wise in VMEM — the n x m
    distance matrix is never materialised in HBM at any other precision;
  * block sizes default to 256 x 256 (MXU-aligned; VMEM footprint at d=128:
    2*(256*128) + 256*256 fp32 ~= 0.5 MB, far under the ~16 MB budget, so the
    pipeline can double-buffer).

Rows/cols beyond (n, m) come from wrapper padding; the pad region is sliced
off in ops.py, so no masking is needed here (the map is total on sq >= 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.kernels import exact_sq_dists

Array = jax.Array


def _sq_dist_tile(x, y, exact_d: int):
    """(bm, d) x (bn, d) -> (bm, bn) squared distances.

    exact_d > 0 accumulates exact per-coordinate differences over the first
    exact_d feature columns (`core.kernels.exact_sq_dists` — 2-D VPU
    broadcasts; well-conditioned near r = 0, where the MXU expansion cancels
    catastrophically, see core.kernels.EXACT_DIST_D).  Padded feature
    columns past exact_d are all-zero and contribute nothing either way.
    """
    if exact_d > 0:
        return exact_sq_dists(x, y, exact_d)
    # MXU cross term with explicit fp32 accumulation.
    xy = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bm, bn)
    x2 = jnp.sum(x * x, axis=1)[:, None]
    y2 = jnp.sum(y * y, axis=1)[None, :]
    return jnp.maximum(x2 + y2 - 2.0 * xy, 0.0)


def _kernel_body(x_ref, y_ref, out_ref, *, kind: str, nu: float, a: float,
                 inv_two_sigma_sq: float, exact_d: int):
    x = x_ref[...].astype(jnp.float32)  # (bm, d)
    y = y_ref[...].astype(jnp.float32)  # (bn, d)
    sq = _sq_dist_tile(x, y, exact_d)
    if kind == "gaussian":
        k = jnp.exp(-sq * inv_two_sigma_sq)
    else:
        ar = a * jnp.sqrt(sq)
        if nu == 0.5:
            k = jnp.exp(-ar)
        elif nu == 1.5:
            k = (1.0 + ar) * jnp.exp(-ar)
        else:  # nu == 2.5
            k = (1.0 + ar + ar * ar * (1.0 / 3.0)) * jnp.exp(-ar)
    out_ref[...] = k.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "nu", "a", "sigma", "bm", "bn", "out_dtype",
                     "interpret", "exact_d"),
)
def pairwise_padded(
    x: Array,
    y: Array,
    *,
    kind: str = "matern",
    nu: float = 1.5,
    a: float = 1.0,
    sigma: float = 1.0,
    bm: int = 256,
    bn: int = 256,
    out_dtype=jnp.float32,
    interpret: bool = False,
    exact_d: int = 0,
) -> Array:
    """Core pallas_call; requires n % bm == 0 and m % bn == 0 (see ops.py)."""
    n, d = x.shape
    m, _ = y.shape
    assert n % bm == 0 and m % bn == 0, (n, m, bm, bn)
    grid = (n // bm, m // bn)
    body = functools.partial(
        _kernel_body,
        kind=kind,
        nu=float(nu),
        a=float(a),
        inv_two_sigma_sq=1.0 / (2.0 * float(sigma) ** 2),
        exact_d=int(exact_d),
    )
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), out_dtype),
        interpret=interpret,
    )(x, y)
