"""Pure-jnp oracle for the tiled stationary-kernel matrix kernel.

K[i, j] = k(||x_i - y_j||) for an isotropic stationary kernel k, computed with
the MXU-friendly expansion ||x-y||^2 = ||x||^2 + ||y||^2 - 2 x.y^T.  This is
the reference the Pallas kernel is validated against (tests/test_pallas_pairwise.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kernels import EXACT_DIST_D, exact_sq_dists

Array = jax.Array


def sq_dists(x: Array, y: Array) -> Array:
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    if x.shape[-1] <= EXACT_DIST_D:
        # Exact per-coordinate differences: the expansion below cancels
        # catastrophically near r = 0 at small d (see core.kernels._sq_dists).
        return exact_sq_dists(x, y, x.shape[-1])
    x2 = jnp.sum(x * x, axis=-1)[:, None]
    y2 = jnp.sum(y * y, axis=-1)[None, :]
    return jnp.maximum(x2 + y2 - 2.0 * (x @ y.T), 0.0)


def apply_map(sq: Array, *, kind: str, nu: float, a: float, sigma: float) -> Array:
    """Map squared distances through the stationary kernel profile."""
    if kind == "gaussian":
        return jnp.exp(-sq / (2.0 * sigma * sigma))
    if kind != "matern":
        raise ValueError(f"unknown kernel kind {kind!r}")
    ar = a * jnp.sqrt(sq)
    if nu == 0.5:
        return jnp.exp(-ar)
    if nu == 1.5:
        return (1.0 + ar) * jnp.exp(-ar)
    if nu == 2.5:
        return (1.0 + ar + ar * ar / 3.0) * jnp.exp(-ar)
    raise ValueError(f"unsupported Matern nu={nu}")


def pairwise(
    x: Array,
    y: Array,
    *,
    kind: str = "matern",
    nu: float = 1.5,
    a: float = 1.0,
    sigma: float = 1.0,
    out_dtype=jnp.float32,
) -> Array:
    """(n, d) x (m, d) -> (n, m) kernel matrix, fp32 internally."""
    return apply_map(sq_dists(x, y), kind=kind, nu=nu, a=a, sigma=sigma).astype(out_dtype)
