"""Pallas TPU kernel: causal GQA flash attention with sliding-window support.

Design (FlashAttention-2 style, adapted to the TPU grid-accumulation idiom):

  * grid (batch, n_q_heads, n_q_blocks, n_k_blocks); the LAST grid axis is the
    KV reduction axis, so the online-softmax state lives in VMEM scratch and
    is carried across consecutive k-steps of the sequential TPU grid;
  * q tile (block_q, head_dim) is revisited for every k-step (its index_map
    ignores the k axis => stays resident in VMEM); k/v tiles (block_k,
    head_dim) stream through; GQA is expressed purely in the k/v index_map
    (kv_head = q_head // group) — no repeated k/v in HBM;
  * scores on the MXU in fp32, running max m_i / normaliser l_i / accumulator
    acc in fp32 scratch; output written once on the final k-step;
  * causal + sliding-window masking by position arithmetic inside the tile;
    fully-masked k-blocks are skipped with pl.when (the dominant saving for
    causal attention: ~2x, and ~seq/window x with a window);
  * unlike the "one-hot" repeat path, VMEM footprint is
    block_q*dh + 2*block_k*dh + block_q*block_k fp32 ~= 0.9 MB at 256/256/128.

Assumes sq == skv (training / prefill self-attention).  Decode uses the
serving path (one-token attention is bandwidth-bound and XLA-fused).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _flash_body(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, causal: bool, window: int,
                block_q: int, block_k: int, n_k_blocks: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = kj * block_k

    # Block-level skip: for causal masks every k beyond the q diagonal is dead;
    # for sliding windows every k older than (q_start - window) is dead too.
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + block_q - 1)
    if window > 0:
        live = jnp.logical_and(live, k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (block_q, dh)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (block_q, block_k)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]          # (block_q, 1)
        l_prev = l_scr[...]          # (block_q, 1)
        m_cur = jnp.max(s, axis=1)[:, None]
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)           # rescale of old state
        p = jnp.exp(s - m_new)                    # (block_q, block_k)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)[:, None]
        v = v_ref[0, 0].astype(jnp.float32)       # (block_k, dh)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kj == n_k_blocks - 1)
    def _finalize():
        # rows that never saw a live key (can't happen with causal self-attn,
        # possible with pure-window configs on padded rows): emit zeros.
        l = l_scr[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "block_q", "block_k",
                     "interpret"),
)
def flash_attention_padded(
    q: Array,
    k: Array,
    v: Array,
    *,
    scale: float,
    causal: bool = True,
    window: int = -1,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> Array:
    """Core pallas_call; seq pre-padded to block multiples, sq == skv."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    assert sq == skv and sq % block_q == 0 and skv % block_k == 0
    assert hq % hkv == 0
    group = hq // hkv
    n_q_blocks = sq // block_q
    n_k_blocks = skv // block_k
    grid = (b, hq, n_q_blocks, n_k_blocks)

    body = functools.partial(
        _flash_body,
        scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k_blocks=n_k_blocks,
    )
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            # online-softmax state: running max, normaliser, fp32 accumulator
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
