"""jit'd public wrapper for flash attention (padding + backend dispatch)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as fk
from repro.kernels.flash_attention import ref

Array = jax.Array


def _round_up(v: int, b: int) -> int:
    return -(-v // b) * b


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "interpret", "use_pallas"),
)
def attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int = -1,
    scale: float | None = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> Array:
    """Causal GQA (flash) attention; (b, hq, s, dh) -> (b, hq, s, dh).

    Sequence is zero-padded to tile multiples; padded q rows attend causally to
    real keys and are sliced off, and padded k rows are after every real q row
    so the causal mask removes them (for the non-causal path we clamp to the
    reference instead).
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if not use_pallas or not causal:
        # non-causal padding would need explicit length masking; XLA path is
        # used for the (rare) bidirectional case.
        return ref.attention(q, k, v, causal=causal, window=window, scale=scale)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, hq, s, dh = q.shape
    bq = min(block_q, _round_up(s, 128 if not interpret else 8))
    bk = min(block_k, _round_up(s, 128 if not interpret else 8))
    sp = _round_up(s, max(bq, bk))
    bq = min(bq, sp)
    bk = min(bk, sp)
    if sp != s:
        pad = ((0, 0), (0, 0), (0, sp - s), (0, 0))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    out = fk.flash_attention_padded(
        q, k, v, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=interpret,
    )
    return out[:, :, :s, :]
