"""Pure-jnp oracle: causal GQA attention with optional sliding window.

Shapes follow the LM stack convention:
  q: (batch, n_q_heads, seq, head_dim)
  k, v: (batch, n_kv_heads, seq, head_dim)       n_q_heads % n_kv_heads == 0
Returns (batch, n_q_heads, seq, head_dim).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -1e30


def attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int = -1,
    scale: float | None = None,
) -> Array:
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = dh ** -0.5
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kq.astype(jnp.float32)
    ) * scale
    qpos = jnp.arange(sq)[:, None] + (skv - sq)  # align ends (self-attn: offset 0)
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vq.astype(jnp.float32))
    return out.astype(q.dtype)
