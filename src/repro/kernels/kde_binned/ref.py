"""Pure-jnp oracle for the binned-KDE scatter kernel.

Cloud-in-cell (multilinear) deposit of n weighted points onto a regular
(grid_size,)^d lattice: each point spreads its mass over the 2^d corners of
its cell with product-of-(1-f, f) weights.  This is the historical one-shot
corner-loop formulation (one scatter-add per corner) — O(n 2^d) updates,
dense in the grid; it exists to validate the Pallas kernel and the windowed
streaming path in `repro.core.kde.scatter_cic`, which compute the same sums
tile by tile.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# One shared lattice-coordinate rule: the Pallas path must bin points
# exactly like the XLA deposit or the parity tests validate nothing.
from repro.core.kde import cic_prep, gather_cic

Array = jax.Array


def binned_grid(points: Array, lo: Array, spacing: Array, grid_size: int,
                weights: Array | None = None) -> Array:
    """Corner-loop CIC deposit (oracle): one dense scatter-add per corner."""
    n, d = points.shape
    base, frac = cic_prep(points, lo, spacing, grid_size)
    grid = jnp.zeros((grid_size,) * d, dtype=points.dtype)
    for corner in range(2 ** d):
        offs = jnp.array([(corner >> k) & 1 for k in range(d)],
                         dtype=jnp.int32)
        idx = base + offs[None, :]
        w = jnp.prod(jnp.where(offs[None, :] == 1, frac, 1.0 - frac), axis=1)
        if weights is not None:
            w = w * weights
        grid = grid.at[tuple(idx[:, k] for k in range(d))].add(w)
    return grid


def gather(grid: Array, query: Array, lo: Array, spacing: Array,
           grid_size: int) -> Array:
    """Multilinear (CIC-adjoint) interpolation of `grid` at the query points."""
    return gather_cic(grid, query, lo, spacing, grid_size)
