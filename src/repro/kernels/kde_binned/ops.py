"""jit'd public wrapper for the binned-KDE scatter Pallas kernel.

Precomputes the vectorizable parts of the cloud-in-cell deposit — all O(n)
arrays, keeping the streaming-memory contract (the Pallas body builds each
corner's 2-nonzero lane row itself and is otherwise a pure segment-reduce,
see kernel.py):

  * `rows`  — per corner (2^(d-1) per point), the flattened sublane row
    index of the stencil corner over the leading d-1 lattice axes;
  * `cw`    — the matching product-of-(1-f, f) corner weight, scaled by
    the optional point weight (zeroed on padded corners, so no masking is
    needed in the kernel);
  * `blast` / `flast` — the last-axis base lane + fraction the body's iota
    compare expands into the lane deposit row;
  * per kc-corner chunk (kc = bm * 2^(d-1), one kernel grid step), the
    corner stream is SORTED by `rows` and `segend` marks the last corner
    of every equal-row run — the kernel then performs one VMEM
    read-modify-write per distinct row instead of one per corner, which
    both vectorizes duplicate-cell collisions and exposes each segment as
    an additive delta the compensated (hi, lo) accumulator can two-sum.

Rows are padded to bm multiples (zero weight, row 0 — the pads sort into
the first segment and deposit nothing); lane padding (g -> 128-multiples
on TPU) is sliced off before the (g,)^d reshape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.kernels import round_up
from repro.kernels.kde_binned import kernel as kk
from repro.kernels.kde_binned import ref

Array = jax.Array


@functools.partial(
    jax.jit, static_argnames=("grid_size", "bm", "interpret", "use_pallas",
                              "accumulator", "finalize")
)
def binned_scatter(
    data: Array,
    lo: Array,
    spacing: Array,
    grid_size: int,
    *,
    weights: Array | None = None,
    bm: int = 256,
    interpret: bool | None = None,
    use_pallas: bool = True,
    accumulator: str = "plain",
    finalize: bool = True,
):
    """(n, d) points -> (grid_size,)^d CIC mass grid (Pallas path).

    Matches `ref.binned_grid` / `repro.core.kde.scatter_cic` to fp32
    reduction-order tolerance.  use_pallas=False falls back to the corner-
    loop oracle; interpret=None resolves to True off-TPU.

    ``accumulator="compensated"`` runs the kernel's two-float (hi, lo)
    grid (kernel.py) — the same strategy `repro.core.streaming` uses, so
    with ``finalize=False`` the returned (hi, lo) state matches the XLA
    engine's and can cross a mesh psum un-collapsed
    (`core.distributed.kde_binned_sharded_multi`).
    """
    n, d = data.shape
    if not 1 <= d <= 3:
        raise ValueError(f"binned_scatter supports 1 <= d <= 3, got d={d}")
    compensated = accumulator == "compensated"
    if not use_pallas:
        grid = ref.binned_grid(data, lo, spacing, grid_size, weights=weights)
        if compensated and not finalize:
            return (grid, jnp.zeros_like(grid))
        return grid
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    g = grid_size
    base, frac = ref.cic_prep(data, lo, spacing, g)

    # Sublane rows + corner weights over the leading d-1 lattice axes.
    n_sub = 2 ** (d - 1)
    rows = jnp.zeros((n, n_sub), dtype=jnp.int32)
    cw = jnp.ones((n, n_sub), dtype=jnp.float32)
    for c in range(n_sub):
        r = jnp.zeros((n,), dtype=jnp.int32)
        w = (jnp.ones((n,), dtype=jnp.float32) if weights is None
             else weights.astype(jnp.float32))
        for k in range(d - 1):
            o = (c >> k) & 1
            r = r * g + base[:, k] + o
            w = w * (frac[:, k] if o else 1.0 - frac[:, k])
        rows = rows.at[:, c].set(r)
        cw = cw.at[:, c].set(w)

    # Last-axis base lane + fraction (the body expands these to lane rows).
    cp = round_up(g, 128) if not interpret else g
    blast = base[:, d - 1][:, None]
    flast = frac[:, d - 1][:, None].astype(jnp.float32)

    bm_ = min(bm, round_up(n, 8))
    np_ = round_up(n, bm_)
    pad = ((0, np_ - n), (0, 0))
    kc = bm_ * n_sub

    # Flatten (point, corner) into the corner stream, then sort each
    # kc-corner chunk by sublane row and flag segment ends — the kernel's
    # one-RMW-per-distinct-row contract.  Pads (zero weight) carry row 0
    # and sort into the first segment harmlessly.
    def chunks(a):
        return jnp.pad(a, pad).reshape(-1, kc)

    rows_c = chunks(rows)
    order = jnp.argsort(rows_c, axis=1)
    take = functools.partial(jnp.take_along_axis, indices=order, axis=1)
    rows_s = take(rows_c)
    cw_s = take(chunks(cw))
    blast_s = take(chunks(jnp.broadcast_to(blast, (n, n_sub))))
    flast_s = take(chunks(jnp.broadcast_to(flast, (n, n_sub))))
    segend = jnp.concatenate(
        [rows_s[:, 1:] != rows_s[:, :-1],
         jnp.ones((rows_s.shape[0], 1), bool)], axis=1).astype(jnp.int32)

    flat = lambda a: a.reshape(-1, 1)  # noqa: E731
    out = kk.scatter_sorted(
        flat(rows_s), flat(cw_s), flat(blast_s), flat(flast_s), flat(segend),
        rows_dim=g ** (d - 1), lanes_dim=cp, kc=kc,
        compensated=compensated, interpret=interpret,
    )

    def crop(grid2d):
        return grid2d[:, :g].reshape((g,) * d).astype(data.dtype)

    if compensated:
        hi, lo_bank = out
        if finalize:
            return crop(hi + lo_bank)   # fold in f32, then cast once
        return (crop(hi), crop(lo_bank))
    return crop(out)
