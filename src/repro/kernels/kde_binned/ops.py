"""jit'd public wrapper for the binned-KDE scatter Pallas kernel.

Precomputes the vectorizable parts of the cloud-in-cell deposit — all O(n)
arrays, keeping the streaming-memory contract (the Pallas body builds each
point's 2-nonzero lane row itself and is otherwise a pure scatter, see
kernel.py):

  * `rows`  — per point, the 2^(d-1) flattened sublane row indices of the
    stencil corners over the leading d-1 lattice axes;
  * `cw`    — the matching product-of-(1-f, f) corner weights, scaled by
    the optional point weight (zeroed on padded rows, so no masking is
    needed in the kernel);
  * `blast` / `flast` — the last-axis base lane + fraction the body's iota
    compare expands into the lane deposit row.

Rows are padded to bm multiples; lane padding (g -> 128-multiples on TPU)
is sliced off before the (g,)^d reshape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.kernels import round_up
from repro.kernels.kde_binned import kernel as kk
from repro.kernels.kde_binned import ref

Array = jax.Array


@functools.partial(
    jax.jit, static_argnames=("grid_size", "bm", "interpret", "use_pallas")
)
def binned_scatter(
    data: Array,
    lo: Array,
    spacing: Array,
    grid_size: int,
    *,
    weights: Array | None = None,
    bm: int = 256,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> Array:
    """(n, d) points -> (grid_size,)^d CIC mass grid (Pallas path).

    Matches `ref.binned_grid` / `repro.core.kde.scatter_cic` to fp32
    reduction-order tolerance.  use_pallas=False falls back to the corner-
    loop oracle; interpret=None resolves to True off-TPU.
    """
    n, d = data.shape
    if not 1 <= d <= 3:
        raise ValueError(f"binned_scatter supports 1 <= d <= 3, got d={d}")
    if not use_pallas:
        return ref.binned_grid(data, lo, spacing, grid_size, weights=weights)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    g = grid_size
    base, frac = ref.cic_prep(data, lo, spacing, g)

    # Sublane rows + corner weights over the leading d-1 lattice axes.
    n_sub = 2 ** (d - 1)
    rows = jnp.zeros((n, n_sub), dtype=jnp.int32)
    cw = jnp.ones((n, n_sub), dtype=jnp.float32)
    for c in range(n_sub):
        r = jnp.zeros((n,), dtype=jnp.int32)
        w = (jnp.ones((n,), dtype=jnp.float32) if weights is None
             else weights.astype(jnp.float32))
        for k in range(d - 1):
            o = (c >> k) & 1
            r = r * g + base[:, k] + o
            w = w * (frac[:, k] if o else 1.0 - frac[:, k])
        rows = rows.at[:, c].set(r)
        cw = cw.at[:, c].set(w)

    # Last-axis base lane + fraction (the body expands these to lane rows).
    cp = round_up(g, 128) if not interpret else g
    blast = base[:, d - 1][:, None]
    flast = frac[:, d - 1][:, None].astype(jnp.float32)

    bm_ = min(bm, round_up(n, 8))
    np_ = round_up(n, bm_)
    pad = ((0, np_ - n), (0, 0))
    grid2d = kk.scatter_padded(
        jnp.pad(rows, pad), jnp.pad(cw, pad), jnp.pad(blast, pad),
        jnp.pad(flast, pad),
        rows_dim=g ** (d - 1), lanes_dim=cp, bm=bm_, interpret=interpret,
    )
    return grid2d[:, :g].reshape((g,) * d).astype(data.dtype)
