"""Pallas TPU kernel: tiled cloud-in-cell scatter-add, grid resident in VMEM.

Scatter is the one stage of the binned KDE with no MXU mapping — it is
data-dependent addressing — so the TPU formulation keeps the WHOLE d <= 3
grid as a VMEM-resident output block (<= 4.7 MB at the production
resolutions: 1024 / 512^2 / 96^3 cells) and streams row tiles through it:

  * grid (n/bm,) — one axis, the row stream; the output BlockSpec maps every
    step to the same (R, C) block, so the grid persists in VMEM across the
    whole stream (canonical accumulation: init at i == 0, += after);
  * the d-dim lattice is laid out 2-D as (R, C) = (g^(d-1), g): the LAST
    lattice axis is the lane axis, the leading axes are flattened into
    sublanes.  ops.py precomputes, per point, the 2^(d-1) sublane-corner row
    indices + corner weights (point weight folded in) and the last-axis
    base lane / fraction — all O(n) inputs; the body builds each point's
    2-nonzero lane deposit row from an iota compare (one VPU op) and then
    scatters: 2^(d-1) dynamic-row accumulates of a full lane vector per
    point;
  * within a program the fori_loop over the bm points is sequential and the
    TPU grid is sequential over i, so read-modify-write accumulation into
    the same rows is safe without atomics.

The per-point fori_loop is serial by nature (that is what scatter is); the
lane axis still vectorizes (each update touches a whole (1, C) row), and
nothing ever round-trips to HBM until the final grid writeback.  Padded rows
are handled by zeroed corner weights (ops.py), not masking.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _scatter_body(rows_ref, cw_ref, blast_ref, flast_ref, out_ref, *,
                  bm: int, n_sub: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    lane = jax.lax.broadcasted_iota(jnp.int32, (1, out_ref.shape[1]), 1)

    def point(p, carry):
        b = blast_ref[p, 0]                           # last-axis base lane
        f = flast_ref[p, 0]
        lane_row = (jnp.where(lane == b, 1.0 - f, 0.0)
                    + jnp.where(lane == b + 1, f, 0.0))  # (1, C), 2 nonzeros
        for c in range(n_sub):                        # static 2^(d-1) corners
            r = rows_ref[p, c]
            cur = pl.load(out_ref, (pl.ds(r, 1), slice(None)))
            pl.store(out_ref, (pl.ds(r, 1), slice(None)),
                     cur + cw_ref[p, c] * lane_row)
        return carry

    jax.lax.fori_loop(0, bm, point, 0)


@functools.partial(
    jax.jit, static_argnames=("rows_dim", "lanes_dim", "bm", "interpret")
)
def scatter_padded(
    rows: Array,     # (np, n_sub) int32 flattened sublane row per corner
    cw: Array,       # (np, n_sub) f32 corner weights x point weight (0 = pad)
    blast: Array,    # (np, 1) int32 last-axis base lane
    flast: Array,    # (np, 1) f32 last-axis fraction
    *,
    rows_dim: int,   # R = g^(d-1)
    lanes_dim: int,  # C = lane-padded g
    bm: int = 256,
    interpret: bool = False,
) -> Array:
    """Core pallas_call; requires np % bm == 0 (padding done by ops.py)."""
    np_, n_sub = rows.shape
    assert np_ % bm == 0, (np_, bm)
    body = functools.partial(_scatter_body, bm=bm, n_sub=n_sub)
    return pl.pallas_call(
        body,
        grid=(np_ // bm,),
        in_specs=[
            pl.BlockSpec((bm, n_sub), lambda i: (i, 0)),
            pl.BlockSpec((bm, n_sub), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows_dim, lanes_dim), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_dim, lanes_dim), jnp.float32),
        interpret=interpret,
    )(rows, cw, blast, flast)
