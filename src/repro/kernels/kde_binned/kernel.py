"""Pallas TPU kernel: sorted segment-reduce cloud-in-cell scatter-add.

Scatter is the one stage of the binned KDE with no MXU mapping — it is
data-dependent addressing — so the TPU formulation keeps the WHOLE d <= 3
grid as a VMEM-resident output block (<= 4.7 MB at the production
resolutions: 1024 / 512^2 / 96^3 cells) and streams corner tiles through it:

  * grid (K/kc,) — one axis, the corner stream (kc = bm * 2^(d-1) corners
    per step); the output BlockSpec maps every step to the same (R, C)
    block, so the grid persists in VMEM across the whole stream (canonical
    accumulation: init at i == 0, += after);
  * the d-dim lattice is laid out 2-D as (R, C) = (g^(d-1), g): the LAST
    lattice axis is the lane axis, the leading axes are flattened into
    sublanes.  ops.py precomputes, per CORNER, the flattened sublane row
    index, the corner weight (point weight folded in), and the last-axis
    base lane / fraction the body's iota compare expands into a 2-nonzero
    lane row — then SORTS each kc-corner chunk by row and marks segment
    ends, so the body accumulates same-row corners in a (1, C) register
    vector and touches VMEM once per DISTINCT row (a segment-reduce),
    not once per corner as the historical serial per-point loop did;
  * within a program the fori_loop over the kc corners is sequential and
    the TPU grid is sequential over i, so the read-modify-write at each
    segment end is safe without atomics.

Because each segment lands in the grid as ONE additive delta, the update
composes with the two-float compensated accumulator: with
``compensated=True`` the kernel carries the grid as a (hi, lo) pair in VMEM
and folds every segment in through an error-free two-sum, banking the
rounding error in lo — the same strategy `repro.core.streaming` runs across
XLA tiles, so the pair can cross a mesh psum un-collapsed
(`dispatch.binned_scatter` no longer reroutes compensated deposits to XLA).
Padded corners carry zero weight and row 0 (ops.py), so they merge into the
first segment and deposit nothing — no masking in the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _scatter_sorted_body(rows_ref, cw_ref, blast_ref, flast_ref, segend_ref,
                         *out_refs, kc: int, compensated: bool):
    hi_ref = out_refs[0]
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        for ref in out_refs:
            ref[...] = jnp.zeros_like(ref)

    lane = jax.lax.broadcasted_iota(jnp.int32, (1, hi_ref.shape[1]), 1)

    def corner(k, acc):
        b = blast_ref[k, 0]                           # last-axis base lane
        f = flast_ref[k, 0]
        w = cw_ref[k, 0]
        acc = acc + w * (jnp.where(lane == b, 1.0 - f, 0.0)
                         + jnp.where(lane == b + 1, f, 0.0))
        end = segend_ref[k, 0] != 0                   # last corner of its row
        r = rows_ref[k, 0]

        @pl.when(end)
        def _():
            cur = pl.load(hi_ref, (pl.ds(r, 1), slice(None)))
            if compensated:
                lo_ref = out_refs[1]
                s = cur + acc                         # TwoSum(cur, acc)
                bb = s - cur
                err = (cur - (s - bb)) + (acc - bb)
                pl.store(hi_ref, (pl.ds(r, 1), slice(None)), s)
                bank = pl.load(lo_ref, (pl.ds(r, 1), slice(None)))
                pl.store(lo_ref, (pl.ds(r, 1), slice(None)), bank + err)
            else:
                pl.store(hi_ref, (pl.ds(r, 1), slice(None)), cur + acc)

        # the accumulator resets at segment boundaries; sublane updates stay
        # vectorized (every op above is a whole (1, C) lane row)
        return jnp.where(end, jnp.zeros_like(acc), acc)

    jax.lax.fori_loop(0, kc, corner, jnp.zeros((1, hi_ref.shape[1]),
                                               jnp.float32))


@functools.partial(
    jax.jit, static_argnames=("rows_dim", "lanes_dim", "kc", "compensated",
                              "interpret")
)
def scatter_sorted(
    rows: Array,     # (K, 1) int32 flattened sublane row per corner
    cw: Array,       # (K, 1) f32 corner weight x point weight (0 = pad)
    blast: Array,    # (K, 1) int32 last-axis base lane
    flast: Array,    # (K, 1) f32 last-axis fraction
    segend: Array,   # (K, 1) int32 1 at the last corner of each row segment
    *,
    rows_dim: int,   # R = g^(d-1)
    lanes_dim: int,  # C = lane-padded g
    kc: int,         # corners per grid step (bm * 2^(d-1))
    compensated: bool = False,
    interpret: bool = False,
):
    """Core pallas_call; requires K % kc == 0 and each kc-chunk sorted by
    `rows` with `segend` marking the last corner of every row run (chunk
    prep done by ops.py).  Returns the (R, C) grid — a (hi, lo) pair of
    grids when ``compensated``."""
    k_, one = rows.shape
    assert one == 1 and k_ % kc == 0, (rows.shape, kc)
    body = functools.partial(_scatter_sorted_body, kc=kc,
                             compensated=compensated)
    n_out = 2 if compensated else 1
    out = pl.pallas_call(
        body,
        grid=(k_ // kc,),
        in_specs=[pl.BlockSpec((kc, 1), lambda i: (i, 0)) for _ in range(5)],
        out_specs=[pl.BlockSpec((rows_dim, lanes_dim), lambda i: (0, 0))
                   for _ in range(n_out)],
        out_shape=[jax.ShapeDtypeStruct((rows_dim, lanes_dim), jnp.float32)
                   for _ in range(n_out)],
        interpret=interpret,
    )(rows, cw, blast, flast, segend)
    return tuple(out) if compensated else out[0]
