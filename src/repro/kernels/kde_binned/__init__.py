"""kde_binned Pallas kernel package (kernel.py + ops.py + ref.py).

Tiled cloud-in-cell scatter-add onto a regular d <= 3 grid — the deposit
stage of the binned (FFT) KDE.  The grid lives VMEM-resident across the row
stream; `repro.kernels.dispatch.binned_scatter` routes between this kernel
and the windowed-scatter XLA path in `repro.core.kde`.
"""
