"""Roofline-guided tile autotuning (`tile=None` == "pick for me").

See `repro.tuning.autotune` for the model/measure/cache machinery and
`repro.kernels.dispatch.resolve_plan` for how the hot paths consume it.
"""

from repro.tuning.autotune import (  # noqa: F401
    DEFAULT_TILE,
    MAX_TILE,
    MIN_TILE,
    OPS,
    Plan,
    cache_path,
    cached_executable,
    candidate_tiles,
    clear_cache,
    measured,
    measuring,
    model_seconds,
    plan_for,
    set_measure,
    shape_key,
)
