"""Roofline-guided tile autotuning for the streaming hot paths.

After PR 5 every streaming reduction rides `repro.core.streaming`, but its
tile sizes were hardcoded (`tile=8192` pipeline default, `bm=bn=256` in the
Pallas gram kernel) while BENCH_pipeline.json shows tile choice alone swings
the solve ~2x.  This module makes ``tile=None`` mean "autotune":

  1. **Ladder** — `candidate_tiles` builds a small pow2 candidate set per
     (op, n, m, d, dtype) bounded by the slab-memory budget;
  2. **Model**  — `model_seconds` ranks it with a per-step roofline
     (max(flops/peak, bytes/bw) + fixed step overhead, with a cache-spill
     penalty once the slab outgrows the fast memory level;
     `repro.roofline.analysis.DeviceSPECS` supplies the constants);
  3. **Measure** — when measurement is enabled (`set_measure(True)`, the
     ``measured()`` context, or ``REPRO_AUTOTUNE=1``), the top
     `MEASURE_TOP_K` candidates run a one-off micro-benchmark on synthetic
     data (a couple of tiles' worth of rows, extrapolated to the full
     stream) and the argmin wins.  Off by default so imports, tests and
     library callers never pay a tuning pause;
  4. **Cache**  — choices persist in-memory AND on disk
     (``REPRO_TUNE_CACHE`` or ``~/.cache/repro/autotune.json``), keyed by
     device kind + shape bucket (pow2-bucketed n and m), so warm runs pay
     zero tuning cost and a measured choice is never re-measured.

The three tuned ops mirror `repro.kernels.dispatch`:

  * ``gram``    — the Nystrom normal-equation row stream
    (`nystrom.scan_normal_eq` tile on XLA; Pallas `gram` bm/bn on TPU);
  * ``deposit`` — the binned-KDE CIC scatter (`kde.scatter_cic` tile on
    XLA; Pallas `kde_binned` bm on TPU; ``m`` is the per-axis grid size);
  * ``predict`` — the batched predict row stream
    (`nystrom.predict_streaming` tile).

Everything here is shape-level plumbing: resolving a plan NEVER perturbs
numerics.  ``op(tile=None)`` is bit-equal to ``op(tile=plan.tile)`` — the
plan only picks the integer (locked by tests/test_autotune.py).
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import tempfile
import time
import warnings
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.precision import PRECISIONS
from repro.roofline.analysis import DeviceSpec, device_spec

Array = jax.Array

OPS = ("gram", "deposit", "predict")

# The precision modes ``precision=None`` may resolve to JOINTLY with the
# tile.  bf16x2 is excluded: it is faster still on MXU but raises the Gram
# noise floor ~256x (precision.EPS_SCALE), so it must be an explicit
# caller choice, never an autotuner surprise.
AUTO_PRECISIONS = ("fp32", "bf16x3")

DEFAULT_TILE = 8192      # the historical hardcoded pipeline default
DEFAULT_BM = 256         # Pallas gram/deposit row block
DEFAULT_BN = 256         # Pallas gram column block

MEASURE_TOP_K = 4        # model-ranked candidates the micro-bench times
MIN_TILE = 512           # smallest ladder rung (per-step overhead floor)
MAX_TILE = 131072        # largest rung (slab memory ceiling at prod m)
_SLAB_BYTES_CAP = 512e6  # hard sanity cap on tile * m * dtype_bytes

_CACHE_VERSION = 2


@dataclasses.dataclass(frozen=True)
class Plan:
    """One resolved execution plan for a streamed op.

    ``source`` records provenance: "model" (analytic ranking only),
    "measured" (micro-benchmarked this process), "cache" (recalled from a
    prior resolution — warm runs), "default" (fallback when resolution is
    impossible, e.g. n == 0).  ``tuning_seconds`` is the wall-clock this
    resolution spent measuring (0.0 for model/cache/default).
    ``precision`` is the Gram-contraction mode the plan was resolved for —
    either echoed back from the caller's pin, or (``precision=None``
    requests on the gram op) the mode the roofline model / micro-benchmark
    chose jointly with the tile.
    """

    op: str
    tile: int
    bm: int = DEFAULT_BM
    bn: int = DEFAULT_BN
    source: str = "default"
    tuning_seconds: float = 0.0
    precision: str = "fp32"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# ----------------------------------------------------------------- gating --

_MEASURE: bool | None = None     # tri-state: None -> env decides


def set_measure(on: bool | None) -> None:
    """Force measurement on/off; None restores the REPRO_AUTOTUNE env gate."""
    global _MEASURE
    _MEASURE = on


def measuring() -> bool:
    """Whether plan resolution may run micro-benchmarks right now."""
    if _MEASURE is not None:
        return _MEASURE
    return os.environ.get("REPRO_AUTOTUNE", "0").lower() in ("1", "true",
                                                             "measure", "on")


@contextlib.contextmanager
def measured(on: bool = True):
    """Scope in which plan resolution may (or may not) micro-benchmark."""
    global _MEASURE
    prev = _MEASURE
    _MEASURE = on
    try:
        yield
    finally:
        _MEASURE = prev


def _can_measure() -> bool:
    """Measurement compiles and runs real kernels — refuse under a trace
    (e.g. a dispatch call inside a shard_map body) and on backends where the
    candidate kernels only run in interpret mode (Pallas off-TPU)."""
    try:
        from jax.core import trace_state_clean
        return bool(trace_state_clean())
    except Exception:
        return True


# ------------------------------------------------------------------ cache --

_MEMORY: dict[str, dict] = {}
_DISK_LOADED = False


def cache_path() -> str:
    """On-disk plan cache location (REPRO_TUNE_CACHE overrides)."""
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


def _entry_valid(v) -> bool:
    """Shape-check one cache entry before trusting it (a truncated write or
    concurrent editor can leave arbitrary JSON behind)."""
    return (isinstance(v, dict)
            and isinstance(v.get("tile"), int) and v["tile"] > 0
            and v.get("source") in ("model", "measured"))


def _load_disk() -> None:
    global _DISK_LOADED
    if _DISK_LOADED:
        return
    _DISK_LOADED = True
    path = cache_path()
    try:
        with open(path) as f:
            payload = json.load(f)
    except FileNotFoundError:
        return                         # cold cache: the normal first run
    except (OSError, ValueError) as e:  # ValueError covers JSONDecodeError
        warnings.warn(
            f"ignoring unreadable autotune plan cache at {path} ({e}); "
            "plans will be re-tuned from scratch", RuntimeWarning,
            stacklevel=2)
        return
    if not isinstance(payload, dict) or "version" not in payload:
        warnings.warn(
            f"autotune plan cache at {path} has no version key (corrupted "
            "or concurrently rewritten); re-tuning from scratch",
            RuntimeWarning, stacklevel=2)
        return
    if payload["version"] != _CACHE_VERSION:
        return   # clean older/newer format: silently start cold
    entries = payload.get("entries")
    if not isinstance(entries, dict):
        warnings.warn(
            f"autotune plan cache at {path} has malformed entries; "
            "re-tuning from scratch", RuntimeWarning, stacklevel=2)
        return
    bad = 0
    for k, v in entries.items():
        if _entry_valid(v):
            _MEMORY.setdefault(k, v)
        else:
            bad += 1
    if bad:
        warnings.warn(
            f"dropped {bad} malformed entr{'y' if bad == 1 else 'ies'} from "
            f"the autotune plan cache at {path}; they will be re-tuned",
            RuntimeWarning, stacklevel=2)


def _save_disk() -> None:
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   prefix=".autotune-")
        with os.fdopen(fd, "w") as f:
            json.dump({"version": _CACHE_VERSION, "entries": _MEMORY}, f,
                      indent=1, sort_keys=True)
        os.replace(tmp, path)   # atomic: concurrent runs never see half a file
    except OSError:
        pass   # read-only FS etc.: in-memory cache still works


def clear_cache() -> None:
    """Drop the in-memory cache and delete the on-disk file."""
    global _DISK_LOADED
    _MEMORY.clear()
    _EXECUTABLES.clear()
    _DISK_LOADED = True   # don't resurrect the file we are about to delete
    try:
        os.remove(cache_path())
    except OSError:
        pass
    _DISK_LOADED = False


# ----------------------------------------------------- compiled executables --

_EXECUTABLES: dict[tuple, Callable] = {}


def cached_executable(key: tuple, build: Callable[[], Callable]) -> Callable:
    """Process-lifetime jit cache for plan-resolved streamed ops.

    An autotuned plan is worthless if every call re-traces the loop it
    tuned, so callers that resolved their tile through `plan_for` wrap the
    hot computation here: first call per `key` (op + kernel params + tile +
    concrete shapes/dtype) jits `build()`'s closure, later calls reuse the
    compiled artifact — the FFTW-wisdom move, applied to XLA executables.
    Explicit-tile calls never come through here: their op-by-op eager
    semantics are the historical bit-parity contract.
    """
    fn = _EXECUTABLES.get(key)
    if fn is None:
        fn = _EXECUTABLES[key] = jax.jit(build())
    return fn


def _bucket(v: int) -> int:
    """Pow2-ceil shape bucketing: nearby shapes share one plan."""
    return 1 << max(0, int(v) - 1).bit_length() if v > 1 else 1


def shape_key(op: str, n: int, m: int, d: int, *, dtype=jnp.float32,
              backend: str = "xla", accumulator: str = "plain",
              precision: str = "fp32",
              device_kind: str | None = None) -> str:
    """Cache key: device kind + backend + op + dtype + bucketed shape.

    n and m are pow2-bucketed so e.g. n = 250k and n = 262144 resolve to
    the same plan (the roofline is smooth in n); d, the accumulator and
    the precision REQUEST are exact (they change the per-step op mix).
    A joint-resolution request keys as "auto" — the entry then carries the
    chosen precision, separate from any explicitly-pinned entries.
    """
    if device_kind is None:
        device_kind = jax.devices()[0].device_kind
    dt = jnp.dtype(dtype).name
    return "/".join([device_kind.replace(" ", "_"), backend, op, dt,
                     f"n{_bucket(n)}", f"m{_bucket(m)}", f"d{int(d)}",
                     accumulator, f"px_{precision}"])


# ------------------------------------------------------------------ model --

def _step_costs(op: str, tile: int, m: int, d: int,
                dtype_bytes: int) -> tuple[float, float, float]:
    """(matmul flops, other flops, working-set bytes) of ONE `tile`-row step.

    gram:    (tile, m) kernel slab build (~d+const flops/entry through the
             augmented-GEMM distance) + the (m, m) syrk + (m,) gemv — the
             syrk is the PRECISION-SCALABLE matmul term (the bf16 split
             runs it at the MXU bf16 rate x a words^2-ish partial count);
    predict: slab build + (tile, m) x (m,) gemv;
    deposit: O(2^d) stencil flops per point, no MXU term; the working set
             is the corner stream plus the resident (m,)^d grid.
    """
    if op == "gram":
        mat = 2.0 * tile * m * m
        rest = 2.0 * tile * m * (d + 2) + 12.0 * tile * m + 2.0 * tile * m
        ws = tile * (m + d) * dtype_bytes + 2 * m * m * dtype_bytes
    elif op == "predict":
        mat = 0.0
        rest = 2.0 * tile * m * (d + 2) + 12.0 * tile * m + 2.0 * tile * m
        ws = tile * (m + d) * dtype_bytes
    elif op == "deposit":
        corners = 2 ** d
        mat = 0.0
        rest = 24.0 * tile * corners
        ws = tile * (corners + d) * dtype_bytes \
            + min(float(m) ** d, 16e6) * dtype_bytes
    else:
        raise ValueError(f"unknown op {op!r}; pick from {OPS}")
    return mat, rest, float(ws)


def model_seconds(op: str, tile: int, n: int, m: int, d: int, *,
                  dtype_bytes: int = 4,
                  spec: DeviceSpec | None = None,
                  precision: str = "fp32") -> float:
    """Analytic whole-stream seconds for one tile choice (ranking only).

    Per step: max(compute, memory) roofline + the fixed step overhead; a
    slab that outgrows `spec.cache_bytes` degrades the compute rate
    proportionally (GEMM panels start streaming from main memory — the
    empirically dominant effect behind the 2x tile swing on CPU).  The
    matmul share of the flops is scaled by the device's per-precision
    compute ceiling (`DeviceSpec.matmul_cost`): < 1 where the bf16 split's
    partial matmuls ride a faster MXU path, > 1 where bf16 emulation is a
    slowdown (CPU) — which is how ``precision=None`` resolution picks fp32
    on CPU and the split modes on MXU hardware.
    """
    spec = spec or device_spec()
    steps = max(1, -(-n // tile))
    mat, rest, ws = _step_costs(op, min(tile, n), m, d, dtype_bytes)
    flops = rest + mat * spec.matmul_cost(precision)
    spill = max(1.0, ws / spec.cache_bytes)
    t_compute = flops / spec.peak_flops * spill
    t_memory = ws / spec.mem_bw
    return steps * (max(t_compute, t_memory) + spec.step_overhead)


def candidate_tiles(op: str, n: int, m: int, d: int, *,
                    dtype_bytes: int = 4,
                    spec: DeviceSpec | None = None,
                    precision: str = "fp32") -> list[int]:
    """Model-ranked pow2 tile ladder (best first), bounded by n and memory.

    The top rung is the pow2-ceil of n (a one-shot slab), so small-n calls
    degenerate to a single whole-array candidate — exactly the historical
    un-tiled behavior, resolved in microseconds.
    """
    spec = spec or device_spec()
    hi = min(_bucket(n), MAX_TILE) if n > 0 else MIN_TILE
    lo = min(MIN_TILE, hi)
    ladder, t = [], lo
    while t <= hi:
        if t * max(m, 1) * dtype_bytes <= _SLAB_BYTES_CAP:
            ladder.append(t)
        t *= 2
    if not ladder:
        ladder = [lo]
    if _bucket(n) > MAX_TILE and MAX_TILE not in ladder:
        ladder.append(MAX_TILE)
    ladder.sort(key=lambda c: model_seconds(op, c, n, m, d,
                                            dtype_bytes=dtype_bytes,
                                            spec=spec, precision=precision))
    return ladder


# ---------------------------------------------------------------- measure --

def _bench(fn: Callable[[], object], reps: int = 3) -> float:
    """Best-of-`reps` wall-clock of an already-warm compiled callable."""
    fn()                                  # compile + warm (excluded)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _measure_tile(op: str, tile: int, n: int, m: int, d: int, dtype,
                  accumulator: str, precision: str = "fp32") -> float:
    """Whole-stream seconds for one candidate, extrapolated from a short
    synthetic stream (<= a few tiles of rows) — candidates are compared on
    identical data/step counts, so the extrapolation cancels out of the
    argmin."""
    n_s = int(min(n, max(4 * tile, 16384)))
    n_s = max(n_s, tile) if tile <= n else n_s
    key = jax.random.PRNGKey(0)
    if op == "deposit":
        from repro.core import kde
        g = max(int(m), 4)
        pts = jax.random.uniform(key, (n_s, d), dtype)
        lo = jnp.zeros((d,), dtype)
        spacing = jnp.full((d,), 1.0 / (g - 1), dtype)
        fn = lambda: kde.scatter_cic(pts, lo, spacing, g, tile=tile,  # noqa: E731
                                     accumulator=accumulator)
    else:
        from repro.core import kernels as core_kernels
        from repro.core import nystrom
        kern = core_kernels.Matern(nu=1.5, lengthscale=1.0)
        x = jax.random.normal(key, (n_s, d), dtype)
        xm = x[: min(int(m), n_s)]
        if op == "gram":
            w = jnp.ones((n_s,), dtype)
            fn = jax.jit(lambda: nystrom.scan_normal_eq(
                kern, x, xm, w, tile=tile, accumulator=accumulator,
                precision=precision))
        else:
            beta = jnp.zeros((xm.shape[0],), dtype)
            fit = nystrom.NystromFit(beta=beta, landmarks=xm,
                                     landmark_idx=jnp.arange(xm.shape[0]),
                                     lam=1e-3)
            fn = jax.jit(lambda: nystrom.predict_streaming(
                kern, fit, x, tile=tile))
    per_stream = _bench(fn)
    steps_sampled = max(1, -(-n_s // tile))
    steps_total = max(1, -(-n // tile))
    return per_stream / steps_sampled * steps_total


# --------------------------------------------------------------- plan_for --

def plan_for(op: str, n: int, m: int, d: int, *, dtype=jnp.float32,
             backend: str = "xla", accumulator: str = "plain",
             precision: str | None = "fp32",
             measure: bool | None = None) -> Plan:
    """Resolve the execution plan for one streamed op at one shape.

    Cache first (warm runs never tune); then the roofline model ranks the
    ladder; when measurement is enabled (``measure=True``, the
    ``measured()`` context, or ``REPRO_AUTOTUNE=1``) AND legal (not under a
    trace; Pallas plans only measure on a real TPU), the top
    `MEASURE_TOP_K` candidates are micro-benchmarked and the argmin wins.
    A measured entry permanently shadows a model entry for its bucket.

    ``precision`` pins the Gram-contraction mode the plan is ranked for.
    ``precision=None`` on the gram op resolves the (tile, precision) pair
    JOINTLY over `AUTO_PRECISIONS`: the candidate set is the cross product
    of the tile ladder with the eligible modes, ranked by the
    per-precision roofline (and micro-benchmarked as pairs when
    measurement is on).  Non-gram ops have no precision-scalable matmul
    and always plan as "fp32".
    """
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}; pick from {OPS}")
    if precision is not None and precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; "
                         f"pick from {PRECISIONS} or None")
    if op != "gram":
        precision = "fp32"
    n, m, d = int(n), int(m), int(d)
    if n <= 0 or m <= 0:
        return Plan(op=op, tile=DEFAULT_TILE, precision=precision or "fp32")
    _load_disk()
    key = shape_key(op, n, m, d, dtype=dtype, backend=backend,
                    accumulator=accumulator,
                    precision=precision if precision is not None else "auto")
    want = (measuring() if measure is None else measure) and _can_measure()
    if backend == "pallas" and jax.default_backend() != "tpu":
        want = False   # interpret-mode timings are meaningless
    entry = _MEMORY.get(key)
    if entry is not None and (entry["source"] == "measured" or not want):
        return Plan(op=op, tile=int(entry["tile"]),
                    bm=int(entry.get("bm", DEFAULT_BM)),
                    bn=int(entry.get("bn", DEFAULT_BN)), source="cache",
                    precision=str(entry.get("precision", "fp32")))

    dtype_bytes = jnp.dtype(dtype).itemsize
    precs = AUTO_PRECISIONS if precision is None else (precision,)
    ladder = candidate_tiles(op, n, m, d, dtype_bytes=dtype_bytes)
    pairs = [(t, p) for p in precs for t in ladder]
    pairs.sort(key=lambda tp: model_seconds(op, tp[0], n, m, d,
                                            dtype_bytes=dtype_bytes,
                                            precision=tp[1]))
    (tile, prec), source, tuning_s = pairs[0], "model", 0.0
    if want:
        t0 = time.perf_counter()
        timed = {tp: _measure_tile(op, tp[0], n, m, d, dtype, accumulator,
                                   precision=tp[1])
                 for tp in pairs[:MEASURE_TOP_K]}
        tile, prec = min(timed, key=timed.get)
        source, tuning_s = "measured", time.perf_counter() - t0
    plan = Plan(op=op, tile=tile, source=source, tuning_seconds=tuning_s,
                precision=prec)
    _MEMORY[key] = {"tile": plan.tile, "bm": plan.bm, "bn": plan.bn,
                    "precision": plan.precision, "source": source}
    _save_disk()
    return plan
