"""mixtral-8x7b — MoE 8 experts top-2 + sliding-window attn.  [arXiv:2401.04088]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe", num_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14_336, vocab_size=32_000,
    n_experts=8, top_k=2, sliding_window=4096, rope_theta=1_000_000.0,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="mixtral-8x7b-smoke", family="moe", num_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    n_experts=4, top_k=2, sliding_window=32, moe_group_size=64,
    tie_embeddings=False,
)
