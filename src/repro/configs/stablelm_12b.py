"""stablelm-12b — dense GQA.  [hf:stabilityai/stablelm-2-12b]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b", family="dense", num_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, head_dim=160, d_ff=13_824, vocab_size=100_352,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="stablelm-12b-smoke", family="dense", num_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    tie_embeddings=False,
)
