"""llava-next-34b — VLM transformer BACKBONE only; the anyres vision tower is
a STUB: input_specs() feeds precomputed patch embeddings.  [hf:llava-v1.6-34b]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="dense", num_layers=60, d_model=7168,
    n_heads=56, n_kv_heads=8, head_dim=128, d_ff=20_480, vocab_size=64_000,
    inputs_embeds=True, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="llava-next-34b-smoke", family="dense", num_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    inputs_embeds=True, tie_embeddings=False,
)
