"""llama3.2-1b — small llama3 dense GQA.  [hf:meta-llama/Llama-3.2-1B]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense", num_layers=16, d_model=2048,
    n_heads=32, n_kv_heads=8, head_dim=64, d_ff=8192, vocab_size=128_256,
    rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="llama3.2-1b-smoke", family="dense", num_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
)
