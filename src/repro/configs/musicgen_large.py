"""musicgen-large — decoder-only over EnCodec tokens; the EnCodec frontend is
a STUB: input_specs() feeds precomputed frame embeddings.  [arXiv:2306.05284]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="dense", num_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=32, head_dim=64, d_ff=8192, vocab_size=2048,
    inputs_embeds=True, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="musicgen-large-smoke", family="dense", num_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=64,
    inputs_embeds=True, tie_embeddings=False,
)
