"""The paper's own experiment configs (KRR side of the framework)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class KRRConfig:
    name: str
    n: int
    d: int
    kernel: str = "matern"      # matern | gaussian
    nu: float = 1.5
    lengthscale: float = 1.0
    lambda_scale: float = 0.075  # lambda = scale * n^{-2a/(2a+d)}
    distribution: str = "bimodal"
    gamma: float = 0.4
    noise_sigma: float = 0.5


FIG1 = KRRConfig(name="fig1_bimodal3d", n=500_000, d=3, nu=1.5)
FIG2 = KRRConfig(name="fig2_1d", n=10_000, d=1, nu=1.5,
                 lambda_scale=0.45, distribution="bimodal1d")
TABLE1_RQC = KRRConfig(name="table1_rqc", n=10_000, d=3, nu=0.5,
                       lambda_scale=0.15, distribution="uci_like")
TABLE1_HTRU2 = KRRConfig(name="table1_htru2", n=17_898, d=8, nu=0.5,
                         lambda_scale=0.15, distribution="uci_like")
TABLE1_CCPP = KRRConfig(name="table1_ccpp", n=9_568, d=5, nu=0.5,
                        lambda_scale=0.15, distribution="uci_like")
