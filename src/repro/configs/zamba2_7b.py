"""zamba2-7b — Mamba2 backbone + one globally shared attention block applied
every 6th layer (81 layers: 13 shared-attn sites + 68 mamba).  [arXiv:2411.15242]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", num_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, head_dim=112, d_ff=14_336, vocab_size=32_000,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, attn_every=6,
)

SMOKE = ModelConfig(
    name="zamba2-7b-smoke", family="hybrid", num_layers=7, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
    ssm_state=16, ssm_headdim=16, ssm_expand=2, attn_every=3,
)
