"""qwen3-1.7b — dense GQA with qk_norm.  [hf:Qwen/Qwen3-1.7B family]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense", num_layers=28, d_model=2048,
    n_heads=16, n_kv_heads=8, head_dim=128, d_ff=6144, vocab_size=151_936,
    qk_norm=True, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen3-1.7b-smoke", family="dense", num_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    qk_norm=True,
)
