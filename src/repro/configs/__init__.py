"""Architecture registry: --arch <id> -> ModelConfig (full or smoke).

The 10 assigned architectures (each with its exact published geometry) plus
the paper's own KRR experiment configs (paper_krr).
"""

from __future__ import annotations

import importlib

from repro.models.config import (LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K,
                                 DECODE_32K, ModelConfig, ShapeSpec,
                                 supports_shape)

_MODULES = {
    "mamba2-130m": "mamba2_130m",
    "qwen3-1.7b": "qwen3_1_7b",
    "llama3.2-1b": "llama3_2_1b",
    "stablelm-12b": "stablelm_12b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "mixtral-8x7b": "mixtral_8x7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "musicgen-large": "musicgen_large",
    "zamba2-7b": "zamba2_7b",
    "llava-next-34b": "llava_next_34b",
}

ARCHS = tuple(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get(name: str, **overrides) -> ModelConfig:
    cfg = _module(name).CONFIG
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_smoke(name: str, **overrides) -> ModelConfig:
    cfg = _module(name).SMOKE
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def cells(include_skips: bool = False):
    """All assigned (arch, shape) dry-run cells; skipped ones annotated."""
    out = []
    for arch in ARCHS:
        cfg = get(arch)
        for shape in SHAPES.values():
            ok, reason = supports_shape(cfg, shape)
            if ok or include_skips:
                out.append((arch, shape.name, ok, reason))
    return out
