"""mamba2-130m — pure SSM (SSD), attention-free.  [arXiv:2405.21060]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm", num_layers=24, d_model=768,
    vocab_size=50_280, ssm_state=128, ssm_headdim=64, ssm_expand=2,
)

SMOKE = ModelConfig(
    name="mamba2-130m-smoke", family="ssm", num_layers=2, d_model=64,
    vocab_size=256, ssm_state=16, ssm_headdim=16, ssm_expand=2,
)
