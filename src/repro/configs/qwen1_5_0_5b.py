"""qwen1.5-0.5b — dense MHA with QKV bias.  [hf:Qwen/Qwen1.5-0.5B]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense", num_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, head_dim=64, d_ff=2816, vocab_size=151_936,
    qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen1.5-0.5b-smoke", family="dense", num_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
    qkv_bias=True,
)
