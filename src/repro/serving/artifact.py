"""Frozen servable KRR artifact: everything `predict` needs, nothing else.

`ServableKRR.freeze(pipeline)` snapshots a fitted `SAKRRPipeline` into a
compact, immutable bundle — the m landmarks, the solved beta, the whitened
K_mm factor, the calibrated (lam, h) pair, the kernel/pipeline config, and
the fitted KDE grid bounds — that can be saved to one `.npz` file and served
from a process that never imports the training data.  The serving contract:

  * ``artifact.predict(x)`` calls `nystrom.predict_streaming` DIRECTLY with
    the execution knobs (backend / tile / precision) captured at freeze
    time, so it is bit-equal to `pipeline.predict(x)` on the same inputs —
    and it never touches pipeline state, so any number of concurrent
    callers (the microbatching `repro.serving.engine`) are safe.
  * ``save`` / ``load`` round-trip losslessly: arrays go through npz binary
    (exact), scalars and the `PipelineConfig` dict through JSON
    (`PipelineConfig.from_dict` restores tuple-typed fields and rejects
    unknown keys) — locked by tests/test_serving.py.
  * ``in_support(x)`` flags queries inside the fitted KDE grid bounds.
    Out-of-support queries still predict fine (the kernel extrapolates
    smoothly), but any density-derived serving logic must clamp to the
    boundary — which `core.kde.cic_prep` now guarantees.

The whitened factor W (K_mm's eigvecs scaled by 1/sqrt(eigvals) above the
jitter floor, zero on the truncated tail) is not needed by the mean
predictor; it is frozen alongside because serving-side extensions —
predictive variance, leverage of incoming queries, score monitoring — are
all quadratic forms in W^T k(x, X_m), and recomputing it needs an O(m^3)
eigh the request path cannot afford.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kde, nystrom
from repro.core.kernels import Kernel, kernel_matrix

Array = jax.Array

# v2: the embedded PipelineConfig dict is schema-versioned
# (PipelineConfig.SCHEMA_VERSION rides inside pipeline_config) and
# artifacts support in-place refresh() for online hot swaps
FORMAT_VERSION = 2


def _whitened_factor(kernel: Kernel, landmarks: Array,
                     jitter: float) -> Array:
    """W = U E^{-1/2} on K_mm's eigenspaces above the jitter floor."""
    k_mm = kernel_matrix(kernel, landmarks)
    evals, evecs = jnp.linalg.eigh(k_mm)
    tau = jitter * evals[-1]
    inv_sqrt = jnp.where(evals > tau,
                         1.0 / jnp.sqrt(jnp.maximum(evals, tau)), 0.0)
    return evecs * inv_sqrt[None, :]


@dataclasses.dataclass(frozen=True)
class ServableKRR:
    """Immutable fitted-model bundle (see module docstring)."""

    config: Any                  # PipelineConfig (untyped: avoid the cycle)
    lam: float                   # calibrated/resolved regularizer
    bandwidth: float | None      # calibrated/resolved KDE h (None: direct)
    beta: Array                  # (m,)
    landmarks: Array             # (m, d)
    landmark_idx: Array          # (m,) indices into the fitted training set
    k_mm_whitener: Array         # (m, m) whitened K_mm factor W
    grid_lo: Array | None        # (d,) fitted KDE grid bounds (binned path)
    grid_hi: Array | None
    n_fit: int                   # training rows the model was fitted on
    backend: str | None          # predict execution knobs, frozen so the
    tile: int | None             # served numbers match pipeline.predict
    precision: str | None

    # ------------------------------------------------------------- freeze --
    @classmethod
    def freeze(cls, pipeline) -> "ServableKRR":
        """Capture a fitted `SAKRRPipeline` (fit/evaluate/calibrate done)."""
        st = pipeline.state
        if st is None or st.fit is None:
            raise RuntimeError(
                "freeze() needs a fitted pipeline: call fit/evaluate/"
                "calibrate (with a SolveStage) first")
        cfg = pipeline.config
        ctx = pipeline._ctx
        bandwidth = ctx.bandwidth
        if bandwidth is None:
            bandwidth = getattr(cfg, "kde_bandwidth", None)
        grid_lo = grid_hi = None
        if ctx.x is not None and ctx.d <= 3:
            h = jnp.asarray(bandwidth if bandwidth is not None
                            else kde.scott_bandwidth(ctx.x), ctx.x.dtype)
            bandwidth = float(h)
            grid_lo, grid_hi = kde.binned_bounds(ctx.x, ctx.x, h)
        return cls(
            config=cfg, lam=float(st.fit.lam),
            bandwidth=float(bandwidth) if bandwidth is not None else None,
            beta=st.fit.beta, landmarks=st.fit.landmarks,
            landmark_idx=st.fit.landmark_idx,
            k_mm_whitener=_whitened_factor(pipeline.kernel,
                                           st.fit.landmarks, cfg.jitter),
            grid_lo=grid_lo, grid_hi=grid_hi, n_fit=st.n,
            backend=pipeline._predict_backend(),
            tile=pipeline._predict_tile(), precision=pipeline._solve_precision())

    # ------------------------------------------------------------ predict --
    @property
    def kernel(self) -> Kernel:
        return self.config.build_kernel()

    @property
    def num_landmarks(self) -> int:
        return int(self.beta.shape[0])

    @property
    def dim(self) -> int:
        return int(self.landmarks.shape[1])

    def as_fit(self) -> nystrom.NystromFit:
        return nystrom.NystromFit(beta=self.beta, landmarks=self.landmarks,
                                  landmark_idx=self.landmark_idx,
                                  lam=self.lam)

    def predict(self, x: Array) -> Array:
        """f(x) for (k, d) query rows — stateless, jit-able, bit-equal to
        `pipeline.predict(x)` (same `nystrom.predict_streaming` call with
        the frozen backend/tile/precision)."""
        return nystrom.predict_streaming(self.kernel, self.as_fit(), x,
                                         tile=self.tile, backend=self.backend,
                                         precision=self.precision)

    # ------------------------------------------------------------ refresh --
    def refresh(self, fit: nystrom.NystromFit, *,
                bandwidth: float | None = None,
                n_fit: int | None = None) -> "ServableKRR":
        """A NEW artifact serving an updated fit — the online-ingestion
        bridge: `SAKRRPipeline.partial_fit` (or an
        `online.OnlineLandmarks.refit`) produces the fit, `refresh` wraps
        it, and `ServingEngine.hot_swap` swaps it in without dropping
        in-flight requests.

        When the landmark set is unchanged (the `partial_fit` fast path)
        the frozen O(m^3) whitener is reused; a changed dictionary
        (SQUEAK add/drop) recomputes it once here, off the request path.
        The execution knobs (backend / tile / precision) and grid bounds
        carry over unchanged.
        """
        same_landmarks = (
            fit.landmarks.shape == self.landmarks.shape
            and bool(jnp.all(fit.landmarks == self.landmarks)))
        whitener = (self.k_mm_whitener if same_landmarks else
                    _whitened_factor(self.kernel, fit.landmarks,
                                     self.config.jitter))
        return dataclasses.replace(
            self, lam=float(fit.lam), beta=fit.beta,
            landmarks=fit.landmarks, landmark_idx=fit.landmark_idx,
            k_mm_whitener=whitener,
            bandwidth=self.bandwidth if bandwidth is None else bandwidth,
            n_fit=self.n_fit if n_fit is None else int(n_fit))

    def in_support(self, x: Array) -> Array:
        """(k,) bool: query inside the fitted KDE grid bounds (all dims)."""
        if self.grid_lo is None:
            raise RuntimeError("no grid bounds were frozen (direct-KDE / "
                               "d > 3 fit); in_support is undefined")
        return jnp.all((x >= self.grid_lo[None, :])
                       & (x <= self.grid_hi[None, :]), axis=1)

    # --------------------------------------------------------- save / load --
    def save(self, path: str) -> str:
        """One-file npz bundle: arrays binary-exact, config/scalars as an
        embedded JSON header.  Returns the written path (npz-suffixed)."""
        meta = {
            "format_version": FORMAT_VERSION,
            "pipeline_config": self.config.to_dict(),
            "lam": self.lam, "bandwidth": self.bandwidth,
            "n_fit": self.n_fit, "backend": self.backend,
            "tile": self.tile, "precision": self.precision,
            "has_grid_bounds": self.grid_lo is not None,
        }
        arrays = {
            "beta": np.asarray(self.beta),
            "landmarks": np.asarray(self.landmarks),
            "landmark_idx": np.asarray(self.landmark_idx),
            "k_mm_whitener": np.asarray(self.k_mm_whitener),
        }
        if self.grid_lo is not None:
            arrays["grid_lo"] = np.asarray(self.grid_lo)
            arrays["grid_hi"] = np.asarray(self.grid_hi)
        if not path.endswith(".npz"):
            path += ".npz"
        with open(path, "wb") as f:
            np.savez(f, __meta__=np.frombuffer(
                json.dumps(meta).encode(), dtype=np.uint8), **arrays)
        return path

    @classmethod
    def load(cls, path: str) -> "ServableKRR":
        from repro.pipeline import PipelineConfig

        if not path.endswith(".npz"):
            path += ".npz"
        with np.load(path) as z:
            meta = json.loads(bytes(z["__meta__"].tobytes()).decode())
            if meta.get("format_version") != FORMAT_VERSION:
                raise ValueError(
                    f"servable bundle {path!r} has format_version "
                    f"{meta.get('format_version')!r}; this build reads "
                    f"{FORMAT_VERSION}")
            arrays = {k: jnp.asarray(z[k]) for k in z.files
                      if k != "__meta__"}
        cfg = PipelineConfig.from_dict(meta["pipeline_config"])
        return cls(
            config=cfg, lam=float(meta["lam"]),
            bandwidth=meta["bandwidth"], beta=arrays["beta"],
            landmarks=arrays["landmarks"],
            landmark_idx=arrays["landmark_idx"],
            k_mm_whitener=arrays["k_mm_whitener"],
            grid_lo=arrays.get("grid_lo"), grid_hi=arrays.get("grid_hi"),
            n_fit=int(meta["n_fit"]), backend=meta["backend"],
            tile=meta["tile"], precision=meta["precision"])
