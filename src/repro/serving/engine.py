"""Microbatching KRR predict engine: queue -> coalesce -> bucketed jit.

`ServingEngine` turns a frozen `ServableKRR` artifact into an online
service.  Callers `submit()` query rows from any thread and get a
`concurrent.futures.Future` back; a single worker thread drains the queue,
coalesces waiting requests into one padded batch, and runs the artifact's
predict under jit.  Three things keep the request path fast:

  * **pow2 batch buckets.**  A coalesced batch of k rows is zero-padded up
    to the next power of two (>= ``min_bucket``), and each bucket size owns
    its own jit'd callable — so a never-seen request size never retraces
    the common path, and the total number of compilations is
    log2(max_batch) regardless of traffic shape.  Padding is cheap because
    `streaming.tile_map` tiles at ``min(tile, rows)``: a 16-row bucket does
    16 rows of kernel work, not one full training tile.
  * **donated input buffers.**  On accelerators the padded device buffer is
    donated to the jit call (``donate_argnums``), so steady-state serving
    allocates no new input storage per batch.  (CPU jax does not support
    donation; the engine detects that and skips it.)
  * **transfer/compute overlap.**  The worker dispatches batch k (jax is
    async — the call returns before compute finishes), then assembles and
    `device_put`s batch k+1 while batch k is still on the device, and only
    then blocks to deliver batch k.  Under load, host->device transfer of
    the next batch always hides behind compute of the current one.

Latency-vs-throughput knobs: ``max_batch`` caps coalescing (bigger = more
throughput, fatter tail), ``max_delay_s`` optionally holds the first
request of a batch to let followers arrive (0 = greedy dispatch, lowest
p50; a few hundred microseconds trades p50 for occupancy), ``min_bucket``
floors the padded size so tiny batches share one compiled shape.
"""

from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.artifact import ServableKRR


@dataclass
class _Request:
    rows: np.ndarray            # (k, d) float
    future: Future = field(default_factory=Future)
    squeeze: bool = False       # caller passed a single (d,) row


@dataclass
class EngineStats:
    batches: int = 0            # jit dispatches
    rows: int = 0               # real (unpadded) rows served
    padded_rows: int = 0        # rows incl. bucket padding
    compiles: int = 0           # distinct (artifact, bucket) pairs traced
    swaps: int = 0              # hot_swap() artifact replacements

    @property
    def occupancy(self) -> float:
        """Real rows / padded rows — 1.0 means no padding waste."""
        return self.rows / self.padded_rows if self.padded_rows else 0.0


class ServingEngine:
    """Threaded microbatcher over a `ServableKRR` (see module docstring)."""

    def __init__(self, artifact: ServableKRR, *, max_batch: int = 256,
                 max_delay_s: float = 0.0, min_bucket: int = 8):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = int(max_batch)
        self.max_delay_s = float(max_delay_s)
        self.min_bucket = int(min_bucket)
        self.stats = EngineStats()
        self._dtype = np.asarray(artifact.landmarks).dtype
        self._queue: queue.Queue[_Request] = queue.Queue()
        # the served model is an (artifact, per-bucket-jit-cache) PAIR that
        # swaps as ONE reference (`hot_swap`): a dispatch reads it once, so
        # a batch never mixes one artifact's weights with another's jit —
        # and the GIL makes the single attribute store/load atomic, no lock
        # on the request path
        self._active: tuple[ServableKRR, dict[int, object]] = (artifact, {})
        self._worker: threading.Thread | None = None
        self._running = False

    @property
    def artifact(self) -> ServableKRR:
        """The artifact new batches are served from (live view)."""
        return self._active[0]

    # ---------------------------------------------------------- lifecycle --
    def start(self) -> "ServingEngine":
        if self._worker is not None:
            raise RuntimeError("engine already started")
        self._running = True
        self._worker = threading.Thread(target=self._loop,
                                        name="krr-serving", daemon=True)
        self._worker.start()
        return self

    def stop(self) -> None:
        """Drain the queue, deliver everything in flight, join the worker."""
        if self._worker is None:
            return
        self._running = False
        self._worker.join()
        self._worker = None
        while True:         # anything racing in after the drain: refuse
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                break
            req.future.set_exception(RuntimeError("engine stopped"))

    def __enter__(self) -> "ServingEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def hot_swap(self, artifact: ServableKRR) -> None:
        """Replace the served artifact WITHOUT stopping the engine.

        Swaps the (artifact, jit-cache) pair in one atomic reference
        store.  In-flight and already-dispatched batches finish against
        the artifact they were packed with; every batch dispatched after
        the swap serves the new one — no request is dropped, reordered,
        or mixed across artifacts.  The new artifact must be
        shape-compatible (same input dim and dtype); typically it comes
        from `ServableKRR.refresh` after a `partial_fit` /
        `OnlineLandmarks.refit`.  Callable from any thread.
        """
        if artifact.dim != self.artifact.dim:
            raise ValueError(
                f"hot_swap artifact has input dim {artifact.dim}; the "
                f"engine serves dim {self.artifact.dim}")
        if np.asarray(artifact.landmarks).dtype != self._dtype:
            raise ValueError(
                f"hot_swap artifact has dtype "
                f"{np.asarray(artifact.landmarks).dtype}; the engine "
                f"serves {self._dtype}")
        self._active = (artifact, {})
        self.stats.swaps += 1

    def warm(self, buckets: tuple[int, ...] | None = None) -> None:
        """Pre-compile the bucketed jit cache (off the request path)."""
        if buckets is None:
            buckets = tuple(b for b in
                            (2 ** p for p in range(16))
                            if self.min_bucket <= b <= self.max_batch)
            buckets = buckets or (self._bucket(1),)
        active = self._active
        d = active[0].dim
        for b in buckets:
            x = jnp.zeros((b, d), dtype=self._dtype)
            jax.block_until_ready(self._jit_for(active, b)(x))

    # ------------------------------------------------------------- submit --
    def submit(self, rows) -> Future:
        """Enqueue (k, d) rows (or one (d,) row) -> Future of (k,) [or ()]
        float predictions.  Thread-safe; resolves in submission order."""
        if self._worker is None:
            raise RuntimeError("engine not started (use `with engine:` or "
                               "engine.start())")
        arr = np.asarray(rows, dtype=self._dtype)
        squeeze = arr.ndim == 1
        if squeeze:
            arr = arr[None, :]
        if arr.ndim != 2 or arr.shape[1] != self.artifact.dim:
            raise ValueError(f"expected rows of dim {self.artifact.dim}, "
                             f"got shape {np.asarray(rows).shape}")
        req = _Request(rows=arr, squeeze=squeeze)
        self._queue.put(req)
        return req.future

    def predict(self, rows) -> np.ndarray:
        """Synchronous submit + wait."""
        return self.submit(rows).result()

    # ------------------------------------------------------------- worker --
    def _bucket(self, k: int) -> int:
        b = self.min_bucket
        while b < k:
            b *= 2
        return b

    def _jit_for(self, active: tuple[ServableKRR, dict], bucket: int):
        artifact, jits = active
        fn = jits.get(bucket)
        if fn is None:
            donate = (0,) if jax.default_backend() != "cpu" else ()
            fn = jax.jit(artifact.predict, donate_argnums=donate)
            jits[bucket] = fn
            self.stats.compiles += 1
        return fn

    def _collect(self, block: bool) -> list[_Request]:
        """Coalesce queued requests up to ``max_batch`` rows.

        ``block``: wait (in short stop-checkable slices) for the first
        request; False = the worker has a batch in flight and must not
        stall its delivery, so only grab what is already waiting.
        """
        items: list[_Request] = []
        rows = 0
        deadline = None
        while True:
            if not items:
                if block:
                    try:
                        req = self._queue.get(timeout=0.02)
                    except queue.Empty:
                        if not (self._running or not self._queue.empty()):
                            return items
                        continue
                else:
                    try:
                        req = self._queue.get_nowait()
                    except queue.Empty:
                        return items
                if self.max_delay_s > 0.0:
                    deadline = time.monotonic() + self.max_delay_s
            else:
                if rows >= self.max_batch:
                    return items
                timeout = None if deadline is None else (
                    deadline - time.monotonic())
                try:
                    if timeout is None or timeout <= 0.0:
                        req = self._queue.get_nowait()
                    else:
                        req = self._queue.get(timeout=timeout)
                except queue.Empty:
                    return items
            items.append(req)
            rows += req.rows.shape[0]

    def _dispatch(self, items: list[_Request]):
        """Pack, pad to the bucket, device_put, launch jit (non-blocking)."""
        active = self._active        # ONE read: weights + jits stay paired
        rows = int(sum(r.rows.shape[0] for r in items))
        bucket = self._bucket(rows)
        batch = np.zeros((bucket, active[0].dim), dtype=self._dtype)
        off = 0
        for r in items:
            k = r.rows.shape[0]
            batch[off:off + k] = r.rows
            off += k
        out = self._jit_for(active, bucket)(jax.device_put(batch))
        self.stats.batches += 1
        self.stats.rows += rows
        self.stats.padded_rows += bucket
        return items, out

    def _deliver(self, items: list[_Request], out) -> None:
        """Block on the device result and resolve every request future."""
        host = np.asarray(out)          # blocks until compute finishes
        off = 0
        for r in items:
            k = r.rows.shape[0]
            piece = host[off:off + k]
            off += k
            r.future.set_result(piece[0] if r.squeeze else piece.copy())

    def _loop(self) -> None:
        pending = None
        while True:
            items = None
            try:
                items = self._collect(block=pending is None)
                inflight = self._dispatch(items) if items else None
            except BaseException as e:     # fail loudly into the futures
                for r in (items or []):
                    if not r.future.done():
                        r.future.set_exception(e)
                inflight = None
            if pending is not None:
                try:
                    self._deliver(*pending)
                except BaseException as e:
                    for r in pending[0]:
                        if not r.future.done():
                            r.future.set_exception(e)
            pending = inflight
            if (pending is None and not self._running
                    and self._queue.empty()):
                return
