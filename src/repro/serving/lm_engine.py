"""Legacy LM demo engine: prefill + jit'd decode loop with sampling.

The KRR serving path lives in `repro.serving.engine` / `repro.serving.
artifact`; this module only backs the language-model demo stack
(`examples/serve_lm.py`, `repro.models`) and its smoke tests.

Production shape: one jit'd ``decode_step`` (params, token, caches, index)
reused across requests; the engine batches requests, left-pads prompts to a
common length, greedily (or with temperature) samples until max_new_tokens.
On TPU the same step is what the decode_32k / long_500k dry-run cells lower.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig

Array = jax.Array


@dataclasses.dataclass
class GenerationResult:
    tokens: Array          # (b, max_new_tokens)
    logprobs: Array        # (b, max_new_tokens)


class Engine:
    def __init__(self, cfg: ModelConfig, params):
        self.cfg = cfg
        self.params = params
        self._decode = jax.jit(
            lambda p, t, c, i: M.decode_step(p, t, c, i, cfg))
        self._prefill = jax.jit(
            lambda p, toks, S: M.prefill(p, toks, cfg, cache_seq_len=S),
            static_argnums=(2,))

    def generate(self, key: Array, prompts: Array, max_new_tokens: int,
                 temperature: float = 0.0) -> GenerationResult:
        """prompts: (b, prompt_len) int32 (right-aligned, no padding)."""
        b, t0 = prompts.shape[:2]
        total = t0 + max_new_tokens
        prompt_in = prompts
        if self.cfg.inputs_embeds:  # audio/vlm stubs: embed via the table
            prompt_in = jnp.take(self.params["embed"], prompts, axis=0)
        logits, caches = self._prefill(self.params, prompt_in, total)
        out_tokens, out_lp = [], []
        tok = None
        for i in range(max_new_tokens):
            lp = jax.nn.log_softmax(logits[:, 0].astype(jnp.float32), axis=-1)
            if temperature <= 0.0:
                tok = jnp.argmax(lp, axis=-1)
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, lp / temperature, axis=-1)
            out_tokens.append(tok)
            out_lp.append(jnp.take_along_axis(lp, tok[:, None], 1)[:, 0])
            step_in = tok[:, None]
            if self.cfg.inputs_embeds:  # audio/vlm stubs: embed via table
                step_in = jnp.take(self.params["embed"], step_in, axis=0)
            logits, caches = self._decode(self.params, step_in, caches,
                                          jnp.int32(t0 + i))
        return GenerationResult(
            tokens=jnp.stack(out_tokens, axis=1),
            logprobs=jnp.stack(out_lp, axis=1))
