"""Online serving for fitted SA-KRR models.

`ServableKRR` freezes a fitted pipeline into a save/load-able predict
bundle; `ServingEngine` microbatches concurrent requests over it.  The
legacy LM demo engine lives in `repro.serving.lm_engine`.
"""

from repro.serving.artifact import ServableKRR
from repro.serving.engine import EngineStats, ServingEngine

__all__ = ["ServableKRR", "ServingEngine", "EngineStats"]
