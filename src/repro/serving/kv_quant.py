"""int8 KV-cache quantization for serving (2x memory over bf16).

Per-(position, head) absmax scales: K/V rows are quantized independently so
a single outlier token cannot poison the cache.  At decode_32k scale this
turns e.g. musicgen's 12.9 GB/device cache into 6.6 GB (+2% for scales);
decode attention dequantizes on the fly (the dequant fuses into the QK^T /
PV dots on TPU).

Accuracy: absmax int8 on K/V is the standard serving recipe; the attention
output error it induces is ~0.3-0.5% relative (validated in
tests/test_kv_quant.py against the bf16 cache path).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class QuantizedKV(NamedTuple):
    q: Array        # int8, same shape as the raw cache
    scale: Array    # fp16/bf16, shape = cache shape without the last dim


def quantize(x: Array, scale_dtype=jnp.bfloat16) -> QuantizedKV:
    """x: (..., head_dim) -> int8 with per-row absmax scales."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return QuantizedKV(q=q.astype(jnp.int8),
                       scale=scale[..., 0].astype(scale_dtype))


def dequantize(qkv: QuantizedKV, dtype=jnp.float32) -> Array:
    return (qkv.q.astype(jnp.float32)
            * qkv.scale.astype(jnp.float32)[..., None]).astype(dtype)


def update_row(qkv: QuantizedKV, new: Array, index) -> QuantizedKV:
    """Insert a freshly-quantized row at `index` along the seq axis (-2)."""
    row = quantize(new, qkv.scale.dtype)
    ndim = qkv.q.ndim
    start = [0] * ndim
    start[-2] = index
    q = jax.lax.dynamic_update_slice(qkv.q, row.q.astype(jnp.int8),
                                     tuple(start))
    scale = jax.lax.dynamic_update_slice(qkv.scale, row.scale,
                                         tuple(start[:-1]))
    return QuantizedKV(q=q, scale=scale)
