"""train_step factory + Trainer with production fault-tolerance behaviour.

make_train_step builds the jit-able pure function

    (params, opt_state, batch, rng) -> (params, opt_state, metrics)

with optional microbatch gradient accumulation (lax.scan over microbatches —
constant memory in accumulation steps) and optional error-feedback gradient
compression applied to the cross-pod all-reduce (see distributed/compression).

Trainer adds the operational layer the brief requires at 1000+ nodes:

  * checkpoint/restart — periodic async sharded checkpoints; on construction
    the Trainer resumes from the newest valid checkpoint (kill -9 mid-run and
    re-launch is the supported recovery path, exercised in tests);
  * deterministic data resume — the TokenStream is stateless (batch_at(step)),
    so a restarted run consumes exactly the batches it would have seen;
  * straggler detection — per-step wall-time EWMA + z-score; steps slower
    than ``straggler_z`` sigmas are counted and surfaced in metrics (on a real
    fleet this feeds the reschedule signal; here it drives logging + tests);
  * elastic restart — checkpoints are mesh-agnostic (full arrays), so a
    restore onto a different device count / rules just re-shards (tested).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw


def make_train_step(cfg: ModelConfig, hp: adamw.Hparams,
                    num_microbatches: int = 1,
                    compress_fn: Optional[Callable] = None):
    """Pure train step; microbatches split the batch's leading axis."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            M.loss_fn, has_aux=True)(params, batch, cfg)
        return loss, metrics, grads

    def step(params, opt_state, batch, _rng=None):
        if num_microbatches == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                mb = b // num_microbatches
                return x.reshape((num_microbatches, mb) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                loss_a, grads_a, metrics_a = acc
                loss, metrics, grads = grads_of(params, mb)
                grads = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grads_a, grads)
                return (loss_a + loss, grads,
                        jax.tree.map(jnp.add, metrics_a, metrics)), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zero_m = {"nll": jnp.zeros((), jnp.float32),
                      "aux": jnp.zeros((), jnp.float32)}
            (loss, grads, metrics), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero_g, zero_m), micro)
            inv = 1.0 / num_microbatches
            loss = loss * inv
            grads = jax.tree.map(lambda g: g * inv, grads)
            metrics = jax.tree.map(lambda m: m * inv, metrics)

        if compress_fn is not None:
            grads = compress_fn(grads)
        params, opt_state, opt_metrics = adamw.update(grads, opt_state,
                                                      params, hp)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return step


@dataclasses.dataclass
class TrainerConfig:
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 50
    async_checkpoint: bool = True
    straggler_z: float = 3.0
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: ModelConfig, hp: adamw.Hparams, data,
                 tcfg: TrainerConfig, rng: jax.Array,
                 num_microbatches: int = 1):
        from repro.checkpoint import manager as ckpt
        self.cfg, self.hp, self.data, self.tcfg = cfg, hp, data, tcfg
        self.ckpt = ckpt.Manager(tcfg.checkpoint_dir,
                                 async_write=tcfg.async_checkpoint)
        self.step_fn = jax.jit(make_train_step(cfg, hp, num_microbatches),
                               donate_argnums=(0, 1))
        self.params = M.init(rng, cfg)
        self.opt_state = adamw.init(self.params)
        self.step = 0
        self.straggler_events = 0
        self._ewma = None
        self._ewvar = 0.0
        # fault tolerance: resume from the newest checkpoint if one exists
        latest = self.ckpt.latest_step()
        if latest is not None:
            restored = self.ckpt.restore(latest,
                                         (self.params, self.opt_state))
            self.params, self.opt_state = restored
            self.step = latest

    def _track_stragglers(self, dt: float) -> bool:
        if self._ewma is None:
            self._ewma = dt
            return False
        z = (dt - self._ewma) / max(self._ewvar ** 0.5, 1e-6)
        slow = z > self.tcfg.straggler_z and dt > 1.5 * self._ewma
        self._ewma = 0.9 * self._ewma + 0.1 * dt
        self._ewvar = 0.9 * self._ewvar + 0.1 * (dt - self._ewma) ** 2
        if slow:
            self.straggler_events += 1
        return slow

    def run(self, num_steps: int, on_step=None) -> dict:
        metrics = {}
        for _ in range(num_steps):
            batch = self.data.batch_at(self.step)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            self._track_stragglers(time.perf_counter() - t0)
            self.step += 1
            if self.step % self.tcfg.checkpoint_every == 0:
                self.ckpt.save(self.step, (self.params, self.opt_state))
            if on_step is not None:
                on_step(self.step, metrics)
        self.ckpt.wait()
        return {k: float(v) for k, v in metrics.items()} | {
            "straggler_events": self.straggler_events}
