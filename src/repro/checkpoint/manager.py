"""Sharded checkpointing with async writes + elastic (mesh-agnostic) restore.

Format: one ``step_<N>/`` directory per checkpoint containing a single
``state.npz`` (leaf arrays keyed by flattened tree path) and ``MANIFEST``
written LAST — a checkpoint without its manifest is treated as torn and
ignored by restore, which is what makes kill-mid-write recovery safe.

Arrays are saved device-agnostic (fully replicated view via
``jax.device_get``), so a restore may target a different mesh shape / device
count than the writer ("elastic resharding"): pass abstract targets with
shardings and the loader ``jax.device_put``s each leaf accordingly.  On a
real multi-host fleet the same layout is written per-host for the host's
addressable shards; the manifest/tear-safety logic is identical.
"""

from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


class Manager:
    def __init__(self, directory: str, async_write: bool = True,
                 keep: int = 3):
        self.dir = directory
        self.async_write = async_write
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- write ----
    def save(self, step: int, state) -> None:
        # join the previous async write BEFORE touching the new state: the
        # host copy below can block on device work for a long time, and
        # overlapping it with a still-running writer thread means a crash
        # in _flatten leaves the previous checkpoint half-written with its
        # thread orphaned (and an in-place-updated state could be
        # snapshotted while the old writer still reads the same buffers)
        self.wait()
        flat = _flatten(state)  # host copy in caller's thread (cheap for
        # sharded arrays: device_get of addressable shards)
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat), daemon=True)
            self._thread.start()
        else:
            self._write(step, flat)

    def _write(self, step: int, flat: dict) -> None:
        path = os.path.join(self.dir, f"step_{step}")
        tmp = path + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "state.npz"), **flat)
        with open(os.path.join(tmp, "MANIFEST"), "w") as f:
            f.write(f"step={step}\nleaves={len(flat)}\n")
        shutil.rmtree(path, ignore_errors=True)
        os.replace(tmp, path)
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self._steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -------------------------------------------------------------- read ----
    def _steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "MANIFEST")):
                out.append(int(m.group(1)))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self._steps()
        return max(steps) if steps else None

    def restore(self, step: int, target):
        """target: a pytree of arrays or ShapeDtypeStructs (with optional
        shardings) defining structure/placement for the restored state."""
        path = os.path.join(self.dir, f"step_{step}", "state.npz")
        data = np.load(path)
        paths, treedef = jax.tree_util.tree_flatten_with_path(target)
        leaves = []
        for path_k, tgt in paths:
            key = "/".join(str(p) for p in path_k)
            arr = data[key]
            sharding = getattr(tgt, "sharding", None)
            if sharding is not None and not isinstance(
                    sharding, jax.sharding.SingleDeviceSharding):
                leaves.append(jax.device_put(arr, sharding))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=tgt.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)
