"""Beyond-paper bridge: SA leverage scores as landmark weights for Nyström
ATTENTION (Nyströmformer-style) — the paper's "sample where density is low"
insight applied to softmax-attention approximation.

The softmax kernel exp(q.k/sqrt(d)) is not stationary, but landmark quality
is still governed by coverage of the key distribution; SA weights computed
from the key density up-weight rare keys exactly like they up-weight rare
inputs in KRR.  Caveat straight from the paper (§3.2 / App. B.4): the SA
density exponent d/(2α) − 1 flattens as d grows, so the demo uses
low-dimensional keys (d=4) — in line with the paper's own scope, and with
attention heads whose keys concentrate near low-dimensional structure.

We build a bimodal key set (5% of keys in a rare-but-queried mode) and
compare the attention-output error with m landmarks sampled uniformly vs by
SA weights, averaged over sampling seeds.

  PYTHONPATH=src python examples/nystrom_attention.py
"""

import jax
import jax.numpy as jnp

from repro.core import kde, kernels, leverage, sampling


def softmax_attention(q, k, v):
    logits = q @ k.T / jnp.sqrt(q.shape[-1])
    return jax.nn.softmax(logits, axis=-1) @ v


def nystrom_attention(q, k, v, landmarks):
    """Nyströmformer: softmax(QK^T) ~ F @ pinv(A) @ B with landmark set L."""
    kl = k[landmarks]
    scale = 1.0 / jnp.sqrt(q.shape[-1])
    F = jax.nn.softmax(q @ kl.T * scale, axis=-1)         # (n, m)
    A = jax.nn.softmax(kl @ kl.T * scale, axis=-1)        # (m, m)
    B = jax.nn.softmax(kl @ k.T * scale, axis=-1)         # (m, n)
    return F @ jnp.linalg.pinv(A) @ (B @ v)


def main() -> None:
    key = jax.random.PRNGKey(0)
    n, d, m, reps = 2048, 4, 32, 8
    kq, kk, kv, km, ks1 = jax.random.split(key, 5)
    # bimodal keys: 95% around 0, 5% in a far mode that dominates some queries
    kmain = jax.random.normal(kk, (n, d))
    krare = 5.0 + 0.25 * jax.random.normal(km, (n, d))
    is_rare = jax.random.uniform(ks1, (n,)) < 0.05
    k = jnp.where(is_rare[:, None], krare, kmain)
    # a third of the queries target the rare mode
    q = jax.random.normal(kq, (n, d))
    q = q.at[: n // 3].add(5.0)
    v = jax.random.normal(kv, (n, d))

    exact = softmax_attention(q, k, v)

    dens = kde.kde_direct(k, k, 0.7)
    sa = leverage.sa_leverage(dens, lam=1e-2,
                              kernel=kernels.Matern(nu=0.5), d=d)
    results = {}
    for name, probs in (("uniform", jnp.full((n,), 1.0 / n)),
                        ("sa-leverage", sa.probs)):
        errs, cov = [], []
        for r in range(reps):
            idx = sampling.sample_without_replacement(
                jax.random.PRNGKey(100 + r), probs, m)
            approx = nystrom_attention(q, k, v, idx)
            errs.append(float(jnp.linalg.norm(approx - exact)
                              / jnp.linalg.norm(exact)))
            cov.append(float(jnp.mean(is_rare[idx])))
        results[name] = sum(errs) / reps
        print(f"{name:>12}: rel. attention error = {results[name]:.4f}  "
              f"(rare-mode landmark share: {100*sum(cov)/reps:.0f}%, "
              f"population share 5%)")
    assert results["sa-leverage"] < results["uniform"], results
    print("SA landmark weighting covers the rare key mode that uniform "
          "sampling under-represents.")


if __name__ == "__main__":
    main()
