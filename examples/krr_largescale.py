"""End-to-end driver (the paper's kind): large-scale Nyström KRR with SA
leverage scores through the streaming `repro.pipeline` stack.

KDE → analytic SA leverage (Eq. 6) → importance-sampled landmarks →
streaming Nyström solve (G = K_nm^T K_nm accumulated over row tiles; the
(n, m) cross-kernel matrix is never materialized) → batched predict.
Default n = 1,000,000 with m = 1024 landmarks fits on a laptop CPU in
O(tile · m) memory; the paper's 5e5-on-a-Xeon headline is the warm-up.
RC/BLESS leverage baselines are run at reduced n for the timing comparison.

  PYTHONPATH=src python examples/krr_largescale.py [--n 1000000] [--m 1024]

`--calibrate` additionally tunes (lam, h) on data through the one-fold
shared-Gram/shared-deposit sweep (`SAKRRPipeline.calibrate`) before the
refit, instead of trusting the paper's asymptotic rates.
"""

import argparse
import time

import jax

from repro.core import krr, rls
from repro.data import krr_data
from repro.pipeline import PipelineConfig, SAKRRPipeline


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1_000_000)
    ap.add_argument("--m", type=int, default=1024, help="Nystrom landmarks")
    ap.add_argument("--tile", type=int, default=16384,
                    help="rows per streaming slab")
    ap.add_argument("--compare-n", type=int, default=20_000,
                    help="n for the RC/BLESS timing comparison")
    ap.add_argument("--calibrate", action="store_true",
                    help="tune (lam, h) on a holdout fold before the refit "
                         "(one shared Gram per h, one KDE deposit total)")
    args = ap.parse_args()

    key = jax.random.PRNGKey(7)

    # -- headline run: the full evaluate fold at n -------------------------
    n = args.n
    data = krr_data.bimodal(jax.random.fold_in(key, 0), n, d=3)
    cfg = PipelineConfig(nu=1.5, num_landmarks=args.m, tile=args.tile)
    n_eval = min(n, 100_000)
    pipe = SAKRRPipeline(cfg)
    if args.calibrate:
        out = pipe.calibrate(data.x, data.y, x_eval=data.x[:n_eval],
                             y_eval=data.y[:n_eval],
                             f_star=data.f_star[:n_eval])
        scores = out["scores"]
        print(f"calibrated over {len(out['cv_scores'])} (lam, h) candidates: "
              f"lam={out['lam']:.3e} (paper rate {cfg.resolve_lam(n):.3e}), "
              f"h={out['bandwidth']:.3g}")
    else:
        scores = pipe.evaluate(data.x, data.y, x_eval=data.x[:n_eval],
                               y_eval=data.y[:n_eval],
                               f_star=data.f_star[:n_eval])
    stage = "  ".join(f"{k}={v:.2f}s" for k, v in pipe.seconds.items())
    print(f"n={n:,} m={pipe.state.num_landmarks}  {stage}")
    print(f"  d_stat≈{pipe.d_stat:.1f}   risk={scores['risk']:.5f}   "
          f"rmse={scores['rmse']:.4f}")

    # -- leverage-method comparison at reduced n ---------------------------
    nc = args.compare_n
    lam_c = 0.075 * nc ** (-2 / 3)
    data_c = krr_data.bimodal(jax.random.fold_in(key, 2), nc, d=3)
    pipe_c = SAKRRPipeline(PipelineConfig(nu=1.5)).fit(data_c.x, data_c.y)
    t_sa = pipe_c.seconds["kde"] + pipe_c.seconds["leverage"]
    print(f"n={nc:,}  SA:    {t_sa:6.2f}s")
    kern = pipe_c.kernel
    t0 = time.perf_counter()
    rls.recursive_rls(kern, data_c.x, lam_c)
    print(f"n={nc:,}  RC:    {time.perf_counter()-t0:6.2f}s")
    t0 = time.perf_counter()
    rls.bless(kern, data_c.x, lam_c)
    print(f"n={nc:,}  BLESS: {time.perf_counter()-t0:6.2f}s")


if __name__ == "__main__":
    main()
