"""End-to-end driver (the paper's kind): large-scale Nyström KRR with SA
leverage scores — the full pipeline the paper makes fast.

Density estimation (binned linear-time KDE) -> analytic leverage (Eq. 6
closed form) -> importance-sampled landmarks -> Nyström solve -> risk,
at n = 200,000 on CPU (paper: 5e5 on a Xeon core).  All methods' leverage
times are reported; RC/BLESS are run at reduced n to keep the demo quick.

  PYTHONPATH=src python examples/krr_largescale.py [--n 200000]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import kde, kernels, krr, leverage, nystrom, rls
from repro.data import krr_data


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--compare-n", type=int, default=20_000,
                    help="n for the RC/BLESS timing comparison")
    args = ap.parse_args()

    kern = kernels.Matern(nu=1.5)
    key = jax.random.PRNGKey(7)

    # -- headline run: SA at full n ------------------------------------------
    n = args.n
    lam = 0.075 * n ** (-2 / 3)
    m = int(5 * n ** (1 / 3))
    data = krr_data.bimodal(jax.random.fold_in(key, 0), n, d=3)

    t0 = time.perf_counter()
    dens = kde.estimate_densities(data.x)
    sa = leverage.sa_leverage(dens, lam, kern, d=3)
    jax.block_until_ready(sa.probs)
    t_sa = time.perf_counter() - t0

    t0 = time.perf_counter()
    fit = nystrom.fit(jax.random.fold_in(key, 1), kern, data.x, data.y,
                      lam, m, sa.probs)
    pred = nystrom.fitted(kern, fit, data.x)
    jax.block_until_ready(pred)
    t_fit = time.perf_counter() - t0
    err = float(krr.in_sample_risk(pred, data.f_star))
    print(f"n={n:,}  SA leverage: {t_sa:.2f}s   nystrom(m={m}): {t_fit:.2f}s"
          f"   error={err:.5f}")

    # -- method comparison at reduced n --------------------------------------
    nc = args.compare_n
    lam_c = 0.075 * nc ** (-2 / 3)
    data_c = krr_data.bimodal(jax.random.fold_in(key, 2), nc, d=3)
    t0 = time.perf_counter()
    dens_c = kde.estimate_densities(data_c.x)
    sa_c = leverage.sa_leverage(dens_c, lam_c, kern, d=3)
    jax.block_until_ready(sa_c.probs)
    print(f"n={nc:,}  SA:    {time.perf_counter()-t0:6.2f}s")
    t0 = time.perf_counter()
    rls.recursive_rls(kern, data_c.x, lam_c)
    print(f"n={nc:,}  RC:    {time.perf_counter()-t0:6.2f}s")
    t0 = time.perf_counter()
    rls.bless(kern, data_c.x, lam_c)
    print(f"n={nc:,}  BLESS: {time.perf_counter()-t0:6.2f}s")


if __name__ == "__main__":
    main()
