"""Quickstart: the paper in ~40 lines.

Fit kernel ridge regression on 20k points from the paper's bimodal design
three ways — exact KRR (small-n oracle), Nyström+uniform, Nyström+SA
(the paper's method) — and compare error and the time spent estimating
leverage scores.

  PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import jax.numpy as jnp

from repro.core import kde, kernels, krr, leverage, nystrom
from repro.data import krr_data

N, D = 20_000, 3
key = jax.random.PRNGKey(0)
kd, ks1, ks2 = jax.random.split(key, 3)

data = krr_data.bimodal(kd, N, d=D)
kern = kernels.Matern(nu=1.5)
lam = 0.075 * N ** (-2 / 3)
m = int(5 * N ** (1 / 3))  # number of Nyström landmarks

# --- the paper's method: density -> analytic leverage -> sampling weights ---
t0 = time.perf_counter()
dens = kde.estimate_densities(data.x)                      # Õ(n) binned KDE
sa = leverage.sa_leverage(dens, lam, kern, d=D)            # Eq. (6), closed form
sa_seconds = time.perf_counter() - t0
print(f"SA leverage for n={N:,}: {sa_seconds*1e3:.1f} ms "
      f"(d_stat ≈ {float(sa.d_stat):.1f} effective dims)")

# --- Nyström fits ------------------------------------------------------------
for name, probs, k in (("uniform", jnp.full((N,), 1.0 / N), ks1),
                       ("SA (paper)", sa.probs, ks2)):
    fit = nystrom.fit(k, kern, data.x, data.y, lam, m, probs)
    err = float(krr.in_sample_risk(nystrom.fitted(kern, fit, data.x),
                                   data.f_star))
    print(f"Nyström[{name:>10}]  m={m}  in-sample error = {err:.5f}")

# --- exact KRR oracle on a subsample (O(n^3) — small n only) -----------------
sub = 2_000
exact = krr.fit(kern, data.x[:sub], data.y[:sub], lam)
err = float(krr.in_sample_risk(
    krr.predict(kern, exact, data.x[:sub]), data.f_star[:sub]))
print(f"exact KRR on n={sub} subsample: in-sample error = {err:.5f}")

# --- the same pipeline as one configured object (repro.pipeline) -------------
# SAKRRPipeline chains KDE -> SA leverage -> landmark sampling -> *streaming*
# Nystrom solve -> batched predict.  The solve accumulates K_nm^T K_nm over
# row tiles (lax.scan on CPU, the fused Pallas `gram` kernel on TPU), so the
# (n, m) cross-kernel matrix is never materialized and the same code scales
# to n = 1e6+ and shards rows across a mesh (repro.distributed.sharding).
from repro.pipeline import PipelineConfig, SAKRRPipeline

pipe = SAKRRPipeline(PipelineConfig(nu=1.5, num_landmarks=m)).fit(data.x, data.y)
err = float(krr.in_sample_risk(pipe.fitted(data.x), data.f_star))
stages = "  ".join(f"{k}={v*1e3:.0f}ms" for k, v in pipe.seconds.items())
print(f"SAKRRPipeline      m={m}  in-sample error = {err:.5f}   ({stages})")
