"""Serve a small model with batched requests through the jit'd decode engine.

  PYTHONPATH=src python examples/serve_lm.py --arch qwen3-1.7b
"""

import argparse
import time

import jax

from repro import configs
from repro.models import model as M
from repro.serving.lm_engine import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b",
                    choices=list(configs.ARCHS))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)  # CPU-runnable reduced config
    params = M.init(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params)

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, 12), 0, cfg.vocab_size)
    t0 = time.perf_counter()
    out = engine.generate(jax.random.PRNGKey(2), prompts,
                          max_new_tokens=args.max_new, temperature=0.8)
    jax.block_until_ready(out.tokens)
    dt = time.perf_counter() - t0
    print(f"{args.batch} requests x {args.max_new} tokens in {dt:.2f}s "
          f"({args.batch*args.max_new/dt:.1f} tok/s, includes compile)")
    for i in range(min(3, args.batch)):
        print(f"req{i}: {out.tokens[i].tolist()}")


if __name__ == "__main__":
    main()
