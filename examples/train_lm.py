"""Train an LM with the production Trainer (checkpoint/restart, data resume).

Default is a CPU-quick ~10M-param llama-style config; --full trains the
~100M-param config the brief describes (few hundred steps — use a beefier
host or be patient on CPU).  Kill it mid-run and re-launch with the same
--ckpt to watch fault-tolerant resume.

  PYTHONPATH=src python examples/train_lm.py --steps 60
  PYTHONPATH=src python examples/train_lm.py --full --steps 300
"""

import argparse
import dataclasses

import jax

from repro.configs import get_smoke
from repro.data import tokens
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.training.train import Trainer, TrainerConfig

TINY = ModelConfig(
    name="llama-tiny-10m", family="dense", num_layers=4, d_model=256,
    n_heads=8, n_kv_heads=4, head_dim=32, d_ff=1024, vocab_size=8192,
)
FULL_100M = ModelConfig(
    name="llama-100m", family="dense", num_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32_768,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="~100M params")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = FULL_100M if args.full else TINY
    print(f"model={cfg.name} params={cfg.param_count():,}")
    hp = adamw.Hparams(peak_lr=1e-3, warmup_steps=args.steps // 10,
                       total_steps=args.steps)
    data = tokens.for_config(cfg, args.batch, args.seq)
    trainer = Trainer(cfg, hp, data,
                      TrainerConfig(checkpoint_dir=args.ckpt,
                                    checkpoint_every=25),
                      jax.random.PRNGKey(0))
    if trainer.step:
        print(f"resumed from checkpoint at step {trainer.step}")

    def log(step, m):
        if step % 10 == 0:
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}")

    final = trainer.run(args.steps - trainer.step, on_step=log)
    print("final:", final)


if __name__ == "__main__":
    main()
