"""Figure 3 / App. B.4: Gaussian kernels with increasing dimension.

Paper setting: bimodal (gamma=0.4, offset=3.0), Gaussian kernel with
bandwidth sigma = 1.5 n^{-1/(2d+3)}, lam = 0.075 n^{-(d+3)/(2d+3)},
d_sub = 5 n^{d/(2d+3)}.  Expected qualitative result: as d grows, ALL
leverage-based methods converge toward vanilla (curse of dimensionality) —
the paper's own negative result, reproduced here for d in {3, 10}.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks import common
from repro.core import kernels as K
from repro.data import krr_data

N = 4_000
DS = (3, 10)
METHODS = ("vanilla", "sa")
REPLICATES = 3


def main() -> None:
    common.section("fig3: Gaussian kernel, increasing dimension")
    print("d,method,in_sample_error")
    for d in DS:
        sigma = 1.5 * N ** (-1.0 / (2 * d + 3))
        lam = 0.075 * N ** (-(d + 3.0) / (2 * d + 3))
        m = int(5 * N ** (d / (2.0 * d + 3)))
        kernel = K.Gaussian(sigma=sigma)
        for method in METHODS:
            errs = []
            for rep in range(REPLICATES):
                key = jax.random.PRNGKey(rep * 7 + d)
                kd, ks = jax.random.split(key)
                data = krr_data.bimodal(kd, N, d=d, offset=3.0)
                probs, _ = common.leverage_probs(method, key, kernel, data,
                                                 lam, d)
                errs.append(common.nystrom_error(ks, kernel, data, lam,
                                                 probs, m))
            print(f"{d},{method},{np.mean(errs):.5f}")


if __name__ == "__main__":
    main()
