"""Figure 2: SA approximation vs the true rescaled leverage G_lambda(x_i,x_i).

Paper setting: 1-D Unif[0,1] / Beta(15,2) / bimodal; Matern nu=1.5;
lam = 0.45 n^{-0.8}; density floor h = 0.3 n^{-0.8} for Beta (App. B.3).
Reports the mean/max relative error of SA vs the exact rescaled leverage,
at two sample sizes — the error must DECREASE with n (Thm 5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import kde as core_kde
from repro.core import kernels as K
from repro.core import krr, leverage
from repro.data import krr_data

NS = (400, 2_000)


def _dataset(name: str, key, n: int):
    if name == "uniform":
        return krr_data.uniform(key, n, d=1)
    if name == "beta":
        return krr_data.beta_15_2(key, n)
    return krr_data.bimodal_1d_paper(key, n)


def main() -> None:
    common.section("fig2: SA vs true rescaled leverage (1-D)")
    print("distribution,n,mean_rel_err,p90_rel_err")
    kernel = K.Matern(nu=1.5)
    for name in ("uniform", "beta", "bimodal"):
        for n in NS:
            lam = 0.45 * n ** -0.8
            key = jax.random.PRNGKey(n + hash(name) % 1000)
            data = _dataset(name, key, n)
            exact = krr.exact_leverage(kernel, data.x, lam)
            g_true = exact.rescaled
            dens = core_kde.estimate_densities(data.x)
            floor = 0.3 * n ** -0.8 if name == "beta" else None
            sa = leverage.sa_leverage(dens, lam, kernel, d=1, n=n,
                                      floor=floor)
            rel = np.abs(np.asarray(sa.rescaled) - np.asarray(g_true)) / \
                np.asarray(g_true)
            print(f"{name},{n},{rel.mean():.4f},"
                  f"{np.quantile(rel, 0.9):.4f}")


if __name__ == "__main__":
    main()
