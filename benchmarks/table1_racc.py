"""Table 1: statistical leverage score approximation accuracy (R-ACC).

Paper setting: Matern nu=0.5, lam = 0.15 n^{-2a/(2a+d)}, alpha = d/2 + 0.5;
accuracy r_i = q~_i / q_i against EXACT leverage scores (O(n^3) oracle).
Datasets: UCI RQC (d=3) / HTRU2 (d=8) / CCPP (d=5) — offline container, so
surrogate normalized mixtures with the same (n-scaled, d-exact) geometry.
CPU-scaled n=2000 (exact leverage is the bottleneck), 3 replicates.
Reports: time, mean R-ACC, 5th/95th percentiles — mirrors Table 1 columns.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import kernels as K
from repro.core import krr
from repro.data import krr_data

DATASETS = {"rqc_like": 3, "htru2_like": 8, "ccpp_like": 5}
METHODS = ("sa", "vanilla", "rc", "bless")
N = 2_000
REPLICATES = 3


def main() -> None:
    common.section("table1: leverage approximation accuracy (R-ACC)")
    print("dataset,method,seconds,mean_racc,q05,q95")
    for name, d in DATASETS.items():
        kernel = K.Matern(nu=0.5)
        alpha = 0.5 + d / 2.0
        lam = krr_data.paper_lambda(N, d, alpha, scale=0.15)
        rows = {m: [] for m in METHODS}
        for rep in range(REPLICATES):
            key = jax.random.PRNGKey(rep * 31 + d)
            data = krr_data.uci_like(key, N, d)
            exact = krr.exact_leverage(kernel, data.x, lam)
            q = exact.leverage / jnp.sum(exact.leverage)
            for method in METHODS:
                probs, secs = common.leverage_probs(method, key, kernel,
                                                    data, lam, d)
                r = np.asarray(probs / q)
                rows[method].append(
                    (secs, float(np.mean(r)),
                     float(np.quantile(r, 0.05)),
                     float(np.quantile(r, 0.95))))
        for method in METHODS:
            arr = np.array(rows[method])
            print(f"{name},{method},{arr[:,0].mean():.3f},"
                  f"{arr[:,1].mean():.3f},{arr[:,2].mean():.3f},"
                  f"{arr[:,3].mean():.3f}")


if __name__ == "__main__":
    main()
