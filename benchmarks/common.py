"""Shared helpers for the paper-table benchmarks (CPU-scaled).

Every benchmark prints ``name,value,...`` CSV rows under a section header so
bench_output.txt is grep-able; sizes are scaled to the container's single CPU
core (the paper used n up to 5e5 on a Xeon — same asymptotics, smaller n).
"""

from __future__ import annotations

import time
from contextlib import contextmanager

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kde as core_kde
from repro.core import kernels as K
from repro.core import krr, leverage, nystrom, rls
from repro.data import krr_data


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, time.perf_counter() - t0


def section(title: str) -> None:
    print(f"\n## {title}")


def leverage_probs(method: str, key, kernel, data, lam: float, d: int):
    """(probs, seconds) for one leverage-approximation method."""
    n = data.x.shape[0]
    if method == "vanilla":
        return jnp.full((n,), 1.0 / n), 0.0
    if method == "sa":
        t0 = time.perf_counter()
        dens = core_kde.estimate_densities(data.x)
        sa = leverage.sa_leverage(dens, lam, kernel, d, n=n)
        jax.block_until_ready(sa.probs)
        return sa.probs, time.perf_counter() - t0
    if method == "sa_grid":
        t0 = time.perf_counter()
        dens = core_kde.estimate_densities(data.x)
        sa = leverage.sa_leverage(dens, lam, kernel, d, n=n, method="grid")
        jax.block_until_ready(sa.probs)
        return sa.probs, time.perf_counter() - t0
    if method == "rc":
        t0 = time.perf_counter()
        r = rls.recursive_rls(kernel, data.x, lam, seed=int(key[-1]))
        jax.block_until_ready(r.probs)
        return r.probs, time.perf_counter() - t0
    if method == "bless":
        t0 = time.perf_counter()
        r = rls.bless(kernel, data.x, lam, seed=int(key[-1]))
        jax.block_until_ready(r.probs)
        return r.probs, time.perf_counter() - t0
    raise ValueError(method)


def nystrom_error(key, kernel, data, lam: float, probs, m: int,
                  tile: int = 8192) -> float:
    """Risk of an importance-sampled Nystrom fit via the streaming solver
    (O(tile * m) memory — same code path as repro.pipeline)."""
    from repro.core import sampling

    idx = sampling.sample_with_replacement(key, probs, m)
    fit = nystrom.fit_streaming(kernel, data.x, data.y, lam, idx, tile=tile)
    pred = nystrom.predict_streaming(kernel, fit, data.x, tile=tile)
    return float(krr.in_sample_risk(pred, data.f_star))
