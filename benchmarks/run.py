"""Run every paper-table benchmark + the roofline report + the pipeline bench.

  PYTHONPATH=src python -m benchmarks.run                    # all sections
  PYTHONPATH=src python -m benchmarks.run --only fig1
  PYTHONPATH=src python -m benchmarks.run --json BENCH.json  # append records

Sections whose main() accepts a ``json_out`` kwarg (currently: pipeline)
append their records to the --json trajectory file; the others print their
CSV rows as before.
"""

from __future__ import annotations

import argparse
import inspect
import time

from benchmarks import (bench_pipeline, fig1_tradeoff, fig2_curves,
                        fig3_gaussian, roofline_report, table1_racc)

SECTIONS = {
    "fig1": fig1_tradeoff.main,
    "table1": table1_racc.main,
    "fig2": fig2_curves.main,
    "fig3": fig3_gaussian.main,
    "roofline": roofline_report.main,
    "pipeline": bench_pipeline.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(SECTIONS))
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="trajectory file for sections that emit records")
    args = ap.parse_args()
    names = [args.only] if args.only else list(SECTIONS)
    for name in names:
        fn = SECTIONS[name]
        kw = {}
        # Always forward --json (including None): without it, sections must
        # NOT write their module-default trajectory file as a side effect.
        if "json_out" in inspect.signature(fn).parameters:
            kw["json_out"] = args.json
        t0 = time.perf_counter()
        fn(**kw)
        print(f"[{name} done in {time.perf_counter() - t0:.1f}s]")


if __name__ == "__main__":
    main()
