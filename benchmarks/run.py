"""Run every paper-table benchmark + the roofline report.

  PYTHONPATH=src python -m benchmarks.run            # all sections
  PYTHONPATH=src python -m benchmarks.run --only fig1
"""

from __future__ import annotations

import argparse
import time

from benchmarks import (fig1_tradeoff, fig2_curves, fig3_gaussian,
                        roofline_report, table1_racc)

SECTIONS = {
    "fig1": fig1_tradeoff.main,
    "table1": table1_racc.main,
    "fig2": fig2_curves.main,
    "fig3": fig3_gaussian.main,
    "roofline": roofline_report.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(SECTIONS))
    args = ap.parse_args()
    names = [args.only] if args.only else list(SECTIONS)
    for name in names:
        t0 = time.perf_counter()
        SECTIONS[name]()
        print(f"[{name} done in {time.perf_counter() - t0:.1f}s]")


if __name__ == "__main__":
    main()
