"""Time/memory trajectory of the streaming SA→Nyström pipeline.

Sweeps n (and one tile sweep at the largest n), fits `SAKRRPipeline` at each
point, and records per-stage seconds, throughput, peak RSS, and the streaming
slab footprint to ``BENCH_pipeline.json`` — a list of records appended across
runs, so successive commits build a trajectory.

  PYTHONPATH=src python -m benchmarks.bench_pipeline [--n-max 262144]
  PYTHONPATH=src python -m benchmarks.run --only pipeline --json BENCH_pipeline.json

Stage subsets (the pipeline is a stage list, so partial runs are first-class;
this is the CI smoke hook for stage-timing regressions):

  PYTHONPATH=src python -m benchmarks.bench_pipeline --stages kde --n 8192
"""

from __future__ import annotations

import argparse
import json
import os
import resource
import time

import jax

from repro.core import krr
from repro.data import krr_data
from repro.pipeline import PipelineConfig, SAKRRPipeline, default_stages


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def append_records(path: str, records: list[dict]) -> None:
    """Append records to a JSON trajectory file (list-of-dicts on disk)."""
    existing: list[dict] = []
    if os.path.exists(path):
        with open(path) as f:
            existing = json.load(f)
    with open(path, "w") as f:
        json.dump(existing + records, f, indent=1)


def _stage_subset(cfg: PipelineConfig, names: list[str]):
    """Default stage list truncated after the last requested stage (earlier
    stages still run — later ones need their artifacts)."""
    stages = default_stages(cfg)
    known = {s.name for s in stages}
    unknown = sorted(set(names) - known)
    if unknown:
        raise SystemExit(f"unknown stage(s) {unknown}; "
                         f"pick from {sorted(known)}")
    last = max(i for i, s in enumerate(stages) if s.name in names)
    return stages[:last + 1]


def bench_one(n: int, tile: int, m: int | None, seed: int = 0,
              stages: list[str] | None = None) -> dict:
    data = krr_data.bimodal(jax.random.PRNGKey(seed), n, d=3)
    cfg = PipelineConfig(nu=1.5, tile=tile, num_landmarks=m)
    stage_list = _stage_subset(cfg, stages) if stages else None
    t0 = time.perf_counter()
    pipe = SAKRRPipeline(cfg, stages=stage_list).fit(data.x, data.y)
    fit_s = time.perf_counter() - t0
    m_used = pipe.state.num_landmarks
    rec = {
        "section": "pipeline",
        "n": n,
        "m": m_used,
        "tile": tile,
        "fit_seconds": round(fit_s, 4),
        "stage_seconds": {k: round(v, 4) for k, v in pipe.seconds.items()},
        "peak_rss_mb": round(_peak_rss_mb(), 1),
    }
    if pipe.state.fit is not None:   # full run: throughput, slab, predict
        rec["rows_per_second"] = round(n / max(fit_s, 1e-9))
        # memory story: the streaming slab is the largest transient buffer
        rec["slab_mb"] = round(tile * m_used * 4 / 2**20, 2)
        n_eval = min(n, 50_000)
        t0 = time.perf_counter()
        pred = jax.block_until_ready(pipe.predict(data.x[:n_eval]))
        rec["predict_seconds"] = round(time.perf_counter() - t0, 4)
        rec["predict_n"] = n_eval
        rec["risk"] = float(krr.in_sample_risk(pred, data.f_star[:n_eval]))
        rec["d_stat"] = float(pipe.d_stat)
    print(",".join(f"{k}={v}" for k, v in rec.items() if k != "stage_seconds"))
    print("  stages: " + ",".join(f"{k}={v}" for k, v in
                                  rec["stage_seconds"].items()))
    return rec


def main(json_out: str | None = "BENCH_pipeline.json",
         n_max: int = 262_144, n_only: int | None = None,
         stages: list[str] | None = None) -> None:
    print("\n## pipeline (streaming SA->Nystrom)")
    records = []
    if n_only is not None or stages:
        n = n_only or 16_384
        records.append(bench_one(n, tile=min(n, 16_384), m=None,
                                 stages=stages))
    else:
        n = 16_384
        while n <= n_max:
            records.append(bench_one(n, tile=16_384, m=None))
            n *= 4
        # tile sweep at the top size: time/memory trade of the streaming slab
        for tile in (4_096, 65_536):
            records.append(bench_one(n_max, tile=tile, m=None))
    if json_out:
        append_records(json_out, records)
        print(f"[appended {len(records)} records to {json_out}]")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-max", type=int, default=262_144)
    ap.add_argument("--n", type=int, default=None,
                    help="single-point run at this n (no sweep)")
    ap.add_argument("--stages", default=None,
                    help="comma-separated stage subset, e.g. 'kde' or "
                         "'kde,leverage' (runs prerequisites, stops there)")
    ap.add_argument("--json", default="BENCH_pipeline.json")
    args = ap.parse_args()
    main(json_out=args.json or None, n_max=args.n_max, n_only=args.n,
         stages=args.stages.split(",") if args.stages else None)
