"""Time/memory trajectory of the streaming SA→Nyström pipeline.

Sweeps n (and one tile sweep at the largest n), runs `SAKRRPipeline.evaluate`
at each point (KDE→leverage→sample→solve→predict→score in one `run_stages`
fold), and records per-stage seconds, throughput, peak RSS, risk, and the
streaming slab footprint to ``BENCH_pipeline.json`` — a list of records
appended across runs, so successive commits build a trajectory.

  PYTHONPATH=src python -m benchmarks.bench_pipeline [--n-max 262144]
  PYTHONPATH=src python -m benchmarks.run --only pipeline --json BENCH_pipeline.json

Stage subsets (the pipeline is a stage list, so partial runs are first-class;
this is the CI smoke hook for stage-timing regressions — `--stages score`
exercises the full evaluate fold):

  PYTHONPATH=src python -m benchmarks.bench_pipeline --stages kde --n 8192
  PYTHONPATH=src python -m benchmarks.bench_pipeline --stages score --n 8192

Leverage-method comparison (the paper's §4.1 accuracy/cost claim): SA vs
uniform vs Recursive-RLS vs BLESS, each sampled without replacement with
inverse-inclusion weights feeding the weighted projection-leverage estimator
(`rls.from_sketch`) and the weighted SoR solve:

  PYTHONPATH=src python -m benchmarks.bench_pipeline --compare --n 16384

Accumulation-strategy comparison (plain fp32 running sum vs the compensated
two-float stream of `repro.core.streaming`, risk + wall-clock; the fast CI
job smokes the compensated solve at n=8192):

  PYTHONPATH=src python -m benchmarks.bench_pipeline --accumulator          # n=1e6
  PYTHONPATH=src python -m benchmarks.bench_pipeline --accumulator --n 8192

Autotuner comparison (`repro.tuning`: fixed tiles vs roofline-guided
``tile=None`` with a cold measured pass and a warm cache hit; the fast CI
job smokes it at n=8192):

  PYTHONPATH=src python -m benchmarks.bench_pipeline --autotune             # n=262144
  PYTHONPATH=src python -m benchmarks.bench_pipeline --autotune --n 8192

Precision-mode comparison (`repro.core.precision`: the fp32 Gram vs the
Ozaki bf16-split modes x plain/compensated accumulation, with joint
(tile, precision) autotuned rows and both backends' resolved plans; the
fast CI job smokes it at n=8192):

  PYTHONPATH=src python -m benchmarks.bench_pipeline --precision            # n=1e6
  PYTHONPATH=src python -m benchmarks.bench_pipeline --precision --n 8192

Online ingestion (`SAKRRPipeline.partial_fit` over banked accumulator state
vs a full refit, plus frozen vs decayed vs SQUEAK drift tracking on
stationary and shifting streams; the fast CI job smokes it at n=8192):

  PYTHONPATH=src python -m benchmarks.bench_pipeline --online               # n=262144
  PYTHONPATH=src python -m benchmarks.bench_pipeline --online --n 8192
"""

from __future__ import annotations

import argparse
import json
import os
import resource
import time

import jax

from repro.core import krr, nystrom, rls, sampling
from repro.data import krr_data
from repro.pipeline import (PipelineConfig, SAKRRPipeline, evaluate_stages)


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def append_records(path: str, records: list[dict]) -> None:
    """Append records to a JSON trajectory file (list-of-dicts on disk)."""
    existing: list[dict] = []
    if os.path.exists(path):
        with open(path) as f:
            existing = json.load(f)
    with open(path, "w") as f:
        json.dump(existing + records, f, indent=1)


def _stage_throughputs(cfg: PipelineConfig, n: int, m: int, d: int,
                       n_eval: int, seconds: dict) -> dict:
    """Achieved-GFLOP/s + bytes-moved columns per flop-carrying stage.

    Lowers each stage's streamed op at the benched shape with abstract
    arguments (no data), reads the compiled program's counted flops/bytes
    (`roofline.analysis.cost_dict`), and divides by the MEASURED stage
    wall-clock (`analysis.achieved_throughput`) — so compute-bound stages
    show gflops_per_s near the device ceiling and bandwidth-bound ones show
    gbytes_per_s instead.  Stages the run skipped, and backends without
    cost_analysis, simply drop out."""
    import jax.numpy as jnp

    from repro.core import kde as core_kde
    from repro.roofline import analysis

    kern = cfg.build_kernel()
    out: dict[str, dict] = {}
    x_t = jax.ShapeDtypeStruct((n, d), jnp.float32)
    y_t = jax.ShapeDtypeStruct((n,), jnp.float32)

    def cost_for(build, *args):
        return analysis.cost_dict(jax.jit(build).lower(*args).compile())

    if seconds.get("kde"):
        g = cfg.kde_grid_size or core_kde.default_grid_size(d)
        lo = jax.numpy.zeros((d,), jnp.float32)
        sp = jax.numpy.full((d,), 1.0 / max(g - 1, 1), jnp.float32)
        try:
            cost = cost_for(lambda p: core_kde.scatter_cic(
                p, lo, sp, g, tile=cfg.tile,
                accumulator=cfg.accumulator), x_t)
            out["kde"] = analysis.achieved_throughput(cost, seconds["kde"])
        except Exception:
            pass
    if seconds.get("solve"):
        xm_t = jax.ShapeDtypeStruct((m, d), jnp.float32)
        try:
            cost = cost_for(lambda x, y, xm: nystrom.scan_normal_eq(
                kern, x, xm, y, tile=cfg.tile, accumulator=cfg.accumulator,
                precision=cfg.precision), x_t, y_t, xm_t)
            out["solve"] = analysis.achieved_throughput(cost,
                                                        seconds["solve"])
        except Exception:
            pass
    if seconds.get("predict"):
        fitz = nystrom.NystromFit(beta=jnp.zeros((m,), jnp.float32),
                                  landmarks=jnp.zeros((m, d), jnp.float32),
                                  landmark_idx=jnp.arange(m), lam=1e-3)
        xe_t = jax.ShapeDtypeStruct((n_eval, d), jnp.float32)
        try:
            cost = cost_for(lambda xe: nystrom.predict_streaming(
                kern, fitz, xe, tile=cfg.tile,
                precision=cfg.precision), xe_t)
            out["predict"] = analysis.achieved_throughput(
                cost, seconds["predict"])
        except Exception:
            pass
    return {k: {kk: round(vv, 3) for kk, vv in v.items()}
            for k, v in out.items()}


def _stage_subset(cfg: PipelineConfig, names: list[str]):
    """Evaluate stage list truncated after the last requested stage (earlier
    stages still run — later ones need their artifacts)."""
    stages = evaluate_stages(cfg)
    known = {s.name for s in stages}
    unknown = sorted(set(names) - known)
    if unknown:
        raise SystemExit(f"unknown stage(s) {unknown}; "
                         f"pick from {sorted(known)}")
    last = max(i for i, s in enumerate(stages) if s.name in names)
    return stages[:last + 1]


def bench_one(n: int, tile: int, m: int | None, seed: int = 0,
              stages: list[str] | None = None) -> dict:
    data = krr_data.bimodal(jax.random.PRNGKey(seed), n, d=3)
    cfg = PipelineConfig(nu=1.5, tile=tile, num_landmarks=m)
    stage_list = _stage_subset(cfg, stages) if stages else None
    n_eval = min(n, 50_000)
    pipe = SAKRRPipeline(cfg, stages=stage_list)
    # a subset stopping before the score stays a plain fit fold ("stops
    # there" — evaluate() would force-append the missing ScoreStage);
    # subsets reaching score run the evaluate fold with the synthetic truth
    # wired into the score stage
    fit_only = stage_list is not None and not any(
        s.name == "score" for s in stage_list)
    t0 = time.perf_counter()
    if fit_only:
        pipe.fit(data.x, data.y)
    else:
        pipe.evaluate(data.x, data.y, x_eval=data.x[:n_eval],
                      y_eval=data.y[:n_eval], f_star=data.f_star[:n_eval])
    total_s = time.perf_counter() - t0
    m_used = pipe.state.num_landmarks
    fit_s = sum(v for k, v in pipe.seconds.items()
                if k not in ("predict", "score"))
    rec = {
        "section": "pipeline",
        "n": n,
        "m": m_used,
        "tile": tile,
        "fit_seconds": round(fit_s, 4),
        "total_seconds": round(total_s, 4),
        "stage_seconds": {k: round(v, 4) for k, v in pipe.seconds.items()},
        "peak_rss_mb": round(_peak_rss_mb(), 1),
    }
    if pipe.state.fit is not None:   # solve ran: throughput + slab footprint
        rec["rows_per_second"] = round(n / max(fit_s, 1e-9))
        # memory story: the streaming slab is the largest transient buffer
        rec["slab_mb"] = round(tile * m_used * 4 / 2**20, 2)
    if pipe.state.scores:            # evaluate fold reached the score stage
        rec["predict_n"] = n_eval
        rec["risk"] = pipe.state.scores.get("risk")
        rec["rmse"] = pipe.state.scores.get("rmse")
        rec["d_stat"] = float(pipe.d_stat)
    rec["stage_throughput"] = _stage_throughputs(
        cfg, n, m_used, data.x.shape[1], n_eval, pipe.seconds)
    print(",".join(f"{k}={v}" for k, v in rec.items() if k != "stage_seconds"))
    print("  stages: " + ",".join(f"{k}={v}" for k, v in
                                  rec["stage_seconds"].items()))
    return rec


# ----------------------------------------------------------------- autotune --

def autotune_bench(n: int = 262_144, seed: int = 0,
                   json_path: str | None = None) -> list[dict]:
    """Hand-picked tiles vs the autotuner at one n (section
    `pipeline_autotune`).

    Clears the plan cache, measures the three streamed ops' plans cold
    (recording the chosen tile/bm/bn and the tuning wall-clock), re-resolves
    them warm (must be a pure cache hit), then times a jit-warmed
    `SAKRRPipeline.fit` at each fixed tile and once with ``tile=None``.
    The acceptance bar compares the autotuned fit against the hand-picked
    tile rows standing in BENCH_pipeline.json (the section="pipeline" sweep
    rows, recorded on the pre-autotuner code path): autotuned <= the best
    hand-picked row and >= 1.2x faster than the tile=16384 default row.
    The same-run warm fixed-tile rows are recorded alongside
    (warm_speedup_*): they isolate what tile choice + the compiled-plan
    cache buy with every jit cache already hot.
    """
    from repro import tuning
    from repro.core import kde as core_kde
    from repro.kernels import dispatch

    data = krr_data.bimodal(jax.random.PRNGKey(seed), n, d=3)
    base = PipelineConfig(nu=1.5)
    m = base.resolve_num_landmarks(n)
    g = base.kde_grid_size or core_kde.default_grid_size(3)

    tuning.clear_cache()
    shapes = {"gram": m, "deposit": g, "predict": m}
    plans = {}
    t0 = time.perf_counter()
    for op, mm in shapes.items():
        plans[op] = tuning.plan_for(op, n, mm, 3, measure=True)
    tuning_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for op, mm in shapes.items():
        warm = tuning.plan_for(op, n, mm, 3, measure=True)
        assert warm.source == "cache", (op, warm)
    warm_tuning_s = time.perf_counter() - t0

    # Round-robin best-of-reps after a jit warm: single fits at this n are
    # noisy enough (~10-15%) to swamp the few-percent spread between
    # neighbouring tiles, and sequential per-config timing folds machine
    # load drift into the comparison — interleaving decorrelates it.
    tiles = [4_096, 16_384, 65_536, None]
    reps = 5
    pipes = {t: SAKRRPipeline(PipelineConfig(nu=1.5, tile=t))
             for t in tiles}
    for t in tiles:
        pipes[t].fit(data.x, data.y)                  # jit warm, untimed
    best = {t: (float("inf"), None) for t in tiles}
    for _ in range(reps):
        for t in tiles:
            pipe = SAKRRPipeline(PipelineConfig(nu=1.5, tile=t))
            pipe.fit(data.x, data.y)
            fit_s = sum(pipe.seconds.values())
            if fit_s < best[t][0]:
                best[t] = (fit_s, pipe)

    records = []
    print("tile,fit_seconds,solve_seconds")
    for t in tiles[:-1]:
        fit_s, pipe = best[t]
        records.append({"section": "pipeline_autotune", "n": n, "m": m,
                        "tile": t, "fit_seconds": round(fit_s, 4),
                        "stage_seconds": {k: round(v, 4)
                                          for k, v in pipe.seconds.items()}})
        print(f"{t},{fit_s:.3f},{pipe.seconds.get('solve', 0.0):.3f}")
    auto_s, pipe = best[None]
    warm_default_s = best[16_384][0]
    warm_best_fixed = min(best[t][0] for t in tiles[:-1])

    # acceptance basis: the standing hand-picked rows (section "pipeline",
    # cold single-shot protocol, pre-autotuner code path) at this n
    hand_rows = {}
    if json_path and os.path.exists(json_path):
        with open(json_path) as f:
            for r in json.load(f):
                if (r.get("section") == "pipeline" and r.get("n") == n
                        and isinstance(r.get("tile"), int)
                        and "fit_seconds" in r):
                    t = r["tile"]
                    hand_rows[t] = min(hand_rows.get(t, float("inf")),
                                       r["fit_seconds"])
    default_s = hand_rows.get(16_384, warm_default_s)
    best_fixed = min(hand_rows.values()) if hand_rows else warm_best_fixed

    rec = {"section": "pipeline_autotune", "n": n, "m": m, "tile": "auto",
           "fit_seconds": round(auto_s, 4),
           "stage_seconds": {k: round(v, 4) for k, v in pipe.seconds.items()},
           "plans": {op: p.to_dict() for op, p in plans.items()},
           "tuning_seconds": round(tuning_s, 4),
           "warm_tuning_seconds": round(warm_tuning_s, 4),
           "hand_picked_rows": hand_rows or None,
           "speedup_vs_default": round(default_s / max(auto_s, 1e-9), 2),
           "speedup_vs_best_fixed": round(best_fixed / max(auto_s, 1e-9), 2),
           "warm_speedup_vs_default": round(
               warm_default_s / max(auto_s, 1e-9), 2),
           "warm_speedup_vs_best_fixed": round(
               warm_best_fixed / max(auto_s, 1e-9), 2)}
    records.append(rec)
    print(f"auto,{auto_s:.3f},{pipe.seconds.get('solve', 0.0):.3f}  "
          f"(plans: " + ", ".join(f"{op}={p.tile}" for op, p in plans.items())
          + f"; tuned in {tuning_s:.2f}s cold / {warm_tuning_s:.4f}s warm)")
    basis = ("BENCH_pipeline.json hand-picked rows" if hand_rows
             else "same-run warm rows (no standing rows at this n)")
    print(f"speedup vs tile=16384 default: {rec['speedup_vs_default']}x; "
          f"vs best hand-picked: {rec['speedup_vs_best_fixed']}x "
          f"[{basis}]")
    print(f"warm same-run basis: {rec['warm_speedup_vs_default']}x vs "
          f"tile=16384, {rec['warm_speedup_vs_best_fixed']}x vs best fixed")
    return records


# -------------------------------------------------------------- accumulator --

def accumulator_bench(n: int = 1_000_000, seed: int = 0) -> list[dict]:
    """plain vs compensated streaming accumulation: risk + wall-clock at one n.

    The ROADMAP's fp32 scale ceiling in numbers: the same evaluate fold runs
    once with the historical plain fp32 Gram accumulation and once with the
    two-float compensated stream (`repro.core.streaming`), which also lowers
    `solve_normal_eq`'s spectral truncation floor (eps/32).  Records risk,
    rmse and per-stage seconds for both so BENCH_pipeline.json tracks what
    the extra ~2 VPU adds per tile buy at n = 1e6 (section
    `pipeline_accumulator`; the fast CI job smokes the compensated solve at
    n = 8192).
    """
    data = krr_data.bimodal(jax.random.PRNGKey(seed), n, d=3)
    n_eval = min(n, 50_000)
    records = []
    # warm every jit cache BOTH timed folds hit (the two accumulators trace
    # different solve HLO, so each needs its own untimed pass — otherwise
    # whichever runs first absorbs all compile time and the wall-clock
    # comparison is a compile-order artifact)
    for acc in ("plain", "compensated"):
        SAKRRPipeline(PipelineConfig(
            nu=1.5, tile=min(n, 16_384), accumulator=acc)).evaluate(
                data.x, data.y, x_eval=data.x[:n_eval],
                y_eval=data.y[:n_eval], f_star=data.f_star[:n_eval])
    print("accumulator,risk,rmse,solve_seconds,total_seconds")
    for acc in ("plain", "compensated"):
        cfg = PipelineConfig(nu=1.5, tile=min(n, 16_384), accumulator=acc)
        pipe = SAKRRPipeline(cfg)
        t0 = time.perf_counter()
        scores = pipe.evaluate(data.x, data.y, x_eval=data.x[:n_eval],
                               y_eval=data.y[:n_eval],
                               f_star=data.f_star[:n_eval])
        total_s = time.perf_counter() - t0
        rec = {"section": "pipeline_accumulator", "n": n,
               "m": pipe.state.num_landmarks, "accumulator": acc,
               "risk": scores.get("risk"), "rmse": scores.get("rmse"),
               "solve_seconds": round(pipe.seconds.get("solve", 0.0), 4),
               "total_seconds": round(total_s, 4),
               "stage_seconds": {k: round(v, 4)
                                 for k, v in pipe.seconds.items()}}
        records.append(rec)
        print(f"{acc},{rec['risk']:.4e},{rec['rmse']:.4e},"
              f"{rec['solve_seconds']},{rec['total_seconds']}")
    return records


# ---------------------------------------------------------------- precision --

def precision_bench(n: int = 1_000_000, seed: int = 0,
                    json_path: str | None = None) -> list[dict]:
    """fp32 vs Ozaki bf16-split Gram economics at one n (section
    `pipeline_precision`).

    Runs the evaluate fold per (precision, accumulator) config — the full
    3 x 2 matrix at n <= 262144, a reduced headline set above — with the
    `accumulator_bench` protocol (per-config jit warm, then timed).  The
    pinned-tile fp32 rows reproduce the PR 6 accumulator protocol; the
    ``precision=None, tile=None`` rows let the autotuner resolve the
    (tile, precision) pair jointly.  Autotuned rows also record the joint
    gram plans BOTH backends would run — on CPU the XLA split twin keeps
    every mode parity-testable while the recorded Pallas plan is what a
    real-TPU run would pick.  The acceptance comparison pulls the standing
    PR 6 rows (section `pipeline_accumulator`) from the trajectory file:
    the autotuned compensated row must cut solve wall-clock >= 20% at no
    worse risk.
    """
    from repro import tuning

    data = krr_data.bimodal(jax.random.PRNGKey(seed), n, d=3)
    n_eval = min(n, 50_000)
    base = PipelineConfig(nu=1.5)
    m = base.resolve_num_landmarks(n)
    d = data.x.shape[1]
    plans = {}
    for b in ("xla", "pallas"):
        plans[b] = tuning.plan_for("gram", n, m, d, backend=b,
                                   accumulator="compensated", precision=None,
                                   measure=(b == "xla")).to_dict()
    pinned = min(n, 16_384)
    if n <= 262_144:
        combos = [(p, a, pinned) for p in ("fp32", "bf16x2", "bf16x3")
                  for a in ("plain", "compensated")]
        combos.append((None, "compensated", None))
    else:
        combos = [("fp32", "plain", pinned), ("fp32", "compensated", pinned),
                  (None, "compensated", None), ("bf16x3", "compensated", None)]

    def run_one(p, a, t):
        cfg = PipelineConfig(nu=1.5, tile=t, precision=p, accumulator=a)
        pipe = SAKRRPipeline(cfg)
        t0 = time.perf_counter()
        scores = pipe.evaluate(data.x, data.y, x_eval=data.x[:n_eval],
                               y_eval=data.y[:n_eval],
                               f_star=data.f_star[:n_eval])
        return cfg, pipe, scores, time.perf_counter() - t0

    for combo in combos:             # per-config jit warm, untimed
        run_one(*combo)
    records = []
    print("precision,accumulator,tile,risk,rmse,solve_seconds,total_seconds")
    for p, a, t in combos:
        cfg, pipe, scores, total_s = run_one(p, a, t)
        m_used = pipe.state.num_landmarks
        rec = {"section": "pipeline_precision", "n": n, "m": m_used,
               "precision": p or "auto", "accumulator": a,
               "tile": t if t is not None else "auto",
               "risk": scores.get("risk"), "rmse": scores.get("rmse"),
               "solve_seconds": round(pipe.seconds.get("solve", 0.0), 4),
               "total_seconds": round(total_s, 4),
               "stage_seconds": {k: round(v, 4)
                                 for k, v in pipe.seconds.items()},
               "stage_throughput": _stage_throughputs(
                   cfg, n, m_used, d, n_eval, pipe.seconds)}
        if t is None:
            rec["plans"] = plans
        records.append(rec)
        print(f"{rec['precision']},{a},{rec['tile']},{rec['risk']:.4e},"
              f"{rec['rmse']:.4e},{rec['solve_seconds']},"
              f"{rec['total_seconds']}")

    # acceptance basis: the latest standing PR 6 accumulator rows at this n
    baseline = {}
    if json_path and os.path.exists(json_path):
        with open(json_path) as f:
            for r in json.load(f):
                if (r.get("section") == "pipeline_accumulator"
                        and r.get("n") == n):
                    baseline[r.get("accumulator")] = r   # latest row wins
    auto = next((r for r in records if r["tile"] == "auto"
                 and r["accumulator"] == "compensated"), None)
    if auto is not None and "compensated" in baseline:
        b = baseline["compensated"]
        auto["baseline_solve_seconds"] = b["solve_seconds"]
        auto["baseline_risk"] = b["risk"]
        auto["solve_speedup_vs_baseline"] = round(
            b["solve_seconds"] / max(auto["solve_seconds"], 1e-9), 2)
        print(f"autotuned compensated solve {auto['solve_seconds']}s vs "
              f"standing baseline {b['solve_seconds']}s -> "
              f"{auto['solve_speedup_vs_baseline']}x at risk "
              f"{auto['risk']:.4e} (baseline {b['risk']:.4e})")
    print("joint gram plans: " + ", ".join(
        f"{b}=(tile={pl['tile']}, bm={pl['bm']}, bn={pl['bn']}, "
        f"{pl['precision']})" for b, pl in plans.items()))
    return records


# ------------------------------------------------------------------- online --

def online_bench(n: int = 262_144, seed: int = 0) -> list[dict]:
    """Online-ingestion economics at one n (section `pipeline_online`).

    Two experiments:

    * **partial_fit vs full refit** — a fitted pipeline absorbs one
      tile-sized chunk via `partial_fit` (O(chunk · m) stream + O(m^3)
      solve, banked accumulator state) vs re-running the whole fit fold on
      n + chunk rows.  Both jit-warmed, best-of-reps; the acceptance bar
      is >= 5x at n = 262144 (the fast CI job smokes the same protocol at
      n = 8192 without a bar — the n/chunk ratio is what buys the gap).
    * **drift tracking** — a stationary and a shifting stream are fed
      chunk-by-chunk to three policies: FROZEN (never update), DECAYED
      (`partial_fit` with exponential forgetting — fixed landmark set),
      and SQUEAK (`OnlineLandmarks` add/drop + weighted coreset refit).
      The shifting stream drifts BOTH the covariates (bimodal mode offset
      2.0 -> 4.0) and the concept (target amplitude 1x -> 2x): pure
      covariate shift with a fixed target leaves old data valid, so
      forgetting buys nothing — amplitude drift is what makes stale rows
      actively wrong and forgetting necessary.  Final risk is scored on a
      fresh eval set from the LAST chunk's distribution: under shift the
      adaptive policies must beat frozen, and SQUEAK's relocated
      dictionary should beat decay-on-stale-landmarks.
    """
    from repro.pipeline import online as online_mod

    tile = min(n, 16_384)
    chunk = tile
    data = krr_data.bimodal(jax.random.PRNGKey(seed), n, d=3)
    stream = krr_data.bimodal(jax.random.PRNGKey(seed + 1), 4 * chunk, d=3)
    cfg = PipelineConfig(nu=1.5, tile=tile)
    records = []

    # --- partial_fit vs full refit -------------------------------------
    pipe = SAKRRPipeline(cfg).fit(data.x, data.y)
    m = pipe.state.num_landmarks
    pipe.partial_fit(stream.x[:chunk], stream.y[:chunk])   # jit warm
    reps = 3
    pf_s = float("inf")
    for r in range(1, reps + 1):
        lo = r * chunk
        t0 = time.perf_counter()
        pipe.partial_fit(stream.x[lo:lo + chunk], stream.y[lo:lo + chunk])
        pf_s = min(pf_s, time.perf_counter() - t0)

    import jax.numpy as jnp
    x_full = jnp.concatenate([data.x, stream.x[:chunk]])
    y_full = jnp.concatenate([data.y, stream.y[:chunk]])
    SAKRRPipeline(cfg).fit(x_full, y_full)                 # jit warm
    refit_s = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        SAKRRPipeline(cfg).fit(x_full, y_full)
        refit_s = min(refit_s, time.perf_counter() - t0)
    speedup = refit_s / max(pf_s, 1e-9)
    rec = {"section": "pipeline_online", "experiment": "partial_fit",
           "n": n, "m": m, "chunk": chunk,
           "partial_fit_seconds": round(pf_s, 4),
           "full_refit_seconds": round(refit_s, 4),
           "speedup": round(speedup, 2)}
    records.append(rec)
    print(f"partial_fit {pf_s:.4f}s vs full refit {refit_s:.4f}s at "
          f"n={n}, chunk={chunk} -> {speedup:.1f}x")

    # --- drift tracking: frozen vs decayed vs SQUEAK -------------------
    n0 = min(n, 16_384)
    csize, t_chunks, gamma = 4_096, 6, 0.6
    cfg0 = PipelineConfig(nu=1.5, tile=min(n0, 16_384))
    for scenario in ("stationary", "shifting"):
        drift = 0.0 if scenario == "stationary" else 1.0
        offs = [2.0 + drift * 2.0 * (t + 1) / t_chunks
                for t in range(t_chunks)]
        scales = [1.0 + drift * (t + 1) / t_chunks
                  for t in range(t_chunks)]
        base = krr_data.bimodal(jax.random.PRNGKey(seed + 2), n0, d=3)
        frozen = SAKRRPipeline(cfg0).fit(base.x, base.y)
        decayed = SAKRRPipeline(cfg0).fit(base.x, base.y)
        squeak = online_mod.seed_landmarks(decayed, oversample=3.0)
        for t, (off, sc) in enumerate(zip(offs, scales)):
            ch = krr_data.bimodal(jax.random.PRNGKey(seed + 10 + t),
                                  csize, d=3, offset=off)
            decayed.partial_fit(ch.x, ch.y * sc, decay=gamma)
            squeak.update(ch.x, ch.y * sc)
        sq_fit = squeak.refit()
        ev = krr_data.bimodal(jax.random.PRNGKey(seed + 99), 8_192, d=3,
                              offset=offs[-1])
        truth = ev.f_star * scales[-1]
        risks = {
            "frozen": float(krr.in_sample_risk(frozen.predict(ev.x),
                                               truth)),
            "decayed": float(krr.in_sample_risk(decayed.predict(ev.x),
                                                truth)),
            "squeak": float(krr.in_sample_risk(nystrom.predict_streaming(
                frozen.kernel, sq_fit, ev.x, tile=cfg0.tile), truth)),
        }
        rec = {"section": "pipeline_online", "experiment": "drift",
               "scenario": scenario, "n0": n0, "chunk": csize,
               "chunks": t_chunks, "decay": gamma,
               "squeak_dict_size": len(squeak),
               "squeak_changes": squeak.changes,
               "risk": {k: round(v, 6) for k, v in risks.items()}}
        records.append(rec)
        print(f"drift[{scenario}]: risk frozen={risks['frozen']:.4e} "
              f"decayed={risks['decayed']:.4e} "
              f"squeak={risks['squeak']:.4e} "
              f"(|D|={len(squeak)}, changes={squeak.changes})")
    return records


# ---------------------------------------------------------------- calibrate --

def calibrate_bench(n: int = 16_384, seed: int = 0) -> list[dict]:
    """Sweep-vs-naive economics of the CalibrateStage fold at one n.

    Times the shared-Gram multi-lam path (`nystrom.fit_streaming_multi`:
    one row-stream accumulation + L whitened solves + one multi-beta
    predict) against the naive per-lam refit loop (L independent
    `fit_streaming` + `predict_streaming` calls) on the SAME landmark set
    and lam grid, then runs the full `SAKRRPipeline.calibrate` fold and
    compares the winning candidate's risk against the paper-rate default.
    Both timed paths are jit-warmed first; the speedup row is the headline
    number (>= 3x at n=16k is the acceptance bar — the Gram accumulation
    dominates and is paid once instead of L times).
    """
    from repro.pipeline.stages import DEFAULT_LAM_FACTORS

    data = krr_data.bimodal(jax.random.PRNGKey(seed), n, d=3)
    cfg = PipelineConfig(nu=1.5)
    kern = cfg.build_kernel()
    lam0 = cfg.resolve_lam(n)
    m = cfg.resolve_num_landmarks(n)
    lam_grid = [f * lam0 for f in DEFAULT_LAM_FACTORS]
    n_val = int(cfg.calibrate_val_fraction * n)
    x_tr, y_tr = data.x[n_val:], data.y[n_val:]
    x_val = data.x[:n_val]
    key = jax.random.PRNGKey(seed + 1)
    idx, _ = sampling.sample_weighted_without_replacement(
        key, rls.uniform(n - n_val).probs, m)

    # warm every jit cache both timed regions hit (same shapes)
    warm = nystrom.fit_streaming_multi(kern, x_tr, y_tr, lam_grid, idx,
                                       tile=cfg.tile)
    jax.block_until_ready(nystrom.predict_streaming_multi(
        kern, warm, x_val, tile=cfg.tile))
    w1 = nystrom.fit_streaming(kern, x_tr, y_tr, lam_grid[0], idx,
                               tile=cfg.tile)
    jax.block_until_ready(nystrom.predict_streaming(kern, w1, x_val,
                                                    tile=cfg.tile))

    t0 = time.perf_counter()
    fits = nystrom.fit_streaming_multi(kern, x_tr, y_tr, lam_grid, idx,
                                       tile=cfg.tile)
    preds = nystrom.predict_streaming_multi(kern, fits, x_val, tile=cfg.tile)
    jax.block_until_ready(preds)
    sweep_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    for lam in lam_grid:
        f = nystrom.fit_streaming(kern, x_tr, y_tr, lam, idx, tile=cfg.tile)
        jax.block_until_ready(nystrom.predict_streaming(kern, f, x_val,
                                                        tile=cfg.tile))
    naive_s = time.perf_counter() - t0
    speedup = naive_s / max(sweep_s, 1e-9)

    # full calibrate fold (lam x h grid) vs the paper-rate default
    n_eval = min(n, 50_000)
    pipe = SAKRRPipeline(cfg)
    t0 = time.perf_counter()
    cal = pipe.calibrate(data.x, data.y, x_eval=data.x[:n_eval],
                         y_eval=data.y[:n_eval],
                         f_star=data.f_star[:n_eval])
    cal_s = time.perf_counter() - t0
    ref = SAKRRPipeline(cfg).evaluate(
        data.x, data.y, x_eval=data.x[:n_eval], y_eval=data.y[:n_eval],
        f_star=data.f_star[:n_eval])
    rec = {
        "section": "pipeline_calibrate", "n": n, "m": m,
        "lam_grid": [float(l) for l in lam_grid],
        "sweep_seconds": round(sweep_s, 4),
        "naive_refit_seconds": round(naive_s, 4),
        "sweep_speedup": round(speedup, 2),
        "best_lam": cal["lam"], "best_h": cal["bandwidth"],
        "cv_candidates": len(cal["cv_scores"]),
        "calibrate_seconds": round(cal_s, 4),
        "risk_calibrated": cal["scores"].get("risk"),
        "risk_paper_rate": ref.get("risk"),
    }
    print(f"lam sweep (L={len(lam_grid)}): shared-Gram {sweep_s:.3f}s vs "
          f"naive refits {naive_s:.3f}s -> {speedup:.1f}x")
    print(f"calibrated (lam={cal['lam']:.3e}, h={cal['bandwidth']:.3g}) "
          f"risk {rec['risk_calibrated']:.3e} vs paper-rate "
          f"{rec['risk_paper_rate']:.3e}")
    return [rec]


# --------------------------------------------------------------- multimodel --

def _mesh2d_calibrate_record(n: int) -> dict:
    """Forced-4-device subprocess: the calibrate sweep's per-h KDE and
    multi-lam solve under a (2, 2) (data, model) mesh vs the 1D replicated
    baseline — wall-clock for both plus the per-h bit-equality flag (the
    2D path must match the 1D data-mesh path with the same data-shard
    count exactly).  A subprocess because jax pins the host device count
    at backend init."""
    import subprocess
    import sys
    import textwrap
    body = f"""
        import json, time
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import distributed as dist, nystrom
        from repro.core.kernels import Gaussian, kernel_matrix
        from repro.distributed import sharding as shd
        from repro.launch import mesh as mesh_lib

        n = {int(n)}
        x = jax.random.normal(jax.random.PRNGKey(0), (n, 3), jnp.float32)
        hs = [0.15, 0.25, 0.4, 0.65]
        lam_grid = [1e-5, 1e-4, 1e-3, 1e-2]
        kern = Gaussian(1.0)
        mesh1_2 = jax.make_mesh((2,), ("data",), devices=jax.devices()[:2])
        mesh1_4 = jax.make_mesh((4,), ("data",))
        mesh2 = mesh_lib.make_local_mesh_2d(model_parallelism=2)

        def kde_sweep():
            return jax.block_until_ready(
                dist.kde_binned_sharded_multi(x, hs, grid_size=64))

        idx = jax.random.choice(jax.random.PRNGKey(1), n, (64,),
                                replace=False)
        xm = x[idx]
        k_nm = kernel_matrix(kern, x, xm)
        g = (k_nm.T @ k_nm).astype(jnp.float32)
        rhs = k_nm.T @ x[:, 0]
        k_mm = kernel_matrix(kern, xm)

        def solve_sweep():
            return jax.block_until_ready(
                nystrom.solve_normal_eq_multi(g, rhs, k_mm, n, lam_grid))

        def timed(mesh):
            with mesh, shd.activate(mesh):
                kde_sweep(); solve_sweep()          # jit warm
                t0 = time.perf_counter(); kde_sweep()
                kde_s = time.perf_counter() - t0
                t0 = time.perf_counter(); solve_sweep()
                solve_s = time.perf_counter() - t0
                return kde_s, solve_s

        # bit parity: identical data-shard count (2) on both sides
        with mesh1_2, shd.activate(mesh1_2):
            kde_ref, solve_ref = np.asarray(kde_sweep()), \\
                np.asarray(solve_sweep())
        with mesh2, shd.activate(mesh2):
            kde_2d, solve_2d = np.asarray(kde_sweep()), \\
                np.asarray(solve_sweep())
        kde1_s, solve1_s = timed(mesh1_4)   # 1D: per-h work replicated
        kde2_s, solve2_s = timed(mesh2)     # 2D: per-h work model-sharded
        print("MM2D " + json.dumps({{
            "per_h_bit_equal": bool((kde_ref == kde_2d).all()),
            "per_lam_bit_equal": bool((solve_ref == solve_2d).all()),
            "kde_sweep_seconds_1d": round(kde1_s, 4),
            "kde_sweep_seconds_2d": round(kde2_s, 4),
            "solve_sweep_seconds_1d": round(solve1_s, 4),
            "solve_sweep_seconds_2d": round(solve2_s, 4)}}))
    """
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(os.path.dirname(
                       os.path.abspath(__file__))), "src")]
                   + ([os.environ["PYTHONPATH"]]
                      if "PYTHONPATH" in os.environ else [])))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    if out.returncode != 0:
        raise RuntimeError(f"mesh2d calibrate bench failed:\n"
                           f"{out.stderr[-3000:]}")
    line = next(ln for ln in out.stdout.splitlines() if ln.startswith("MM2D"))
    rec = json.loads(line[len("MM2D "):])
    rec.update(section="pipeline_multimodel", kind="calibrate_mesh2d",
               n=int(n), num_h=4, num_lams=4, devices="2x2 forced host")
    return rec


def multimodel_bench(n: int = 16_384, seed: int = 0) -> list[dict]:
    """Many-model batched fit economics + 2D-mesh calibrate sweep numbers.

    For B in {16, 256} tenant models (shared x, per-model y/lam/landmark
    set): wall-clock of ONE `nystrom.fit_streaming_batched` pass vs the
    sequential per-model `fit_streaming` python loop (both jit-warmed at
    their autotuned tiles, best-of-3 wall-clock), with per-model parity at
    a matched explicit tile — the batched path must be a pure
    reorganization of the same arithmetic, just without paying the row
    stream B times.  The B=256 speedup is the acceptance headline (>= 5x).

    Parity is reported at three levels because the raw coefficient vector
    is NOT determined to fp32 reduction-order precision at this
    conditioning: the whitened solve amplifies one-ulp Gram differences
    ~1e6-fold (measured: g matches to ~1e-7 rel between the two paths, yet
    betas move ~1e-1 — and the LOOP PATH AGAINST ITSELF at two tile sizes
    moves ~1e-2, the recorded `loop_self_beta_rel_err` yardstick).  So the
    record carries (a) `beta_max_rel_err` with that same-arithmetic
    yardstick next to it, (b) `pred_max_rel_err` — function-space parity,
    which IS well-determined — and (c) `val_mse_max_rel_err` per-model
    risk parity.  A forced-4-device subprocess then records the calibrate
    sweep's (2, 2)-mesh timing and per-h/per-lam bit-equality vs the 1D
    path (`_mesh2d_calibrate_record`).
    """
    import jax.numpy as jnp
    import numpy as np

    data = krr_data.bimodal(jax.random.PRNGKey(seed), n, d=3)
    cfg = PipelineConfig(nu=1.5)
    kern = cfg.build_kernel()
    lam0 = cfg.resolve_lam(n)
    m = 16                              # small per-tenant models: the
    # batched win is per-model dispatch amortization, so the target regime
    # is many tiny tenant fits (m=16 landmarks) over one shared row stream
    n_val = 2_048
    tile = 2_048                        # matched tile: same scan-step count
    x_tr, x_val = data.x[n_val:], data.x[:n_val]
    n_tr = n - n_val
    rng = np.random.default_rng(seed)
    records = []
    for big in (16, 256):
        # per-tenant targets: shared signal, per-model scale + noise
        scales = jnp.asarray(rng.uniform(0.5, 2.0, size=(big, 1)),
                             jnp.float32)
        noise = jnp.asarray(rng.normal(scale=0.1, size=(big, n_tr)),
                            jnp.float32)
        ys = scales * data.y[n_val:][None, :] + noise
        ys_val = scales * data.y[:n_val][None, :]
        lams = jnp.asarray(rng.uniform(0.5, 2.0, size=(big,)) * lam0,
                           jnp.float32)
        lsets = jnp.asarray(
            np.stack([rng.choice(n_tr, size=m, replace=False)
                      for _ in range(big)]))

        # timing: BOTH paths at their autotuned best (tile=None) — the
        # production comparison a tenant-serving deployment would make.
        # Best-of-3 per path: single-shot wall-clock on a shared CPU host
        # swings ~20% run to run, and min-of-repeats is the standard way to
        # strip scheduler noise from a throughput comparison.
        jax.block_until_ready(nystrom.fit_streaming_batched(
            kern, x_tr, ys, lams, lsets).beta)                 # jit warm
        jax.block_until_ready(nystrom.fit_streaming(
            kern, x_tr, ys[0], float(lams[0]), lsets[0]).beta)  # jit warm
        batched_s = float("inf")
        loop_s = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(nystrom.fit_streaming_batched(
                kern, x_tr, ys, lams, lsets).beta)
            batched_s = min(batched_s, time.perf_counter() - t0)
            t0 = time.perf_counter()
            for b in range(big):
                jax.block_until_ready(nystrom.fit_streaming(
                    kern, x_tr, ys[b], float(lams[b]), lsets[b]).beta)
            loop_s = min(loop_s, time.perf_counter() - t0)
        speedup = loop_s / max(batched_s, 1e-9)

        # parity: matched explicit tile so both paths run the same
        # scan-step count (see docstring for the three levels)
        fit_b = nystrom.fit_streaming_batched(kern, x_tr, ys, lams, lsets,
                                              tile=tile)
        fits = [nystrom.fit_streaming(kern, x_tr, ys[b], float(lams[b]),
                                      lsets[b], tile=tile)
                for b in range(big)]
        beta_err = max(
            float(jnp.max(jnp.abs(fits[b].beta - fit_b.beta[b])) /
                  (jnp.max(jnp.abs(fits[b].beta)) + 1e-30))
            for b in range(big))
        self_fit = nystrom.fit_streaming(kern, x_tr, ys[0], float(lams[0]),
                                         lsets[0], tile=tile // 2)
        self_err = float(
            jnp.max(jnp.abs(self_fit.beta - fits[0].beta)) /
            (jnp.max(jnp.abs(fits[0].beta)) + 1e-30))
        preds_b = nystrom.predict_streaming_batched(kern, fit_b, x_val,
                                                    tile=tile)
        preds_l = jnp.stack([
            nystrom.predict_streaming(kern, fits[b], x_val, tile=tile)
            for b in range(big)])
        pred_err = float(jnp.max(jnp.abs(preds_b - preds_l)) /
                         jnp.max(jnp.abs(preds_l)))
        mse_b = np.asarray(jnp.mean((preds_b - ys_val) ** 2, axis=1))
        mse_l = np.asarray(jnp.mean((preds_l - ys_val) ** 2, axis=1))
        mse_err = float(np.max(np.abs(mse_b - mse_l) /
                               np.maximum(mse_l, 1e-30)))
        rec = {
            "section": "pipeline_multimodel", "kind": "batched_fit",
            "n": n_tr, "num_models": big, "m": m, "tile": tile,
            "batched_fit_seconds": round(batched_s, 4),
            "loop_fit_seconds": round(loop_s, 4),
            "batched_speedup": round(speedup, 2),
            "beta_max_rel_err": beta_err,
            "loop_self_beta_rel_err": self_err,
            "pred_max_rel_err": pred_err,
            "val_mse_max_rel_err": mse_err,
        }
        records.append(rec)
        print(f"B={big:4d} models (n={n_tr}, m={m}): batched "
              f"{batched_s:.3f}s vs loop {loop_s:.3f}s -> {speedup:.1f}x "
              f"(beta {beta_err:.1e} vs self-yardstick {self_err:.1e}, "
              f"pred {pred_err:.1e}, val-mse {mse_err:.1e})")

    rec2d = _mesh2d_calibrate_record(min(n, 8_192))
    records.append(rec2d)
    print(f"2D-mesh calibrate sweep (n={rec2d['n']}): per-h bit-equal "
          f"{rec2d['per_h_bit_equal']}, per-lam bit-equal "
          f"{rec2d['per_lam_bit_equal']}; kde {rec2d['kde_sweep_seconds_1d']}"
          f"s (1D) vs {rec2d['kde_sweep_seconds_2d']}s (2x2), solve "
          f"{rec2d['solve_sweep_seconds_1d']}s vs "
          f"{rec2d['solve_sweep_seconds_2d']}s")
    return records


# ------------------------------------------------------------------ compare --

def compare_methods(n: int = 16_384, m: int | None = None,
                    seed: int = 0) -> list[dict]:
    """SA vs uniform vs Recursive-RLS vs BLESS at one n (paper §4.1 / Fig 1).

    Every method's probs are sampled WITHOUT replacement (Gumbel top-k) with
    inverse-inclusion weights; the weights feed both the weighted SoR solve
    and the weighted projection-leverage estimator (`rls.from_sketch`), whose
    statistical-dimension estimate is reported as `d_proj` — so the recorded
    importance weights are load-bearing for every row of the table.
    """
    data = krr_data.bimodal(jax.random.PRNGKey(seed), n, d=3)
    cfg = PipelineConfig(nu=1.5, num_landmarks=m)
    kern = cfg.build_kernel()
    lam = cfg.resolve_lam(n)
    m_used = cfg.resolve_num_landmarks(n)
    n_eval = min(n, 50_000)
    key = jax.random.PRNGKey(seed + 1)

    def probs_for(method: str):
        from repro.pipeline import (DensityStage, LeverageStage, StageContext,
                                    run_stages)
        t0 = time.perf_counter()
        if method == "sa":
            ctx = StageContext(config=cfg, kernel=kern, x=data.x, y=data.y,
                               n=n, d=data.x.shape[1], lam=lam,
                               num_landmarks=m_used)
            run_stages([DensityStage(), LeverageStage()], ctx)
            jax.block_until_ready(ctx.leverage.probs)
            return ctx.leverage.probs, time.perf_counter() - t0
        if method == "uniform":
            return rls.uniform(n).probs, time.perf_counter() - t0
        if method == "rc":
            r = rls.recursive_rls(kern, data.x, lam, seed=seed)
        elif method == "bless":
            r = rls.bless(kern, data.x, lam, seed=seed)
        else:
            raise ValueError(method)
        jax.block_until_ready(r.probs)
        return r.probs, time.perf_counter() - t0

    # warm up every jit cache the timed regions hit (all methods share the
    # same shapes): the KDE/leverage fold for the 'sa' row, and the
    # solve/predict pair every row runs — so no timed region absorbs
    # compilation
    warm_idx, warm_w = sampling.sample_weighted_without_replacement(
        key, rls.uniform(n).probs, m_used)
    warm = nystrom.fit_streaming(kern, data.x, data.y, lam, warm_idx,
                                 tile=cfg.tile, weights=warm_w)
    jax.block_until_ready(nystrom.predict_streaming(
        kern, warm, data.x[:n_eval], tile=cfg.tile))

    probs_for("sa")     # warm the binned-KDE + leverage jits, untimed

    records = []
    print("method,lev_seconds,solve_seconds,risk,d_proj")
    for method in ("sa", "uniform", "rc", "bless"):
        probs, lev_s = probs_for(method)
        idx, w = sampling.sample_weighted_without_replacement(
            key, probs, m_used)
        t0 = time.perf_counter()
        fit = nystrom.fit_streaming(kern, data.x, data.y, lam, idx,
                                    tile=cfg.tile, weights=w)
        pred = nystrom.predict_streaming(kern, fit, data.x[:n_eval],
                                         tile=cfg.tile)
        jax.block_until_ready(pred)
        solve_s = time.perf_counter() - t0
        risk = float(krr.in_sample_risk(pred, data.f_star[:n_eval]))
        # weighted projection estimate from the same sketch: d_proj is the
        # statistical dimension it implies (weights demonstrably consumed)
        proj = rls.from_sketch(kern, data.x, lam, idx, weights=w)
        d_proj = float(proj.leverage.sum())
        rec = {"section": "pipeline_compare", "n": n, "m": m_used,
               "method": method, "lev_seconds": round(lev_s, 4),
               "solve_seconds": round(solve_s, 4), "risk": risk,
               "d_proj": round(d_proj, 2)}
        records.append(rec)
        print(f"{method},{lev_s:.3f},{solve_s:.3f},{risk:.3e},{d_proj:.1f}")
    return records


def main(json_out: str | None = "BENCH_pipeline.json",
         n_max: int = 262_144, n_only: int | None = None,
         stages: list[str] | None = None, compare: bool = False,
         calibrate: bool = False, accumulator: bool = False,
         autotune: bool = False, precision: bool = False,
         online: bool = False, multimodel: bool = False) -> None:
    if multimodel:
        print("\n## pipeline multimodel (batched many-tenant fits + 2D mesh)")
        records = multimodel_bench(n=n_only or 16_384)
    elif online:
        print("\n## pipeline online (partial_fit vs refit + drift tracking)")
        records = online_bench(n=n_only or 262_144)
    elif precision:
        print("\n## pipeline precision (fp32 vs Ozaki bf16-split Gram)")
        records = precision_bench(n=n_only or 1_000_000, json_path=json_out)
    elif autotune:
        print("\n## pipeline autotune (fixed tiles vs roofline autotuner)")
        records = autotune_bench(n=n_only or 262_144, json_path=json_out)
    elif accumulator:
        print("\n## pipeline accumulator (plain vs compensated two-float)")
        records = accumulator_bench(n=n_only or 1_000_000)
    elif calibrate:
        print("\n## pipeline calibrate (shared-Gram sweep vs naive refits)")
        records = calibrate_bench(n=n_only or 16_384)
    elif compare:
        print("\n## pipeline compare (SA vs uniform vs RC vs BLESS)")
        records = compare_methods(n=n_only or 16_384)
    else:
        print("\n## pipeline (streaming SA->Nystrom)")
        records = []
        if n_only is not None or stages:
            n = n_only or 16_384
            records.append(bench_one(n, tile=min(n, 16_384), m=None,
                                     stages=stages))
        else:
            n = 16_384
            while n <= n_max:
                records.append(bench_one(n, tile=16_384, m=None))
                n *= 4
            # tile sweep at the top size: time/memory trade of the slab
            for tile in (4_096, 65_536):
                records.append(bench_one(n_max, tile=tile, m=None))
    if json_out:
        append_records(json_out, records)
        print(f"[appended {len(records)} records to {json_out}]")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-max", type=int, default=262_144)
    ap.add_argument("--n", type=int, default=None,
                    help="single-point run at this n (no sweep)")
    ap.add_argument("--stages", default=None,
                    help="comma-separated stage subset, e.g. 'kde' or "
                         "'kde,leverage' or 'score' (runs prerequisites, "
                         "stops there)")
    ap.add_argument("--compare", action="store_true",
                    help="SA vs uniform vs recursive-RLS vs BLESS risk/time "
                         "table (weighted projection estimator)")
    ap.add_argument("--calibrate", action="store_true",
                    help="CalibrateStage sweep economics: shared-Gram "
                         "multi-lam sweep vs naive per-lam refits, plus the "
                         "full (lam, h) calibrate fold vs paper-rate risk")
    ap.add_argument("--accumulator", action="store_true",
                    help="plain vs compensated (two-float) streaming "
                         "accumulation: risk and wall-clock at n "
                         "(default 1e6)")
    ap.add_argument("--autotune", action="store_true",
                    help="fixed tiles vs the roofline autotuner "
                         "(repro.tuning): clears the plan cache, measures "
                         "cold, checks the warm cache hit, records the "
                         "chosen plans (default n=262144)")
    ap.add_argument("--precision", action="store_true",
                    help="fp32 vs Ozaki bf16-split Gram precision modes x "
                         "plain/compensated accumulation, with joint "
                         "(tile, precision) autotuned rows and both "
                         "backends' resolved plans (default n=1e6)")
    ap.add_argument("--online", action="store_true",
                    help="online ingestion: partial_fit-per-chunk vs full "
                         "refit wall-clock, plus frozen vs decayed vs "
                         "SQUEAK drift tracking on stationary and shifting "
                         "streams (default n=262144)")
    ap.add_argument("--multimodel", action="store_true",
                    help="many-model batched KRR: fit_streaming_batched vs "
                         "the per-model python loop at B in {16, 256} "
                         "(wall-clock + per-model parity), plus the "
                         "forced-4-device 2D-mesh calibrate sweep timing "
                         "and bit-equality record")
    ap.add_argument("--json", default="BENCH_pipeline.json")
    args = ap.parse_args()
    main(json_out=args.json or None, n_max=args.n_max, n_only=args.n,
         stages=args.stages.split(",") if args.stages else None,
         compare=args.compare, calibrate=args.calibrate,
         accumulator=args.accumulator, autotune=args.autotune,
         precision=args.precision, online=args.online,
         multimodel=args.multimodel)
