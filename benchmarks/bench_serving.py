"""Serving benchmark: online predict latency + sustained throughput.

Records to ``BENCH_serving.json`` (appended trajectory, like the other
BENCH_* files):

  * warm sequential single-row latency (p50 / p99) — the engine's round-trip
    floor with a hot jit cache
  * sustained rows/sec under >= 4 producer threads, each keeping a sliding
    window of requests in flight (real queue depth, so the engine actually
    microbatches), with per-request p50 / p99 under load
  * the one-request-at-a-time throughput baseline and the microbatch
    speedup over it
  * engine counters: batches, distinct jit compiles, bucket occupancy

  PYTHONPATH=src python -m benchmarks.bench_serving                # full
  PYTHONPATH=src python -m benchmarks.bench_serving --n-requests 256  # smoke

Acceptance bar (ISSUE 8): warm p50 single-row latency < 10 ms on CPU at
m=1024, microbatched sustained throughput >= 5x the sequential baseline.
"""

from __future__ import annotations

import argparse
import platform
import time

import jax

from benchmarks.bench_pipeline import append_records
from benchmarks.common import section
from repro.launch.serve import (concurrent_load, fit_and_freeze,
                                make_queries, pct, sequential_latency)
from repro.serving import ServingEngine


def bench(n: int, m: int, d: int, n_requests: int, producers: int,
          window: int, max_batch: int, seed: int) -> dict:
    t0 = time.perf_counter()
    pipe, art = fit_and_freeze(n, m, d=d, seed=seed)
    fit_s = time.perf_counter() - t0
    queries = make_queries(n_requests, d, seed + 1)
    print(f"fit n={n} m={m} d={d}: {fit_s:.2f}s")

    with ServingEngine(art, max_batch=max_batch) as eng:
        eng.warm()

        section("sequential single-row (warm baseline)")
        n_seq = min(256, n_requests)
        t0 = time.perf_counter()
        seq = sequential_latency(eng, queries[:n_seq])
        seq_wall = time.perf_counter() - t0
        seq_rows_s = n_seq / seq_wall
        print(f"p50={pct(seq, 50) * 1e3:.2f}ms p99={pct(seq, 99) * 1e3:.2f}ms "
              f"baseline={seq_rows_s:.0f} rows/s")

        section(f"concurrent x{producers} producers (window {window})")
        lats, wall = concurrent_load(eng, queries, producers=producers,
                                     window=window)
        rows_s = n_requests / wall
        speedup = rows_s / seq_rows_s
        print(f"{rows_s:.0f} rows/s ({speedup:.1f}x sequential)  "
              f"p50={pct(lats, 50) * 1e3:.2f}ms "
              f"p99={pct(lats, 99) * 1e3:.2f}ms  wall={wall:.2f}s")
        st = eng.stats
        print(f"engine: batches={st.batches} compiles={st.compiles} "
              f"occupancy={st.occupancy:.2f}")

    return {
        "section": "serving",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "host": platform.machine(),
        "n": n, "m": m, "d": d, "n_requests": n_requests,
        "producers": producers, "window": window, "max_batch": max_batch,
        "fit_seconds": round(fit_s, 3),
        "seq_p50_ms": round(pct(seq, 50) * 1e3, 3),
        "seq_p99_ms": round(pct(seq, 99) * 1e3, 3),
        "seq_rows_per_s": round(seq_rows_s, 1),
        "load_p50_ms": round(pct(lats, 50) * 1e3, 3),
        "load_p99_ms": round(pct(lats, 99) * 1e3, 3),
        "rows_per_s": round(rows_s, 1),
        "speedup_vs_sequential": round(speedup, 2),
        "batches": st.batches, "compiles": st.compiles,
        "occupancy": round(st.occupancy, 3),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=16384, help="training rows")
    ap.add_argument("--m", type=int, default=1024, help="landmarks")
    ap.add_argument("--d", type=int, default=3)
    ap.add_argument("--n-requests", type=int, default=4096,
                    help="single-row requests under concurrent load "
                         "(small values double as the CI smoke)")
    ap.add_argument("--producers", type=int, default=4)
    ap.add_argument("--window", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", type=str, default="BENCH_serving.json",
                    help='trajectory file; "" disables the append')
    args = ap.parse_args()
    if args.n_requests <= 512:      # smoke: shrink the fit, keep the engine
        args.n = min(args.n, 4096)
        args.m = min(args.m, 256)

    rec = bench(args.n, args.m, args.d, args.n_requests, args.producers,
                args.window, args.max_batch, args.seed)
    if args.json:
        append_records(args.json, [rec])
        print(f"\nappended -> {args.json}")


if __name__ == "__main__":
    main()
