"""Roofline report: aggregates results/dryrun/*.json into the per-(arch x
shape x mesh) three-term table (see EXPERIMENTS.md §Roofline)."""

from __future__ import annotations

import glob
import json
import os

from repro.roofline import analysis as roofline


def load(out_dir: str = "results/dryrun") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") == "ok":
            rows.append(rec)
    return rows


def main() -> None:
    rows = load()
    print("\n## roofline: per-cell three-term analysis (from dry-run)")
    if not rows:
        print("(no dry-run artifacts under results/dryrun — run "
              "`python -m repro.launch.dryrun --all --mesh both` first)")
        return
    print(roofline.fmt_table(rows))
    worst = min(rows, key=lambda r: r["roofline_fraction"])
    coll = max(rows, key=lambda r: r["t_collective"] /
               max(r["t_compute"] + r["t_memory"] + r["t_collective"], 1e-30))
    print(f"\nworst roofline fraction: {worst['arch']} x {worst['shape']} "
          f"[{worst['mesh']}] at {100*worst['roofline_fraction']:.1f}%")
    print(f"most collective-bound:  {coll['arch']} x {coll['shape']} "
          f"[{coll['mesh']}] t_coll={coll['t_collective']:.3e}s")


if __name__ == "__main__":
    main()
