"""Figure 1: leverage-approximation runtime vs in-sample error trade-off.

Paper setting: 3-D bimodal design (gamma=0.4), Matern nu=1.5,
lam = 0.075 n^{-2/3}, d_sub = 5 n^{1/3}; methods Vanilla / RC / BLESS / SA.
CPU-scaled: n in {2k, 8k, 24k}, 3 replicates (paper: up to 5e5, 30 reps).
Expected qualitative result (matches the paper): SA's runtime is the
smallest and grows ~linearly, while matching RC/BLESS error; Vanilla is
free but loses accuracy because the small far mode is under-sampled.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks import common
from repro.core import kernels as K
from repro.data import krr_data

NS = (2_000, 8_000, 24_000)
METHODS = ("vanilla", "rc", "bless", "sa")
REPLICATES = 3


def main() -> None:
    common.section("fig1: runtime vs error tradeoff (3-D bimodal)")
    print("method,n,lev_seconds,in_sample_error")
    kernel = K.Matern(nu=1.5)
    for n in NS:
        lam = 0.075 * n ** (-2.0 / 3.0)
        m = int(5 * n ** (1.0 / 3.0))
        for method in METHODS:
            errs, times = [], []
            for rep in range(REPLICATES):
                key = jax.random.PRNGKey(1000 * rep + n % 997)
                kd, ks = jax.random.split(key)
                data = krr_data.bimodal(kd, n, d=3)
                probs, secs = common.leverage_probs(method, key, kernel,
                                                    data, lam, d=3)
                err = common.nystrom_error(ks, kernel, data, lam, probs, m)
                errs.append(err)
                times.append(secs)
            print(f"{method},{n},{np.mean(times):.3f},{np.mean(errs):.5f}")


if __name__ == "__main__":
    main()
