import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb measurement harness: extrapolated per-device costs for a
(cfg overrides, hp) variant of one cell.

  PYTHONPATH=src python scripts_hillclimb.py zamba2-7b train_4k \
      remat=dots param_dtype=bfloat16 master=1
"""

import sys  # noqa: E402
import dataclasses  # noqa: E402

import jax  # noqa: E402

from repro import configs  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch import mesh as mesh_lib  # noqa: E402
from repro.launch.dryrun import (_cell_costs, _depth_variants, _extrapolate,  # noqa: E402
                                 rules_for, serve_dtype)
from repro.models.config import SHAPES  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.roofline import analysis as R  # noqa: E402


def main() -> None:
    arch, shape_name = sys.argv[1], sys.argv[2]
    overrides, rule_over, master = {}, {}, False
    for kv in sys.argv[3:]:
        k, v = kv.split("=", 1)
        if k == "master":
            master = bool(int(v))
        elif k.startswith("rule."):
            rule_over[k[5:]] = (None if v == "none"
                                else tuple(v.split(",")))
        elif v in ("True", "False"):
            overrides[k] = v == "True"
        else:
            try:
                overrides[k] = int(v)
            except ValueError:
                overrides[k] = v
    shape = SHAPES[shape_name]
    cfg = serve_dtype(configs.get(arch, **overrides), shape)
    hp = adamw.Hparams(master_weights=master)
    mesh = mesh_lib.make_production_mesh()
    rules = dict(rules_for(shape, cfg), **rule_over)
    with mesh, shd.activate(mesh, rules):
        cfg1, cfg2, n_units = _depth_variants(cfg)
        total = _extrapolate(_cell_costs(cfg1, shape, hp),
                             _cell_costs(cfg2, shape, hp), n_units)
    coll = sum(total["coll"].values())
    t_c = total["flops"] / R.PEAK_FLOPS
    t_m = total["bytes"] / R.HBM_BW
    t_l = coll / R.LINK_BW
    mf = R.model_flops(cfg, shape)
    t_model = mf / 256 / R.PEAK_FLOPS
    bound = max(t_c, t_m, t_l)
    print(f"VARIANT {arch} {shape_name} {sys.argv[3:]}")
    print(f"  flops/dev={total['flops']:.3e} bytes/dev={total['bytes']:.3e} "
          f"coll/dev={coll:.3e}")
    print(f"  t_comp={t_c:.3f}s t_mem={t_m:.3f}s t_coll={t_l:.3f}s "
          f"useful={mf/(total['flops']*256):.2f} "
          f"roofline={100*t_model/bound:.1f}%")


if __name__ == "__main__":
    main()
