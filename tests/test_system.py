"""End-to-end behaviour of the paper's system: Algorithm 1 -> Nystrom -> risk.

This is the full production path a user runs:
  densities (binned KDE)  ->  SA leverage (closed form)  ->  landmark sampling
  ->  Nystrom KRR solve  ->  in-sample risk comparable to exact KRR.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kde, kernels as K, krr, leverage, nystrom
from repro.data import krr_data


def test_full_pipeline_bimodal_3d():
    """The paper's Fig. 1 setting (3-D bimodal, Matern nu=1.5), scaled down."""
    n = 2000
    kern = K.Matern(nu=1.5)
    data = krr_data.bimodal(jax.random.PRNGKey(0), n, d=3, gamma=0.4)
    lam = 0.075 * n ** (-2.0 / 3.0)

    # Algorithm 1: estimate densities, apply Eq. (6), normalize.
    h = 0.15 * n ** (-1.0 / 7.0)
    dens = kde.estimate_densities(data.x, h=h, method="binned", grid_size=64)
    sa = leverage.sa_leverage(dens, lam, kern, d=3, n=n, floor=1e-3)
    np.testing.assert_allclose(float(jnp.sum(sa.probs)), 1.0, rtol=1e-5)

    # Nystrom with the paper's projection dimension 5 n^{1/3}.
    m = int(5 * n ** (1.0 / 3.0))
    ny = nystrom.fit(jax.random.PRNGKey(1), kern, data.x, data.y, lam, m, sa.probs)
    risk_sa = float(krr.in_sample_risk(nystrom.fitted(kern, ny, data.x), data.f_star))

    # Exact KRR reference.
    exact = krr.fit(kern, data.x, data.y, lam)
    risk_exact = float(krr.in_sample_risk(exact.fitted, data.f_star))

    assert risk_exact < 0.05
    assert risk_sa < 5.0 * risk_exact + 1e-3, (risk_sa, risk_exact)


def test_pipeline_is_jit_compatible():
    """Density -> SA -> probs composes under jit (one fused accelerator call)."""
    kern = K.Matern(nu=1.5)

    @jax.jit
    def probs_of(x):
        dens = kde.kde_direct(x, x, 0.1)
        return leverage.sa_leverage(dens, 1e-3, kern, d=2, n=x.shape[0]).probs

    x = jax.random.uniform(jax.random.PRNGKey(2), (256, 2))
    p = probs_of(x)
    assert p.shape == (256,)
    assert bool(jnp.all(p > 0))
    np.testing.assert_allclose(float(jnp.sum(p)), 1.0, rtol=1e-5)
