"""`streaming.multi_reduce` — one tile scan driving N accumulators: plain
slots are bit-equal to sequential passes, compensated slots keep their
(hi, lo) pair through the fused scan and a forced-2-device psum, and the
fused pipeline `evaluate()` streams x at most twice (deposit + Gram) while
scoring within 2e-3 of the separate predict pass."""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernels as K, nystrom, streaming
from repro.core.kernels import kernel_matrix
from repro.data import krr_data

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KERN = K.Matern(nu=1.5)


def run_sub(body: str, env_extra: dict | None = None) -> str:
    code = textwrap.dedent("""
        import jax
        import jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               **(env_extra or {}))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _gram_emits(x, xm, y):
    """Two reductions off one kernel tile: G = K^T K and rhs = K^T y."""
    def emit(xt, yt):
        k = kernel_matrix(KERN, xt, xm).astype(jnp.float32)
        return (k.T @ k, k.T @ yt)

    def emit_g(xt):
        k = kernel_matrix(KERN, xt, xm).astype(jnp.float32)
        return k.T @ k

    def emit_r(xt, yt):
        k = kernel_matrix(KERN, xt, xm).astype(jnp.float32)
        return k.T @ yt

    return emit, emit_g, emit_r


# ------------------------------------------------------------- bit parity --

def test_fused_plain_slots_bit_equal_sequential():
    """A fused plain-slot scan runs each slot's exact op sequence — the
    results match slot-by-slot sequential `tile_reduce` calls bitwise."""
    n, m = 4096, 32
    x = jax.random.normal(jax.random.PRNGKey(0), (n, 3), jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32)
    xm = x[:m]
    emit, emit_g, emit_r = _gram_emits(x, xm, y)
    inits = (jnp.zeros((m, m)), jnp.zeros((m,)))
    g_f, r_f = streaming.multi_reduce(emit, x, (y,), tile=256, inits=inits)
    g_s = streaming.tile_reduce(emit_g, x, tile=256, init=inits[0])
    r_s = streaming.tile_reduce(emit_r, x, (y,), tile=256, init=inits[1])
    assert np.array_equal(np.asarray(g_f), np.asarray(g_s))
    assert np.array_equal(np.asarray(r_f), np.asarray(r_s))


def test_fused_mixed_slots_and_errors():
    """Per-slot strategies mix freely; malformed slot counts raise."""
    n, m = 2048, 16
    x = jax.random.normal(jax.random.PRNGKey(2), (n, 3), jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(3), (n,), jnp.float32)
    xm = x[:m]
    emit, emit_g, emit_r = _gram_emits(x, xm, y)
    inits = (jnp.zeros((m, m)), jnp.zeros((m,)))
    g_f, r_f = streaming.multi_reduce(emit, x, (y,), tile=128, inits=inits,
                                      accumulators=("compensated", "plain"))
    g_s = streaming.tile_reduce(emit_g, x, tile=128, init=inits[0],
                                accumulator="compensated")
    r_s = streaming.tile_reduce(emit_r, x, (y,), tile=128, init=inits[1])
    assert np.array_equal(np.asarray(g_f), np.asarray(g_s))
    assert np.array_equal(np.asarray(r_f), np.asarray(r_s))
    with pytest.raises(ValueError):
        streaming.MultiAccumulator(("plain",), combines=(None, None))
    with pytest.raises(ValueError):
        streaming.multi_reduce(emit, x, (y,), tile=128, inits=inits,
                               accumulators=("plain",))


def test_fused_compensated_slot_state_survives_scan():
    """finalize=False exposes the per-slot states: the compensated slot is
    a live (hi, lo) pair (lo nonzero on a long stream) whose collapse equals
    the finalized fused run bitwise."""
    n, m = 32768, 24
    x = jax.random.normal(jax.random.PRNGKey(4), (n, 3), jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(5), (n,), jnp.float32)
    xm = x[:m]
    emit, _, _ = _gram_emits(x, xm, y)
    inits = (jnp.zeros((m, m)), jnp.zeros((m,)))
    state = streaming.multi_reduce(emit, x, (y,), tile=256, inits=inits,
                                   accumulators=("compensated", "plain"),
                                   finalize=False)
    (g_hi, g_lo), r_state = state
    assert float(jnp.abs(g_lo).max()) > 0.0
    g_f, r_f = streaming.multi_reduce(emit, x, (y,), tile=256, inits=inits,
                                      accumulators=("compensated", "plain"))
    assert np.array_equal(np.asarray(g_hi + g_lo), np.asarray(g_f))
    assert np.array_equal(np.asarray(r_state), np.asarray(r_f))


@pytest.mark.slow
def test_fused_compensated_slot_survives_psum():
    """Forced 2-device mesh: a MultiAccumulator with a compensated slot
    psums hi and lo separately — the sharded fused reduction matches the
    single-device one to reduction-order noise, with lo alive post-psum."""
    out = run_sub("""
        from repro.core import kernels as K, streaming
        from repro.core.kernels import kernel_matrix
        from repro.distributed import sharding as shd
        assert jax.device_count() == 2, jax.devices()
        kern = K.Matern(nu=1.5)
        n, m = 32768, 24
        x = jax.random.normal(jax.random.PRNGKey(0), (n, 3))
        y = jax.random.normal(jax.random.PRNGKey(1), (n,))
        xm = x[:m]
        multi = streaming.MultiAccumulator(("compensated", "plain"))

        def local(xv, yv, xm_rep):
            def emit(xt, yt):
                k = kernel_matrix(kern, xt, xm_rep).astype(jnp.float32)
                return (k.T @ k, k.T @ yt)
            inits = (jnp.zeros((m, m)), jnp.zeros((m,)))
            return streaming.multi_reduce(
                emit, xv, (yv,), tile=512, inits=inits,
                accumulators=("compensated", "plain"), finalize=False)

        g_ref, r_ref = streaming.mesh_reduce(local, (x, y), (xm,),
                                             accumulator=multi)
        mesh = jax.make_mesh((2,), ("data",))
        with mesh, shd.activate(mesh):
            state = streaming.mesh_reduce(local, (x, y), (xm,),
                                          accumulator=multi, finalize=False)
            (g_hi, g_lo), r_state = state
            g_sh, r_sh = multi.finalize(state)
        assert float(jnp.abs(g_lo).max()) > 0.0
        np.testing.assert_allclose(np.asarray(g_sh), np.asarray(g_ref),
                                   rtol=2e-6, atol=1e-4)
        np.testing.assert_allclose(np.asarray(r_sh), np.asarray(r_ref),
                                   rtol=2e-5, atol=1e-4)
        print("MULTI_PSUM_OK")
    """, env_extra={"XLA_FLAGS": "--xla_force_host_platform_device_count=2"})
    assert "MULTI_PSUM_OK" in out


# -------------------------------------------------------- fused evaluate() --

def test_evaluate_streams_x_at_most_twice(monkeypatch):
    """The fused `evaluate()` touches the full x stream exactly twice — the
    KDE deposit and the score-carrying Gram pass.  No predict pass runs."""
    from repro.pipeline import PipelineConfig, SAKRRPipeline
    n = 4096
    data = krr_data.bimodal(jax.random.PRNGKey(6), n, d=3)
    passes = []
    orig_reduce, orig_map = streaming.tile_reduce, streaming.tile_map

    def counting_reduce(emit, x, *a, **kw):
        if hasattr(x, "shape") and x.shape and x.shape[0] == n:
            passes.append("reduce")
        return orig_reduce(emit, x, *a, **kw)

    def counting_map(fn, x, *a, **kw):
        if hasattr(x, "shape") and x.shape and x.shape[0] == n:
            passes.append("map")
        return orig_map(fn, x, *a, **kw)

    monkeypatch.setattr(streaming, "tile_reduce", counting_reduce)
    monkeypatch.setattr(streaming, "tile_map", counting_map)
    pipe = SAKRRPipeline(PipelineConfig(num_landmarks=64, tile=512))
    scores = pipe.evaluate(data.x, data.y, f_star=data.f_star)
    assert set(scores) == {"mse", "rmse", "risk"}
    assert len(passes) <= 2, passes
    assert "map" not in passes   # the predict pass was fused away


def test_fused_scores_match_predict_pass():
    """Fused in-sample scoring (quadratic forms in the Gram moments,
    assembled in host f64) agrees with the separate predict-then-score fold
    to 2e-3 relative — the two big terms cancel to ~n * mse, so this locks
    ~3 surviving digits through the cancellation."""
    from repro.pipeline import (PipelineConfig, PredictStage, SAKRRPipeline,
                                ScoreStage, StageContext, default_stages,
                                run_stages)
    data = krr_data.bimodal(jax.random.PRNGKey(7), 4096, d=3)
    cfg = PipelineConfig(num_landmarks=96, tile=512)
    pipe = SAKRRPipeline(cfg)
    fused = pipe.evaluate(data.x, data.y, f_star=data.f_star)
    assert pipe.state.predictions is None

    ctx = StageContext(config=cfg, kernel=cfg.build_kernel(), x=data.x,
                       y=data.y, n=4096, d=3, lam=cfg.resolve_lam(4096),
                       num_landmarks=96, f_star=data.f_star)
    stages = default_stages(cfg) + [PredictStage(), ScoreStage()]
    run_stages(stages, ctx)
    assert ctx.predictions is not None
    for key in ("mse", "rmse", "risk"):
        np.testing.assert_allclose(fused[key], ctx.scores[key], rtol=2e-3)


def test_fit_streaming_scored_moments_match_direct():
    """The scored fit's moments reproduce the direct quadratic forms."""
    n, m = 4096, 48
    x = jax.random.normal(jax.random.PRNGKey(8), (n, 3), jnp.float32)
    y = jnp.sin(x[:, 0]) + 0.1 * jax.random.normal(jax.random.PRNGKey(9),
                                                   (n,), jnp.float32)
    idx = jnp.arange(m)
    fit, mom = nystrom.fit_streaming_scored(KERN, x, y, 1e-3, idx, tile=512)
    ref = nystrom.fit_streaming(KERN, x, y, 1e-3, idx, tile=512)
    # the scored fit's rhs is column 0 of a widened (n, 1+r) gemm — same
    # products as the (n,) gemv but a different XLA reduction order, so the
    # solve agrees to whitening noise rather than bitwise
    np.testing.assert_allclose(np.asarray(fit.beta), np.asarray(ref.beta),
                               rtol=1e-2, atol=1e-3)
    assert mom["n_eval"] == n and mom["rhs_f"] is None
    beta = np.asarray(fit.beta, np.float64)
    q = beta @ np.asarray(mom["g"], np.float64) @ beta
    mse = (q - 2.0 * beta @ np.asarray(mom["rhs_y"], np.float64)
           + mom["y_sq"]) / n
    pred = nystrom.predict_streaming(KERN, fit, x, tile=512)
    mse_ref = float(jnp.mean((pred - y) ** 2))
    np.testing.assert_allclose(mse, mse_ref, rtol=2e-3)
