"""Algebraic baselines (uniform / Recursive-RLS / BLESS) sanity + accuracy."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kernels as K, krr, rls
from repro.data import krr_data

KERN = K.Matern(nu=0.5)


def test_projection_estimator_exact_with_full_sketch():
    n = 300
    data = krr_data.uniform(jax.random.PRNGKey(0), n)
    lam = 1e-3
    exact = krr.exact_leverage(KERN, data.x, lam)
    est = rls.projection_leverage(
        KERN, data.x, data.x, jnp.ones(n), mu=n * lam, jitter=0.0
    )
    np.testing.assert_allclose(
        np.asarray(est), np.asarray(exact.leverage), rtol=2e-3, atol=2e-4
    )


def _corr(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return np.corrcoef(a, b)[0, 1]


def test_recursive_rls_correlates_with_exact():
    n = 900
    data = krr_data.bimodal_1d_paper(jax.random.PRNGKey(1), n)
    lam = 0.45 * n ** -0.8
    exact = krr.exact_leverage(KERN, data.x, lam)
    est = rls.recursive_rls(KERN, data.x, lam, seed=0)
    assert est.sketch_size > 0
    assert _corr(est.leverage, exact.leverage) > 0.8
    r = np.asarray(est.probs) / np.asarray(exact.probs)
    assert 0.5 < np.median(r) < 2.0


def test_bless_correlates_with_exact():
    n = 900
    data = krr_data.bimodal_1d_paper(jax.random.PRNGKey(2), n)
    lam = 0.45 * n ** -0.8
    exact = krr.exact_leverage(KERN, data.x, lam)
    est = rls.bless(KERN, data.x, lam, seed=0)
    assert est.sketch_size > 0
    assert _corr(est.leverage, exact.leverage) > 0.8
    r = np.asarray(est.probs) / np.asarray(exact.probs)
    assert 0.5 < np.median(r) < 2.0


def test_race_sketch_sizes_are_deterministic():
    """RC/BLESS sketches are Gumbel-top-k races now, not Bernoulli draws:
    the sketch SIZE is k = round(sum inclusion) — a function of the
    leverage profile, not of the inclusion coin flips — so repeated runs
    (same seed) reproduce it exactly and the `--compare` bench's
    sketch_size/d_proj rows stop wobbling."""
    n = 600
    data = krr_data.bimodal_1d_paper(jax.random.PRNGKey(3), n)
    lam = 0.45 * n ** -0.8
    for est in (rls.recursive_rls, rls.bless):
        a = est(KERN, data.x, lam, seed=0)
        b = est(KERN, data.x, lam, seed=0)
        assert a.sketch_size == b.sketch_size > 0
        np.testing.assert_array_equal(np.asarray(a.leverage),
                                      np.asarray(b.leverage))


def test_race_sketch_weights_match_bernoulli_convention():
    """The race sketch's weights are inverse-inclusion estimates (>= 1 up
    to the threshold noise) consumed by the same weighted projection
    estimator; on a flat inclusion profile the race reduces to uniform-ish
    sampling with k = round(sum pi)."""
    rng = np.random.default_rng(0)
    inclusion = np.full(200, 0.25)
    idx, w = rls._race_sketch(rng, inclusion)
    assert idx.shape[0] == 50                 # deterministic: 200 * 0.25
    assert len(np.unique(idx)) == 50          # distinct (without replacement)
    assert np.all(w > 0)
    # unbiasedness of the estimated sizes: E[sum w over sketch] ~ n
    totals = []
    for seed in range(20):
        i, wi = rls._race_sketch(np.random.default_rng(seed), inclusion)
        totals.append(wi.sum())
    assert abs(np.mean(totals) - 200) / 200 < 0.15, np.mean(totals)


def test_uniform_baseline():
    u = rls.uniform(50)
    np.testing.assert_allclose(np.asarray(u.probs), 1.0 / 50)
    np.testing.assert_allclose(float(jnp.sum(u.probs)), 1.0, rtol=1e-6)
