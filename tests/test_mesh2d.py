"""2D (data x model) mesh semantics under forced host devices.

Each test forks a fresh interpreter with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (jax locks the
device count at backend init, so the parent process cannot host these)
and builds a (2, 2) (data, model) grid via `launch.mesh.make_local_mesh_2d`.

The locked contracts:
  * data axes psum, the model axis shards independent work — per-h KDE
    densities and per-lam whitened solves on the 2D mesh are BIT-equal to
    the 1D data-mesh path with the same data-shard count;
  * `streaming.row_shard_count` counts data-axis shards only (the
    eps_scale step budget must not inflate with model parallelism), and
    `streaming.model_shard_count` counts the model axis;
  * the compensated (hi, lo) accumulator pair and `accstate.psum` survive
    the data-axis-only reduction un-collapsed;
  * `nystrom.fit_streaming_batched` / `predict_streaming_batched` match
    the per-model python loop, meshless and sharded.
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_forced(body: str, marker: str, devices: int = 4,
                timeout: int = 500) -> None:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    assert marker in out.stdout, out.stdout[-2000:]


def test_kde_multi_2d_mesh_bit_equal_to_1d():
    """Per-h densities on the (2, 2) mesh are bit-equal to the 2-device 1D
    data mesh: same deposit participants, same per-h op sequence (the
    bandwidth is sliced from a sharded device array on the 2D path)."""
    body = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed import sharding as shd
        from repro.core import distributed as dist, streaming
        from repro.launch import mesh as mesh_lib

        assert jax.device_count() == 4
        x = jax.random.normal(jax.random.PRNGKey(0), (256, 2), jnp.float32)
        hs = [0.2, 0.3, 0.5, 0.8]

        mesh1 = jax.make_mesh((2,), ("data",), devices=jax.devices()[:2])
        with mesh1, shd.activate(mesh1):
            ref = np.asarray(dist.kde_binned_sharded_multi(x, hs,
                                                           grid_size=32))
            assert streaming.row_shard_count(x.shape) == 2
            assert streaming.model_shard_count(len(hs)) == 1

        mesh2 = mesh_lib.make_local_mesh_2d(model_parallelism=2)
        with mesh2, shd.activate(mesh2):
            out = np.asarray(dist.kde_binned_sharded_multi(x, hs,
                                                           grid_size=32))
            # row_shard_count: DATA axis only; the model axis must not
            # inflate the eps_scale step budget
            assert streaming.row_shard_count(x.shape) == 2
            assert streaming.model_shard_count(len(hs)) == 2

        np.testing.assert_array_equal(ref, out)
        print("KDE2D_BITEQ_OK")
    """
    _run_forced(body, "KDE2D_BITEQ_OK")


def test_solve_multi_2d_mesh_bit_equal_to_1d():
    """The model-sharded multi-lam whitened solve (2 lams per chip column)
    is bit-equal to the 1D-mesh replicated body (4 lams per chip): the
    per-lam op chain compiles identically regardless of the local count."""
    body = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import nystrom
        from repro.core.kernels import Gaussian, kernel_matrix
        from repro.distributed import sharding
        from repro.launch import mesh as mesh_lib

        rng = np.random.default_rng(0)
        n, d, m = 512, 3, 16
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        y = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
        kern = Gaussian(1.0)
        idx = jnp.asarray(rng.integers(0, n, size=(m,)))
        xm = x[idx]
        k_nm = kernel_matrix(kern, x, xm)
        g = (k_nm.T @ k_nm).astype(jnp.float32)
        rhs = k_nm.T @ y
        k_mm = kernel_matrix(kern, xm)
        lam_grid = [1e-3, 3e-3, 1e-2, 3e-2]

        eager = nystrom.solve_normal_eq_multi(g, rhs, k_mm, n, lam_grid)
        with sharding.activate(mesh_lib.make_local_mesh()):
            ref = nystrom.solve_normal_eq_multi(g, rhs, k_mm, n, lam_grid)
        with sharding.activate(mesh_lib.make_local_mesh_2d(2)):
            shd = nystrom.solve_normal_eq_multi(g, rhs, k_mm, n, lam_grid)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(shd))
        # the meshless eager loop differs only by jit-time FMA fusion
        np.testing.assert_allclose(np.asarray(eager), np.asarray(ref),
                                   rtol=1e-4, atol=1e-6)
        print("SOLVE2D_BITEQ_OK")
    """
    _run_forced(body, "SOLVE2D_BITEQ_OK")


def test_streaming_primitives_2d_mesh():
    """mesh_reduce/mesh_map model_args layouts; the compensated (hi, lo)
    pair and `accstate.psum` cross the data-axis-only psum un-collapsed
    (1D and (2, 2) results bit-equal: same data-shard participants)."""
    body = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core import accstate, streaming
        from repro.distributed import sharding as shd
        from repro.launch import mesh as mesh_lib

        mesh1 = jax.make_mesh((2,), ("data",), devices=jax.devices()[:2])
        mesh2 = mesh_lib.make_local_mesh_2d(model_parallelism=2)
        rows = jax.random.normal(jax.random.PRNGKey(1), (64,), jnp.float32)
        w = jnp.arange(1.0, 5.0)

        # mesh_reduce(model_args=): per-model reductions over shared rows
        def local(r_loc, w_loc):
            return jax.vmap(lambda wi: wi * jnp.sum(r_loc))(w_loc)
        with mesh2, shd.activate(mesh2):
            got = streaming.mesh_reduce(local, (rows,), model_args=(w,))
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(w) * float(jnp.sum(rows)),
                                   rtol=1e-6)

        # compensated pair: 1D vs 2D bit-equal (data psum only)
        def local_c(r_loc):
            return streaming.tile_reduce(lambda t: jnp.sum(t), r_loc,
                                         tile=16, init=jnp.zeros(()),
                                         accumulator="compensated",
                                         pad="zero", finalize=False)
        with mesh1, shd.activate(mesh1):
            s1 = streaming.mesh_reduce(local_c, (rows,),
                                       accumulator="compensated")
        with mesh2, shd.activate(mesh2):
            s2 = streaming.mesh_reduce(local_c, (rows,),
                                       accumulator="compensated")
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))

        # mesh_map(model_args=): (B, n) batched map layout
        x = jax.random.normal(jax.random.PRNGKey(2), (256, 2), jnp.float32)
        def mloc(x_loc, w_loc):
            return jax.vmap(lambda wi: wi * x_loc[:, 0])(w_loc)
        with mesh2, shd.activate(mesh2):
            mm = streaming.mesh_map(mloc, x, model_args=(w,), out_rank=2)
        np.testing.assert_allclose(
            np.asarray(mm),
            np.asarray(w)[:, None] * np.asarray(x[:, 0])[None, :],
            rtol=1e-6)

        # accstate.psum: value through the strategy psum, rows sum,
        # steps max — inside a shard_map over the data axis of the 2D mesh
        def body(xs):
            st = accstate.init("compensated", jnp.zeros((), jnp.float32),
                               rows=xs.shape[0], steps=1)
            hi, lo = st.value
            st = accstate.AccState(value=(hi + jnp.sum(xs), lo),
                                   rows=st.rows, steps=st.steps,
                                   spec=st.spec)
            return accstate.psum(st, ("data",))
        vec = jnp.arange(8, dtype=jnp.float32)
        out = shard_map(body, mesh=mesh2, in_specs=P("data"),
                        out_specs=P())(vec)
        assert float(accstate.finalize(out)) == float(jnp.sum(vec))
        assert accstate.rows_of(out) == 8.0
        assert accstate.steps_of(out) == 1
        print("STREAM2D_OK")
    """
    _run_forced(body, "STREAM2D_OK")


def test_batched_fit_predict_2d_mesh():
    """fit_streaming_batched matches the per-model fit_streaming loop
    (meshless, <1e-4 rel) and stays within psum reduction-order tolerance
    under the (2, 2) mesh; predict_streaming_batched matches per-model
    predict_streaming in both regimes."""
    body = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import nystrom
        from repro.core.kernels import Gaussian
        from repro.distributed import sharding
        from repro.launch import mesh as mesh_lib

        rng = np.random.default_rng(0)
        n, d, m, B = 512, 3, 16, 4
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        ys = jnp.asarray(rng.normal(size=(B, n)), jnp.float32)
        kern = Gaussian(1.0)
        lams = jnp.asarray([1e-3, 3e-3, 1e-2, 3e-2], jnp.float32)
        lsets = jnp.asarray(rng.integers(0, n, size=(B, m)))

        bf = nystrom.fit_streaming_batched(kern, x, ys, lams, lsets,
                                           tile=128)
        for b in range(B):
            f = nystrom.fit_streaming(kern, x, ys[b], float(lams[b]),
                                      lsets[b], tile=128)
            err = float(jnp.max(jnp.abs(f.beta - bf.beta[b])) /
                        (jnp.max(jnp.abs(f.beta)) + 1e-30))
            assert err < 1e-4, (b, err)

        mesh2 = mesh_lib.make_local_mesh_2d(model_parallelism=2)
        with sharding.activate(mesh2):
            bf2 = nystrom.fit_streaming_batched(kern, x, ys, lams, lsets,
                                                tile=128)
        err = float(jnp.max(jnp.abs(bf2.beta - bf.beta)) /
                    jnp.max(jnp.abs(bf.beta)))
        assert err < 1e-3, err   # psum order through the fp32 solve

        xq = jnp.asarray(rng.normal(size=(256, d)), jnp.float32)
        pred = nystrom.predict_streaming_batched(kern, bf, xq, tile=64)
        assert pred.shape == (B, 256), pred.shape
        for b in range(B):
            f = nystrom.NystromFit(beta=bf.beta[b],
                                   landmarks=bf.landmarks[b],
                                   landmark_idx=bf.landmark_idx[b],
                                   lam=float(lams[b]))
            p = nystrom.predict_streaming(kern, f, xq, tile=64)
            np.testing.assert_allclose(np.asarray(p), np.asarray(pred[b]),
                                       rtol=1e-5, atol=1e-5)
        with sharding.activate(mesh2):
            pred2 = nystrom.predict_streaming_batched(kern, bf, xq, tile=64)
        np.testing.assert_allclose(np.asarray(pred2), np.asarray(pred),
                                   rtol=1e-5, atol=1e-5)

        # weights + shared-y broadcast variants
        w = jnp.asarray(rng.uniform(0.5, 2.0, size=(B, m)), jnp.float32)
        bf4 = nystrom.fit_streaming_batched(kern, x, ys, lams, lsets,
                                            tile=128, weights=w)
        f1 = nystrom.fit_streaming(kern, x, ys[2], float(lams[2]), lsets[2],
                                   tile=128, weights=w[2])
        err = float(jnp.max(jnp.abs(bf4.beta[2] - f1.beta)) /
                    (jnp.max(jnp.abs(f1.beta)) + 1e-30))
        assert err < 1e-4, err
        print("BATCHED2D_OK")
    """
    _run_forced(body, "BATCHED2D_OK")


def test_mesh_construction_validation():
    """Production/local mesh factories: shape derivation + divisibility
    errors (no forced devices needed for the error paths)."""
    body = """
        import jax, pytest
        from repro.launch import mesh as mesh_lib

        assert jax.device_count() == 4
        m = mesh_lib.make_production_mesh(model_parallelism=2)
        assert m.shape == {"data": 2, "model": 2}
        m = mesh_lib.make_production_mesh(model_parallelism=2,
                                          num_devices=2)
        assert m.shape == {"data": 1, "model": 2}
        m2 = mesh_lib.make_local_mesh_2d(model_parallelism=2)
        assert m2.axis_names == ("data", "model")
        try:
            mesh_lib.make_production_mesh(model_parallelism=3)
        except ValueError as e:
            assert "not divisible" in str(e)
        else:
            raise AssertionError("expected ValueError")
        try:
            mesh_lib.make_local_mesh_2d(model_parallelism=3)
        except ValueError as e:
            assert "divisor" in str(e)
        else:
            raise AssertionError("expected ValueError")
        print("MESHVAL_OK")
    """
    _run_forced(body, "MESHVAL_OK")
