"""Exact KRR, Nystrom approximation, and the end-to-end paper pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernels as K, krr, leverage, nystrom, rls
from repro.data import krr_data

KERN = K.Matern(nu=1.5)


def test_exact_krr_regularization_path():
    """Training error decreases monotonically as lambda shrinks, crossing
    the noise floor once the ridge is small enough.

    The path deliberately runs into the fp32 danger zone: below lam ~
    sqrt(eps_f32) the plain fp32 solve first stalls above the floor and then
    explodes (lam=1e-8 gave MSE 0.33 > the lam=1e-6 value; 1e-9 gave 1.8e3),
    so this locks in krr.fit's f64 fallback, not just the happy path.
    """
    data = krr_data.uniform(jax.random.PRNGKey(0), 200)
    errs = []
    for lam in (1e-2, 1e-4, 1e-6, 1e-8):
        fit = krr.fit(KERN, data.x, data.y, lam=lam)
        errs.append(float(jnp.mean((fit.fitted - data.y) ** 2)))
    assert errs[0] > errs[1] > errs[2] > errs[3], errs
    # Training MSE crosses the irreducible noise floor (var = 0.25) from
    # above once n*lam drops under the kernel's eigenvalue tail.
    assert errs[-1] < 0.25, errs
    # ... but does not collapse to interpolation at these ridges.
    assert errs[-1] > 0.05, errs


def test_exact_krr_risk_reasonable():
    n = 800
    data = krr_data.uniform(jax.random.PRNGKey(1), n)
    fit = krr.fit(KERN, data.x, data.y, lam=0.45 * n ** -0.8)
    risk = float(krr.in_sample_risk(fit.fitted, data.f_star))
    # Noise variance is 0.25; the regression error must be well below it.
    assert risk < 0.05, risk


def test_predict_consistent_with_fitted():
    data = krr_data.uniform(jax.random.PRNGKey(2), 150)
    fit = krr.fit(KERN, data.x, data.y, lam=1e-3)
    pred = krr.predict(KERN, fit, data.x)
    np.testing.assert_allclose(np.asarray(pred), np.asarray(fit.fitted), rtol=1e-4, atol=1e-5)


def test_exact_leverage_properties():
    n = 400
    data = krr_data.uniform(jax.random.PRNGKey(3), n)
    lev = krr.exact_leverage(KERN, data.x, lam=1e-3)
    assert float(jnp.min(lev.leverage)) > 0.0
    assert float(jnp.max(lev.leverage)) <= 1.0 + 1e-5
    np.testing.assert_allclose(float(jnp.sum(lev.probs)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(
        float(lev.d_stat), float(jnp.sum(lev.leverage)), rtol=1e-6
    )


def test_nystrom_with_all_landmarks_equals_exact():
    n = 200
    data = krr_data.uniform(jax.random.PRNGKey(4), n)
    lam = 1e-3
    exact = krr.fit(KERN, data.x, data.y, lam)
    ny = nystrom.fit_from_landmarks(KERN, data.x, data.y, lam, jnp.arange(n))
    fitted = nystrom.fitted(KERN, ny, data.x)
    np.testing.assert_allclose(
        np.asarray(fitted), np.asarray(exact.fitted), rtol=5e-3, atol=5e-3
    )


def _nystrom_risk(probs, data, lam, m, seed):
    fit = nystrom.fit(jax.random.PRNGKey(seed), KERN, data.x, data.y, lam, m, probs)
    return float(krr.in_sample_risk(nystrom.fitted(KERN, fit, data.x), data.f_star))


def test_sa_nystrom_attains_exact_risk_bimodal():
    """Paper Thm 6 / Fig 1: SA-weighted Nystrom ~ exact KRR risk (C * R_n)."""
    n = 1500
    data = krr_data.bimodal_1d_paper(jax.random.PRNGKey(5), n)
    lam = 0.45 * n ** -0.8
    exact_fit = krr.fit(KERN, data.x, data.y, lam)
    exact_risk = float(krr.in_sample_risk(exact_fit.fitted, data.f_star))

    sa = leverage.sa_leverage(data.density, lam, KERN, d=1, n=n)
    m = int(5 * n ** (1 / 3.0)) * 2
    risks = [_nystrom_risk(sa.probs, data, lam, m, seed) for seed in range(3)]
    assert np.median(risks) < 4.0 * exact_risk + 1e-4, (risks, exact_risk)


def test_sa_beats_uniform_on_bimodal():
    """Fig 1's qualitative claim: Vanilla misses the small mode, SA doesn't."""
    n = 1500
    data = krr_data.bimodal_1d_paper(jax.random.PRNGKey(6), n)
    lam = 0.45 * n ** -0.8
    sa = leverage.sa_leverage(data.density, lam, KERN, d=1, n=n)
    uni = rls.uniform(n)
    m = 24  # scarce landmark budget exposes the difference
    sa_risks = [_nystrom_risk(sa.probs, data, lam, m, s) for s in range(5)]
    uni_risks = [_nystrom_risk(uni.probs, data, lam, m, s) for s in range(5)]
    # The minor mode carries the risk for uniform sampling.
    assert np.median(sa_risks) < np.median(uni_risks), (sa_risks, uni_risks)


def test_nystrom_risk_improves_with_landmarks():
    n = 1000
    data = krr_data.uniform(jax.random.PRNGKey(7), n)
    lam = 0.45 * n ** -0.8
    exact = krr.exact_leverage(KERN, data.x, lam)
    r_small = np.median([_nystrom_risk(exact.probs, data, lam, 8, s) for s in range(3)])
    r_big = np.median([_nystrom_risk(exact.probs, data, lam, 96, s) for s in range(3)])
    assert r_big < r_small
