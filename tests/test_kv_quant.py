"""int8 KV-cache quantization: round-trip accuracy + attention-output error
vs the bf16 cache path + hypothesis property (scale covers absmax)."""

from __future__ import annotations

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import ref as fa_ref
from repro.serving import kv_quant


def test_roundtrip_error_small():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 64, 32))
    qkv = kv_quant.quantize(x)
    back = kv_quant.dequantize(qkv)
    err = np.abs(np.asarray(back) - np.asarray(x))
    # absmax int8 + bf16 scale: error <= absmax * (1/254 + 2^-8) per row
    absmax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    assert (err <= absmax * (1 / 254 + 1 / 256) * 1.05 + 1e-6).all()


def test_attention_output_error_vs_fp_cache():
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    b, h, s, dh = 1, 4, 128, 32
    q = jax.random.normal(keys[0], (b, h, s, dh))
    k = jax.random.normal(keys[1], (b, h, s, dh))
    v = jax.random.normal(keys[2], (b, h, s, dh))
    exact = fa_ref.attention(q, k, v, causal=True)
    kq = kv_quant.dequantize(kv_quant.quantize(k))
    vq = kv_quant.dequantize(kv_quant.quantize(v))
    approx = fa_ref.attention(q, kq, vq, causal=True)
    rel = float(jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact))
    assert rel < 0.01, rel  # sub-1% relative output error


def test_update_row_matches_requantize():
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 16, 8))
    qkv = kv_quant.quantize(x)
    new = jax.random.normal(jax.random.PRNGKey(3), (2, 3, 1, 8))
    updated = kv_quant.update_row(qkv, new, 5)
    ref = x.at[:, :, 5:6, :].set(new)
    np.testing.assert_allclose(
        np.asarray(kv_quant.dequantize(updated)),
        np.asarray(kv_quant.dequantize(kv_quant.quantize(ref))),
        rtol=0, atol=1e-6)


@hypothesis.given(st.integers(0, 2 ** 31 - 1), st.floats(0.01, 100.0))
@hypothesis.settings(max_examples=20, deadline=None)
def test_scale_covers_absmax(seed, magnitude):
    x = magnitude * jax.random.normal(jax.random.PRNGKey(seed % 1000), (4, 16))
    qkv = kv_quant.quantize(x, scale_dtype=jnp.float32)
    # every element representable: |x| <= 127 * scale (fp32 scales exact)
    assert (np.abs(np.asarray(x)) <=
            127.0 * np.asarray(qkv.scale)[..., None] * (1 + 1e-5) + 1e-7).all()
    # bf16 scales stay within one bf16 ulp of covering
    qb = kv_quant.quantize(x)
    assert (np.abs(np.asarray(x)) <=
            127.0 * np.asarray(qb.scale)[..., None] * (1 + 2 ** -7) + 1e-7).all()