"""Roofline-guided tile autotuner (`repro.tuning`): deterministic plans,
shape-bucketed cache keys, measured-plan caching, and the bit-parity
contract — resolving ``tile=None`` never perturbs numerics, it only picks
the integer the op would have been called with."""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tuning
from repro.core import kde, kernels, nystrom
from repro.kernels import dispatch


@pytest.fixture()
def tune_cache(tmp_path, monkeypatch):
    """Isolated plan cache: every test starts cold and touches only tmp."""
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(path))
    tuning.set_measure(None)
    tuning.clear_cache()
    yield path
    tuning.clear_cache()
    tuning.set_measure(None)


# ------------------------------------------------------------------- plans --
def test_model_plan_deterministic(tune_cache):
    a = tuning.plan_for("gram", 262144, 320, 3)
    tuning.clear_cache()
    b = tuning.plan_for("gram", 262144, 320, 3)
    assert a.tile == b.tile
    assert a.source == "model" and b.source == "model"
    assert a.tuning_seconds == 0.0


def test_shape_bucket_stability(tune_cache):
    """Nearby shapes share one pow2 bucket, hence one cache entry."""
    k1 = tuning.shape_key("gram", 5000, 300, 3)
    k2 = tuning.shape_key("gram", 6000, 290, 3)
    assert k1 == k2
    k3 = tuning.shape_key("gram", 9000, 300, 3)   # next n bucket
    assert k3 != k1
    p1 = tuning.plan_for("gram", 5000, 300, 3)
    p2 = tuning.plan_for("gram", 6000, 290, 3)
    assert p1.source == "model" and p2.source == "cache"
    assert p1.tile == p2.tile


def test_cache_hit_and_disk_roundtrip(tune_cache):
    cold = tuning.plan_for("predict", 50000, 160, 3)
    assert cold.source == "model"
    warm = tuning.plan_for("predict", 50000, 160, 3)
    assert warm.source == "cache" and warm.tile == cold.tile
    # the entry survives on disk and a cold in-memory cache recalls it
    from repro.tuning import autotune
    payload = json.loads(tune_cache.read_text())
    assert payload["version"] == autotune._CACHE_VERSION
    assert len(payload["entries"]) == 1
    autotune._MEMORY.clear()
    autotune._DISK_LOADED = False
    again = tuning.plan_for("predict", 50000, 160, 3)
    assert again.source == "cache" and again.tile == cold.tile


def test_corrupted_disk_cache_warns_and_retunes(tune_cache):
    """Garbage bytes in autotune.json must never raise: plan resolution
    warns once and re-tunes from scratch."""
    from repro.tuning import autotune
    tune_cache.write_bytes(b"\x00\x9f{not json at all\xff")
    autotune._MEMORY.clear()
    autotune._DISK_LOADED = False
    with pytest.warns(RuntimeWarning, match="re-tuned from scratch"):
        plan = tuning.plan_for("gram", 50000, 160, 3)
    assert plan.tile > 0 and plan.source == "model"
    # a valid-JSON payload with the wrong shape is equally survivable
    tune_cache.write_text(json.dumps({"entries": []}))
    autotune._MEMORY.clear()
    autotune._DISK_LOADED = False
    with pytest.warns(RuntimeWarning, match="no version key"):
        plan = tuning.plan_for("gram", 50000, 160, 3)
    assert plan.tile > 0
    # and a version-matched payload with malformed entries drops only them
    tune_cache.write_text(json.dumps({
        "version": autotune._CACHE_VERSION,
        "entries": {"k1": {"tile": "huge"}, "k2": 7}}))
    autotune._MEMORY.clear()
    autotune._DISK_LOADED = False
    with pytest.warns(RuntimeWarning, match="malformed entr"):
        plan = tuning.plan_for("gram", 50000, 160, 3)
    assert plan.tile > 0


def test_ladder_bounds_and_one_shot_top_rung(tune_cache):
    for op in tuning.OPS:
        ladder = tuning.candidate_tiles(op, 40000, 320, 3)
        assert ladder, op
        for t in ladder:
            assert t & (t - 1) == 0, (op, t)          # pow2 rungs only
            assert tuning.MIN_TILE <= t <= tuning.MAX_TILE
        # the one-shot rung (pow2-ceil of n) is always a candidate when it
        # fits the ladder ceiling — small-n calls degenerate to untiled
        assert 65536 in ladder, op
    small = tuning.candidate_tiles("deposit", 600, 96, 3)
    assert max(small) >= 600


def test_unknown_op_and_degenerate_shapes(tune_cache):
    with pytest.raises(ValueError):
        tuning.plan_for("nope", 1000, 10, 3)
    p = tuning.plan_for("gram", 0, 10, 3)
    assert p.source == "default" and p.tile == tuning.DEFAULT_TILE


# ---------------------------------------------------------------- measured --
@pytest.mark.slow
def test_measured_plan_then_warm_cache(tune_cache):
    n, m, d = 4096, 32, 3
    t0 = time.perf_counter()
    cold = tuning.plan_for("gram", n, m, d, measure=True)
    cold_s = time.perf_counter() - t0
    assert cold.source == "measured"
    assert cold.tuning_seconds > 0.0
    assert cold.tuning_seconds <= cold_s
    t0 = time.perf_counter()
    warm = tuning.plan_for("gram", n, m, d, measure=True)
    warm_s = time.perf_counter() - t0
    assert warm.source == "cache" and warm.tile == cold.tile
    assert warm_s < 0.1, warm_s     # warm runs never re-measure
    entry = json.loads(tune_cache.read_text())["entries"]
    assert list(entry.values())[0]["source"] == "measured"


def test_measured_context_gates_measurement(tune_cache):
    assert not tuning.measuring()
    with tuning.measured():
        assert tuning.measuring()
        with tuning.measured(False):
            assert not tuning.measuring()
        assert tuning.measuring()
    assert not tuning.measuring()


def test_no_measurement_under_trace(tune_cache):
    """plan resolution inside a jit trace must fall back to the model (a
    micro-benchmark cannot run mid-trace)."""
    seen = {}

    @jax.jit
    def f(x):
        seen["plan"] = tuning.plan_for("gram", 8192, 64, 3, measure=True)
        return x

    f(jnp.ones(3))
    assert seen["plan"].source == "model"


# -------------------------------------------------------------- bit parity --
def _data(n=2000, d=3, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(kx, (n, d), jnp.float32),
            jax.random.normal(ky, (n,), jnp.float32))


def test_gram_autotuned_bit_equal(tune_cache):
    x, y = _data()
    kern = kernels.Matern(1.5)
    xm = x[:48]
    tile = dispatch.resolve_tile("gram", x.shape[0], 48, 3, dtype=x.dtype)
    g0, r0 = nystrom.scan_normal_eq(kern, x, xm, y, tile=None)
    g1, r1 = nystrom.scan_normal_eq(kern, x, xm, y, tile=tile)
    assert np.array_equal(np.asarray(g0), np.asarray(g1))
    assert np.array_equal(np.asarray(r0), np.asarray(r1))


def test_deposit_autotuned_bit_equal(tune_cache):
    x, _ = _data(seed=1)
    g = 48
    lo = jnp.full((3,), -4.0)
    spacing = jnp.full((3,), 8.0 / (g - 1))
    tile = dispatch.resolve_tile("deposit", x.shape[0], g, 3, dtype=x.dtype)
    a = dispatch.binned_scatter(x, lo, spacing, g, backend="xla", tile=None)
    b = dispatch.binned_scatter(x, lo, spacing, g, backend="xla", tile=tile)
    c = kde.scatter_cic(x, lo, spacing, g, tile=tile)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    assert np.array_equal(np.asarray(b), np.asarray(c))


def test_predict_and_fit_autotuned_bit_equal(tune_cache):
    x, y = _data(seed=2)
    kern = kernels.Matern(1.5)
    idx = jnp.arange(40)
    fit0 = nystrom.fit_streaming(kern, x, y, 1e-3, idx, tile=None)
    tile = dispatch.resolve_tile("gram", x.shape[0], 40, 3, dtype=x.dtype)
    fit1 = nystrom.fit_streaming(kern, x, y, 1e-3, idx, tile=tile)
    assert np.array_equal(np.asarray(fit0.beta), np.asarray(fit1.beta))
    ptile = dispatch.resolve_tile("predict", x.shape[0], 40, 3, dtype=x.dtype)
    p0 = nystrom.predict_streaming(kern, fit0, x, tile=None)
    p1 = nystrom.predict_streaming(kern, fit0, x, tile=ptile)
    assert np.array_equal(np.asarray(p0), np.asarray(p1))


def test_gram_executable_cached_and_reused(tune_cache):
    """tile=None fits route the Gram pass through ONE plan-keyed compiled
    executable: the second same-shape fit reuses it (no new cache entry),
    and an explicit-tile fit never populates the cache."""
    from repro.tuning import autotune as at
    x, y = _data(seed=4)
    kern = kernels.Matern(1.5)
    idx = jnp.arange(40)
    at._EXECUTABLES.clear()
    fit0 = nystrom.fit_streaming(kern, x, y, 1e-3, idx, tile=None)
    assert len(at._EXECUTABLES) == 1
    fn = next(iter(at._EXECUTABLES.values()))
    fit1 = nystrom.fit_streaming(kern, x, y, 1e-3, idx, tile=None)
    assert len(at._EXECUTABLES) == 1
    assert next(iter(at._EXECUTABLES.values())) is fn
    assert np.array_equal(np.asarray(fit0.beta), np.asarray(fit1.beta))
    nystrom.fit_streaming(kern, x, y, 1e-3, idx, tile=512)
    assert len(at._EXECUTABLES) == 1   # explicit tile stays eager


def test_pipeline_autotune_config_runs(tune_cache):
    """PipelineConfig(tile=None, autotune=True) fits end to end and records
    the same artifacts as a pinned-tile config (same seed, same numerics —
    n is small enough that both resolve to a one-shot slab)."""
    from repro.pipeline.api import PipelineConfig, SAKRRPipeline
    x, y = _data(n=1500, seed=3)
    auto = SAKRRPipeline(PipelineConfig(num_landmarks=32)).fit(x, y)
    assert auto.config.tile is None
    pinned = SAKRRPipeline(
        PipelineConfig(num_landmarks=32, tile=1 << 11)).fit(x, y)
    np.testing.assert_allclose(np.asarray(auto.state.fit.beta),
                               np.asarray(pinned.state.fit.beta),
                               rtol=1e-5)
    measured = SAKRRPipeline(
        PipelineConfig(num_landmarks=32, autotune=True)).fit(x, y)
    assert measured.state.fit is not None
    assert os.path.exists(str(tune_cache))
