"""CalibrateStage subsystem: multi-lam solve parity vs per-lam fit loops
(weighted and unweighted), shared-deposit KDE parity vs per-h `kde_binned`,
the calibrate fold's grid/rewrite contract, and (forced 2 devices) that the
sweep under a mesh accumulates ONE Gram per bandwidth and ONE deposit total.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kde, kernels as K, nystrom
from repro.data import krr_data
from repro.pipeline import (CalibrateStage, PipelineConfig, SAKRRPipeline,
                            StageContext)
from repro.pipeline.stages import DEFAULT_H_FACTORS, DEFAULT_LAM_FACTORS

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LAMS = [1e-2, 1e-3, 1e-4, 1e-5]


def _data(n=2048, d=3, seed=0):
    return krr_data.bimodal(jax.random.PRNGKey(seed), n, d=d)


def _landmarks(n, m=64):
    return jnp.arange(0, n, n // m)[:m]


# ------------------------------------------------------------ multi-lam fit --

def test_solve_normal_eq_multi_bit_matches_single():
    data = _data()
    kern = K.Matern(nu=1.5)
    idx = _landmarks(2048)
    xm = data.x[idx]
    g, rhs = nystrom.streaming_normal_eq(kern, data.x, data.y, xm, tile=512)
    k_mm = K.kernel_matrix(kern, xm).astype(g.dtype)
    betas = nystrom.solve_normal_eq_multi(g, rhs, k_mm, 2048, LAMS)
    assert betas.shape == (len(LAMS), 64)
    for i, lam in enumerate(LAMS):
        want = nystrom.solve_normal_eq(g, rhs, k_mm, 2048, lam)
        np.testing.assert_array_equal(np.asarray(betas[i]), np.asarray(want))


@pytest.mark.parametrize("weighted", [False, True])
def test_fit_streaming_multi_bit_matches_per_lam_loop(weighted):
    """One shared Gram accumulation + per-lam whitened solves must be
    bit-equal to L independent fit_streaming calls (same op sequence)."""
    data = _data(seed=1)
    kern = K.Matern(nu=1.5)
    idx = _landmarks(2048)
    w = (1.0 + jnp.arange(64, dtype=jnp.float32) / 16.0) if weighted else None
    fits = nystrom.fit_streaming_multi(kern, data.x, data.y, LAMS, idx,
                                       tile=512, weights=w)
    assert [f.lam for f in fits] == LAMS
    for lam, fit in zip(LAMS, fits):
        ref = nystrom.fit_streaming(kern, data.x, data.y, lam, idx,
                                    tile=512, weights=w)
        np.testing.assert_array_equal(np.asarray(fit.beta),
                                      np.asarray(ref.beta))


def test_predict_streaming_multi_matches_per_fit():
    data = _data(seed=2)
    kern = K.Matern(nu=1.5)
    idx = _landmarks(2048)
    fits = nystrom.fit_streaming_multi(kern, data.x, data.y, LAMS, idx,
                                       tile=512)
    preds = nystrom.predict_streaming_multi(kern, fits, data.x[:300],
                                            tile=128)
    assert preds.shape == (len(LAMS), 300)
    for i, fit in enumerate(fits):
        want = np.asarray(nystrom.predict_streaming(kern, fit, data.x[:300],
                                                    tile=128))
        np.testing.assert_allclose(np.asarray(preds[i]), want,
                                   rtol=1e-5, atol=1e-6)


# --------------------------------------------------------- shared-deposit --

def test_kde_binned_multi_matches_per_h():
    """One CIC deposit + per-h FFT/gather == independent kde_binned calls
    pinned to the same (max-h) grid bounds."""
    data = _data(n=4096, seed=3)
    hs = [0.12, 0.2, 0.35]
    multi = kde.kde_binned_multi(data.x, data.x, hs, grid_size=64)
    assert multi.shape == (3, 4096)
    lo, hi = kde.binned_bounds(data.x, data.x,
                               jnp.asarray(max(hs), data.x.dtype))
    for i, h in enumerate(hs):
        single = kde.kde_binned(data.x, data.x, h, grid_size=64, lo=lo, hi=hi)
        np.testing.assert_allclose(np.asarray(multi[i]), np.asarray(single),
                                   rtol=1e-5, atol=1e-12)


def test_kde_binned_default_bounds_unchanged():
    """The single-h public entry must still use its own +-4h bounds (the
    pre-refactor contract) when lo/hi are not pinned."""
    data = _data(n=2048, seed=4)
    got = kde.kde_binned(data.x, data.x, 0.25, grid_size=96)
    ref = kde.kde_direct(data.x, data.x, 0.25)
    rel = np.abs(np.asarray(got) - np.asarray(ref)) / (np.asarray(ref) + 1e-9)
    assert np.quantile(rel, 0.9) < 0.05


# ------------------------------------------------------------- stage fold --

def test_calibrate_stage_contract():
    """Grid sizes, exactly one best record, lam/bandwidth rewritten to the
    winner, downstream artifacts invalidated, per-h seconds recorded."""
    data = _data(n=2048, seed=5)
    cfg = PipelineConfig(num_landmarks=48, tile=512)
    ctx = StageContext(config=cfg, kernel=cfg.build_kernel(), x=data.x,
                       y=data.y, n=2048, d=3, lam=cfg.resolve_lam(2048),
                       num_landmarks=48)
    lam0 = ctx.lam
    CalibrateStage()(ctx)
    n_cand = len(DEFAULT_LAM_FACTORS) * len(DEFAULT_H_FACTORS)
    assert len(ctx.cv_scores) == n_cand
    best = [r for r in ctx.cv_scores if r["best"]]
    assert len(best) == 1
    assert best[0]["val_mse"] == min(r["val_mse"] for r in ctx.cv_scores)
    assert ctx.cv_best["lam"] == best[0]["lam"] == ctx.lam
    assert ctx.cv_best["bandwidth"] == best[0]["h"] == ctx.bandwidth
    # the swept lam grid brackets the paper-rate reference
    assert any(abs(r["lam"] - lam0) < 1e-12 for r in ctx.cv_scores)
    # downstream artifacts were invalidated for the calibrated refit
    assert ctx.densities is None and ctx.fit is None
    per_h = [k for k in ctx.seconds if k.startswith("calibrate[h=")]
    assert len(per_h) == len(DEFAULT_H_FACTORS)
    assert "calibrate" in ctx.seconds and "calibrate[kde]" in ctx.seconds


def test_calibrate_stage_explicit_grids_and_fraction():
    data = _data(n=1024, seed=6)
    cfg = PipelineConfig(num_landmarks=32, tile=256)
    ctx = StageContext(config=cfg, kernel=cfg.build_kernel(), x=data.x,
                       y=data.y, n=1024, d=3, lam=cfg.resolve_lam(1024),
                       num_landmarks=32)
    stage = CalibrateStage(lam_grid=[1e-3, 1e-4], h_grid=[0.2],
                           val_fraction=0.5)
    stage(ctx)
    assert len(ctx.cv_scores) == 2
    assert {r["h"] for r in ctx.cv_scores} == {0.2}
    assert ctx.lam in (1e-3, 1e-4) and ctx.bandwidth == 0.2


def test_config_grids_feed_calibrate_stage():
    data = _data(n=1024, seed=7)
    cfg = PipelineConfig(num_landmarks=32, tile=256,
                         lam_grid=(1e-3, 1e-4, 1e-5), h_grid=(0.15, 0.3))
    ctx = StageContext(config=cfg, kernel=cfg.build_kernel(), x=data.x,
                       y=data.y, n=1024, d=3, lam=cfg.resolve_lam(1024),
                       num_landmarks=32)
    CalibrateStage()(ctx)
    assert len(ctx.cv_scores) == 6
    assert {r["lam"] for r in ctx.cv_scores} == {1e-3, 1e-4, 1e-5}


def test_pipeline_calibrate_end_to_end():
    """SAKRRPipeline.calibrate: sweep + full refit at the winner in one
    fold; the calibrated in-sample risk must not lose to the paper-rate
    default by more than noise (both evaluated on the same points)."""
    data = _data(n=4096, seed=8)
    cfg = PipelineConfig(num_landmarks=96, tile=1024)
    pipe = SAKRRPipeline(cfg)
    out = pipe.calibrate(data.x, data.y, f_star=data.f_star)
    assert set(out) >= {"lam", "bandwidth", "val_mse", "cv_scores", "scores"}
    assert pipe.state.lam == out["lam"]
    assert pipe.state.cv_best["lam"] == out["lam"]
    assert pipe.state.fit is not None and pipe.state.fit.lam == out["lam"]
    assert "risk" in out["scores"]
    ref = SAKRRPipeline(cfg).evaluate(data.x, data.y, f_star=data.f_star)
    assert out["scores"]["risk"] <= ref["risk"] * 1.5


def test_calibrate_shares_one_gumbel_race_across_h():
    """The bandwidth grid's landmark draws must all receive the SAME
    precomputed Gumbel race (gumbel=), so the h axis of the sweep carries
    zero sampling noise (ROADMAP gap (e)): candidates differ only through
    their density-driven probs."""
    from repro.core import sampling as sampling_mod
    from repro.pipeline import stages as stages_mod

    data = _data(n=1024, seed=9)
    cfg = PipelineConfig(num_landmarks=32, tile=256, h_grid=(0.15, 0.3, 0.6))
    ctx = StageContext(config=cfg, kernel=cfg.build_kernel(), x=data.x,
                       y=data.y, n=1024, d=3, lam=cfg.resolve_lam(1024),
                       num_landmarks=32)
    seen: list = []
    real = sampling_mod.sample_weighted_without_replacement

    def spy(key, probs, m, **kw):
        seen.append(kw.get("gumbel"))
        return real(key, probs, m, **kw)

    stages_mod.sampling.sample_weighted_without_replacement = spy
    try:
        CalibrateStage()(ctx)
    finally:
        stages_mod.sampling.sample_weighted_without_replacement = real
    assert len(seen) == 3                      # one draw per h candidate
    assert all(g is not None for g in seen)    # explicit shared race
    assert all(g is seen[0] for g in seen)     # the SAME noise object


# ------------------------------------------------------------ mesh sharing --

def test_calibrate_fold_under_mesh_shares_gram_and_deposit():
    """Forced 2 devices: the whole (lam x h) sweep must run ONE deposit
    (and its single grid psum) total and ONE Gram accumulation (its psum)
    per bandwidth — not one per candidate — and still match the unsharded
    fold's selection."""
    body = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.data import krr_data
        from repro.distributed import sharding as shd
        from repro.kernels import dispatch
        from repro.pipeline import CalibrateStage, PipelineConfig, StageContext

        assert jax.device_count() == 2
        n = 2048
        data = krr_data.bimodal(jax.random.PRNGKey(0), n, d=3)
        cfg = PipelineConfig(num_landmarks=48, tile=512,
                             lam_grid=(1e-3, 1e-4, 1e-5), h_grid=(0.15, 0.3))

        def ctx():
            return StageContext(config=cfg, kernel=cfg.build_kernel(),
                                x=data.x, y=data.y, n=n, d=3,
                                lam=cfg.resolve_lam(n), num_landmarks=48)

        # val_fraction 0.25 -> n_tr = 1536 already divides the 2-device
        # mesh, so sharded and unsharded runs see the IDENTICAL split (the
        # stage would otherwise grow the holdout to restore divisibility and
        # the folds would not be comparable candidate-by-candidate)
        stage = lambda: CalibrateStage(val_fraction=0.25)

        counts = {"gram": 0, "scatter": 0}
        real_gram = dispatch.gram_accumulate
        real_scatter = dispatch.binned_scatter
        def gram(*a, **k):
            counts["gram"] += 1
            return real_gram(*a, **k)
        def scatter(*a, **k):
            counts["scatter"] += 1
            return real_scatter(*a, **k)
        dispatch.gram_accumulate = gram
        dispatch.binned_scatter = scatter

        c_ref = ctx(); stage()(c_ref)
        ref_counts = dict(counts)
        counts.update(gram=0, scatter=0)
        mesh = jax.make_mesh((2,), ("data",))
        with mesh, shd.activate(mesh):
            c_sh = ctx(); stage()(c_sh)

        # shared work: one Gram stream per h (2), one deposit for the sweep
        assert counts["gram"] == 2, counts
        assert counts["scatter"] == 1, counts
        assert ref_counts == counts, (ref_counts, counts)

        # identical fold -> per-candidate scores differ only by psum
        # reduction order, and the winner's quality matches
        for a, b in zip(c_ref.cv_scores, c_sh.cv_scores):
            np.testing.assert_allclose(a["val_mse"], b["val_mse"],
                                       rtol=1e-3)
        np.testing.assert_allclose(c_sh.cv_best["val_mse"],
                                   c_ref.cv_best["val_mse"], rtol=1e-3)
        print("CALIBRATE_MESH_OK")
    """
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=2")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         capture_output=True, text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "CALIBRATE_MESH_OK" in out.stdout
