"""Split-precision Gram contraction (`repro.core.precision`): the Ozaki
fixed-point bf16 slicing, the fp32 bit-parity contract, per-mode kernel
parity on the EXACT_DIST_D-sensitive Matern-1/2 cluster, joint
(tile, precision) plan resolution, and the PR 7 acceptance bar — a
bf16x3+compensated Gram at n >= 1e5 lands >= 10x closer to the f64
reference than the plain fp32 stream."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tuning
from repro.core import kernels as K, nystrom, precision
from repro.core.kernels import kernel_matrix
from repro.kernels import dispatch


@pytest.fixture()
def tune_cache(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(path))
    tuning.set_measure(None)
    tuning.clear_cache()
    yield path
    tuning.clear_cache()
    tuning.set_measure(None)


# ------------------------------------------------------------------ slicing --

def test_split_words_are_exact_bf16_grid_multiples():
    """Every word is an integer multiple of a power-of-two step in
    [-2^8, 2^8] — the bf16 cast loses nothing, and the step refines by
    2^-8 per word."""
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 64), jnp.float32)
    words = precision.split_words(x, 3, axis=(0,))
    amax = np.abs(np.asarray(x)).max(axis=0, keepdims=True)
    step = np.exp2(np.floor(np.log2(amax)) - 7.0)
    for w in words:
        w32 = np.asarray(w, np.float32)
        units = w32 / step
        np.testing.assert_array_equal(units, np.rint(units))
        assert np.abs(units).max() <= 256.0
        step = step * 2.0 ** -8


def test_split_words_reconstruction_residual():
    """The fp32 sum of the words reconstructs x to half the last grid step:
    ~amax * 2^-17 for two words, ~amax * 2^-25 for three (absolute,
    fixed-point — not per-element relative)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (512, 8), jnp.float32)
    amax = np.abs(np.asarray(x)).max(axis=0)
    for words, bound in ((2, 2.0 ** -16), (3, 2.0 ** -24)):
        parts = precision.split_words(x, words, axis=(0,))
        recon = sum(np.asarray(p, np.float64) for p in parts)
        err = np.abs(recon - np.asarray(x, np.float64)).max(axis=0)
        assert (err <= bound * amax).all(), (words, (err / amax).max())


def test_split_partials_exact_for_short_contractions():
    """For contractions <= 256 elements each bf16 x bf16 partial matmul is
    EXACT in fp32 accumulation (integer grid sums below 2^24 steps) — the
    partials match an f64 evaluation bitwise."""
    a = jax.random.normal(jax.random.PRNGKey(2), (256, 24), jnp.float32)
    b = jax.random.uniform(jax.random.PRNGKey(3), (256, 16), jnp.float32)
    dims = (((0,), (0,)), ((), ()))
    parts = precision.split_dot_partials(a, b, dims, "bf16x3")
    aw = precision.split_words(a, 3, axis=(0,))
    bw = precision.split_words(b, 3, axis=(0,))
    for part, (p, q) in zip(parts, precision._PAIRS[3]):
        ref = (np.asarray(aw[p], np.float64).T @ np.asarray(bw[q], np.float64))
        np.testing.assert_array_equal(np.asarray(part, np.float64), ref)


def test_fp32_split_dot_is_bitwise_lax_dot_general():
    a = jax.random.normal(jax.random.PRNGKey(4), (333, 17), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(5), (333, 9), jnp.float32)
    dims = (((0,), (0,)), ((), ()))
    got = precision.split_dot(a, b, dims, precision="fp32")
    ref = jax.lax.dot_general(a, b, dims,
                              preferred_element_type=jnp.float32)
    assert np.array_equal(np.asarray(got), np.asarray(ref))


def test_split_dot_accuracy_ladder():
    """bf16x3 beats fp32; bf16x2 sits within its ~2^-16 documented floor."""
    a = jax.random.normal(jax.random.PRNGKey(6), (256, 32), jnp.float32)
    dims = (((0,), (0,)), ((), ()))
    ref = np.asarray(a, np.float64).T @ np.asarray(a, np.float64)
    scale = np.abs(ref).max()

    def err(p):
        g = precision.split_dot(a, a, dims, precision=p)
        return np.abs(np.asarray(g, np.float64) - ref).max() / scale

    e32, e2, e3 = err("fp32"), err("bf16x2"), err("bf16x3")
    # On ONE small dot fp32 is already near-exact; bf16x3 must sit at the
    # same ~2^-20 level (its >= 10x advantage appears on long streams,
    # locked by the slow acceptance test below).
    assert e3 <= max(4.0 * e32, 2.0 ** -20)
    assert e2 <= 2.0 ** -13   # headroom on the ~2^-16 fixed-point floor
    with pytest.raises(ValueError):
        precision.check("fp16x9")


# ------------------------------------------------- EXACT_DIST_D clustering --

def _clustered(n=512, m=32):
    base = jnp.full((n, 1), 0.5, jnp.float32)
    off = jax.random.uniform(jax.random.PRNGKey(3), (n, 1), jnp.float32) * 1e-3
    x = base + off
    return x, x[:m]


@pytest.mark.parametrize("prec,tol", [("fp32", 1e-5), ("bf16x2", 5e-4),
                                      ("bf16x3", 2e-6)])
def test_matern_half_r_to_zero_parity_per_precision(prec, tol, tune_cache):
    """Matern-1/2, d=1, r -> 0 cluster: the split must ride the SAME
    per-coordinate EXACT_DIST_D distances as fp32 (only kernel VALUES are
    sliced), so every mode keeps near-origin accuracy on both backends.
    bf16x3 is in fact tighter than fp32 here (exact partial accumulation);
    bf16x2's fixed-point floor stays orders below its 5e-4 gate."""
    x, xm = _clustered()
    kern = K.Matern(nu=0.5)
    w = jnp.ones((x.shape[0],))
    ktile = jax.jit(lambda xt: kernel_matrix(kern, xt, xm))
    ref = np.zeros((xm.shape[0],) * 2, np.float64)
    for i in range(0, x.shape[0], 128):
        kk = np.asarray(ktile(x[i:i + 128]), np.float64)
        ref += kk.T @ kk
    scale = np.abs(ref).max()
    gx, _ = dispatch.gram_accumulate(kern, x, xm, w, tile=128, backend="xla",
                                     accumulator="compensated",
                                     precision=prec)
    gp, _ = dispatch.gram_accumulate(kern, x, xm, w, backend="pallas",
                                     interpret=True, bm=128, bn=32,
                                     accumulator="compensated",
                                     precision=prec)
    for g in (gx, gp):
        err = np.abs(np.asarray(g, np.float64) - ref).max() / scale
        assert err <= tol, (prec, err)


# -------------------------------------------------------------- acceptance --

@pytest.mark.slow
def test_bf16x3_gram_10x_tighter_than_plain_fp32():
    """PR 7 acceptance bar (PR 5 harness): at n >= 1e5 the bf16x3 split
    with the compensated accumulator lands >= 10x closer to the f64
    accumulation of the same f32 kernel tiles than the plain fp32 stream —
    the fixed-point slices make every within-tile partial matmul exact, so
    only the (compensated) cross-tile floor remains."""
    n, m, d, tile = 131072, 64, 3, 256
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d), jnp.float32)
    xm = x[:m]
    kern = K.Matern(nu=1.5)
    w = jnp.zeros((n,))
    gp, _ = nystrom.scan_normal_eq(kern, x, xm, w, tile=tile)
    g3, _ = nystrom.scan_normal_eq(kern, x, xm, w, tile=tile,
                                   accumulator="compensated",
                                   precision="bf16x3")
    tiles = jax.jit(lambda xt: kernel_matrix(kern, xt, xm))
    ref = np.zeros((m, m), np.float64)
    for i in range(n // tile):
        k = np.asarray(tiles(x[i * tile:(i + 1) * tile]), np.float64)
        ref += k.T @ k
    scale = np.abs(ref).max()
    err_plain = np.abs(np.asarray(gp, np.float64) - ref).max() / scale
    err_b3 = np.abs(np.asarray(g3, np.float64) - ref).max() / scale
    assert err_b3 * 10 <= err_plain, (err_plain, err_b3)


# ------------------------------------------------------------------- plans --

def test_joint_plan_resolution_and_pinning(tune_cache):
    """precision=None on the gram op resolves (tile, precision) jointly:
    the chosen mode comes from AUTO_PRECISIONS (fp32 on CPU, where bf16
    emulation is a modeled slowdown); pinned modes are echoed back and key
    separately; non-gram ops always plan fp32."""
    auto = tuning.plan_for("gram", 262144, 320, 3, precision=None)
    assert auto.precision in tuning.autotune.AUTO_PRECISIONS
    if jax.devices()[0].platform == "cpu":
        assert auto.precision == "fp32"
    pinned = tuning.plan_for("gram", 262144, 320, 3, precision="bf16x3")
    assert pinned.precision == "bf16x3"
    assert tuning.shape_key("gram", 262144, 320, 3, precision="auto") \
        != tuning.shape_key("gram", 262144, 320, 3, precision="bf16x3")
    dep = tuning.plan_for("deposit", 262144, 96, 3, precision=None)
    assert dep.precision == "fp32"
    with pytest.raises(ValueError):
        tuning.plan_for("gram", 1024, 32, 3, precision="fp64")


def test_joint_plan_persisted_in_cache(tune_cache):
    """The jointly-chosen precision survives the disk round trip."""
    from repro.tuning import autotune
    a = tuning.plan_for("gram", 8192, 64, 3, precision=None)
    autotune._MEMORY.clear()
    autotune._DISK_LOADED = False
    b = tuning.plan_for("gram", 8192, 64, 3, precision=None)
    assert b.source == "cache"
    assert (b.tile, b.precision) == (a.tile, a.precision)


def test_explicit_tile_defaults_to_fp32_bit_parity(tune_cache):
    """A pinned-tile call with precision unspecified must stay bit-equal to
    pre-precision code: it resolves to the historical fp32 single dot."""
    x = jax.random.normal(jax.random.PRNGKey(7), (2048, 3), jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(8), (2048,), jnp.float32)
    kern = K.Matern(nu=1.5)
    idx = jnp.arange(48)
    a = nystrom.fit_streaming(kern, x, y, 1e-3, idx, tile=512)
    b = nystrom.fit_streaming(kern, x, y, 1e-3, idx, tile=512,
                              precision="fp32")
    assert np.array_equal(np.asarray(a.beta), np.asarray(b.beta))


# ---------------------------------------------------------------- end to end --

def test_fit_streaming_bf16x3_matches_fp32(tune_cache):
    x = jax.random.normal(jax.random.PRNGKey(9), (4096, 3), jnp.float32)
    y = jnp.sin(x[:, 0]) + 0.1 * jax.random.normal(jax.random.PRNGKey(10),
                                                   (4096,), jnp.float32)
    kern = K.Matern(nu=1.5)
    idx = jnp.arange(64)
    f32 = nystrom.fit_streaming(kern, x, y, 1e-3, idx, tile=512,
                                accumulator="compensated")
    b3 = nystrom.fit_streaming(kern, x, y, 1e-3, idx, tile=512,
                               precision="bf16x3", accumulator="compensated")
    # beta lives partly in near-cutoff whitened directions that a ~1e-7
    # Gram perturbation can rotate; the function-space prediction is the
    # stable comparison, beta only gets a coarse absolute gate.
    np.testing.assert_allclose(np.asarray(b3.beta), np.asarray(f32.beta),
                               atol=5e-3)
    p32 = nystrom.predict_streaming(kern, f32, x[:256], tile=128)
    p3a = nystrom.predict_streaming(kern, b3, x[:256], tile=128)
    p3b = nystrom.predict_streaming(kern, b3, x[:256], tile=128,
                                    precision="bf16x3")
    np.testing.assert_allclose(np.asarray(p3a), np.asarray(p32), rtol=1e-3,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(p3b), np.asarray(p3a), rtol=1e-3,
                               atol=1e-4)


def test_pipeline_config_precision_end_to_end(tune_cache):
    """PipelineConfig(precision=...) threads through solve/predict/score."""
    from repro.data import krr_data
    from repro.pipeline import PipelineConfig, SAKRRPipeline
    data = krr_data.bimodal(jax.random.PRNGKey(11), 2048, d=3)
    ref = SAKRRPipeline(PipelineConfig(num_landmarks=64, tile=512,
                                       accumulator="compensated"))
    sc_ref = ref.evaluate(data.x, data.y, f_star=data.f_star)
    b3 = SAKRRPipeline(PipelineConfig(num_landmarks=64, tile=512,
                                      precision="bf16x3",
                                      accumulator="compensated"))
    sc_b3 = b3.evaluate(data.x, data.y, f_star=data.f_star)
    assert set(sc_b3) >= {"mse", "rmse", "risk"}
    np.testing.assert_allclose(sc_b3["rmse"], sc_ref["rmse"], rtol=5e-2)
    np.testing.assert_allclose(sc_b3["risk"], sc_ref["risk"], rtol=0.5,
                               atol=1e-6)
