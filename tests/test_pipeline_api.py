"""repro.pipeline: config round-trip, end-to-end fit/predict quality, and
the O(tile · m) memory contract surface (tile invariance)."""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import krr, nystrom
from repro.data import krr_data
from repro.pipeline import PipelineConfig, SAKRRPipeline


def test_config_roundtrip_and_defaults():
    cfg = PipelineConfig(nu=2.5, tile=1024, num_landmarks=64)
    again = PipelineConfig.from_dict(cfg.to_dict())
    assert again == cfg
    n = 8000
    assert cfg.resolve_lam(n) == pytest.approx(0.075 * n ** (-2.0 / 3.0))
    assert PipelineConfig().resolve_num_landmarks(n) == int(5 * n ** (1 / 3))
    assert PipelineConfig(kernel_kind="gaussian", sigma=0.5).build_kernel().sigma == 0.5
    with pytest.raises(ValueError):
        PipelineConfig(kernel_kind="laplace").build_kernel()


def test_config_json_roundtrip_restores_tuples():
    """JSON turns lam_grid/h_grid tuples into lists; from_dict must restore
    them (frozen-dataclass equality/hash) — the servable-artifact contract."""
    cfg = PipelineConfig(lam_grid=(1e-3, 1e-2), h_grid=(0.1, 0.2, 0.4),
                         num_landmarks=64)
    again = PipelineConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert again == cfg
    assert hash(again) == hash(cfg)
    assert isinstance(again.lam_grid, tuple)
    assert isinstance(again.h_grid, tuple)
    # None grids stay None through the round trip
    none_cfg = PipelineConfig()
    assert PipelineConfig.from_dict(
        json.loads(json.dumps(none_cfg.to_dict()))) == none_cfg


def test_config_from_dict_rejects_unknown_keys():
    d = PipelineConfig().to_dict()
    d["not_a_field"] = 1
    with pytest.raises(ValueError, match="unknown PipelineConfig key"):
        PipelineConfig.from_dict(d)


def test_predict_is_reentrant_and_preserves_evaluate_state():
    """Regression: predict used to fold over the SAVED fitted context,
    clobbering evaluate()'s scores/predictions and letting interleaved
    predict calls corrupt each other via the shared snapshot."""
    data = krr_data.bimodal(jax.random.PRNGKey(7), 1024, d=3)
    pipe = SAKRRPipeline(PipelineConfig(num_landmarks=48, tile=512))
    scores = pipe.evaluate(data.x, data.y, f_star=data.f_star)
    eval_preds = np.asarray(pipe.state.predictions) \
        if pipe.state.predictions is not None else None

    qa = data.x[:100]
    qb = data.x[100:150] + 0.25
    pa_first = np.asarray(pipe.predict(qa))
    pb = np.asarray(pipe.predict(qb))
    pa_second = np.asarray(pipe.predict(qa))
    # interleaved predicts are independent: same query, same answer
    np.testing.assert_array_equal(pa_first, pa_second)
    assert pb.shape == (50,)
    # the evaluate() snapshot survives any number of predicts
    assert pipe.state.scores == scores
    if eval_preds is not None:
        np.testing.assert_array_equal(
            np.asarray(pipe.state.predictions), eval_preds)


def test_pipeline_fit_quality_bimodal():
    """End-to-end risk well under the 0.25 noise floor (paper's setting)."""
    n = 8192
    data = krr_data.bimodal(jax.random.PRNGKey(0), n, d=3)
    pipe = SAKRRPipeline(PipelineConfig(tile=2048)).fit(data.x, data.y)
    assert set(pipe.seconds) == {"kde", "leverage", "sample", "solve"}
    risk = float(krr.in_sample_risk(pipe.fitted(data.x), data.f_star))
    assert risk < 0.05, risk
    assert pipe.d_stat > 1.0
    # predict runs through the same stage fold, so it times itself too
    assert set(pipe.seconds) == {"kde", "leverage", "sample", "solve",
                                 "predict"}
    assert all(v >= 0.0 for v in pipe.seconds.values())


def test_pipeline_tile_invariance():
    """The tile size is an execution detail: results must not depend on it
    beyond fp32 reduction order."""
    n = 2048
    data = krr_data.bimodal(jax.random.PRNGKey(1), n, d=3)
    cfgs = [PipelineConfig(tile=t, num_landmarks=48, seed=3) for t in (256, 2048)]
    preds = []
    for cfg in cfgs:
        pipe = SAKRRPipeline(cfg).fit(data.x, data.y)
        preds.append(np.asarray(pipe.predict(data.x[:400])))
    # fp32 reduction order shifts the solve's spectral cutoff slightly;
    # predictions stay within ~1e-3 absolute on O(1)-scale targets.
    np.testing.assert_allclose(preds[0], preds[1], rtol=1e-2, atol=2e-3)


def test_pipeline_predict_matches_dense_nystrom():
    """The pipeline's solve is nystrom.fit_streaming on SA-sampled landmarks;
    its predictions must match the dense solve on the same landmarks."""
    n = 2048
    data = krr_data.bimodal(jax.random.PRNGKey(2), n, d=3)
    pipe = SAKRRPipeline(PipelineConfig(num_landmarks=64, tile=512)).fit(
        data.x, data.y)
    st = pipe.state
    dense = nystrom.fit_from_landmarks(pipe.kernel, data.x, data.y, st.lam,
                                       st.fit.landmark_idx)
    want = np.asarray(nystrom.predict(pipe.kernel, dense, data.x[:300]))
    got = np.asarray(pipe.predict(data.x[:300]))
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-3)


def test_pipeline_predict_before_fit_raises():
    with pytest.raises(RuntimeError):
        SAKRRPipeline().predict(jnp.zeros((4, 3)))


def test_pipeline_gaussian_kernel_path():
    n = 1500
    data = krr_data.bimodal(jax.random.PRNGKey(4), n, d=3)
    cfg = PipelineConfig(kernel_kind="gaussian", sigma=0.6, num_landmarks=48,
                         tile=512)
    pipe = SAKRRPipeline(cfg).fit(data.x, data.y)
    risk = float(krr.in_sample_risk(pipe.fitted(data.x), data.f_star))
    assert np.isfinite(risk) and risk < 0.25, risk


def test_pipeline_fit_under_active_mesh_matches_single_device():
    """The same fit call, inside an activated 2-device mesh, shards the
    solve rows on the 'data' axis and must match the unsharded run."""
    import os
    import subprocess
    import sys
    import textwrap

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = textwrap.dedent("""
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.data import krr_data
        from repro.distributed import sharding as shd
        from repro.pipeline import PipelineConfig, SAKRRPipeline
        assert jax.device_count() == 2
        data = krr_data.bimodal(jax.random.PRNGKey(0), 2048, d=3)
        cfg = PipelineConfig(num_landmarks=48, tile=512, seed=1)
        ref = SAKRRPipeline(cfg).fit(data.x, data.y).predict(data.x[:256])
        mesh = jax.make_mesh((2,), ("data",))
        with mesh, shd.activate(mesh):
            sh = SAKRRPipeline(cfg).fit(data.x, data.y).predict(data.x[:256])
        np.testing.assert_allclose(np.asarray(sh), np.asarray(ref),
                                   rtol=2e-2, atol=2e-3)
        print("PIPELINE_MESH_OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=2")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PIPELINE_MESH_OK" in out.stdout


def test_pipeline_state_is_small():
    """fit must keep only O(n) vectors and O(m) solve state — no (n, m)."""
    n = 1024
    data = krr_data.bimodal(jax.random.PRNGKey(5), n, d=3)
    pipe = SAKRRPipeline(PipelineConfig(num_landmarks=32, tile=256)).fit(
        data.x, data.y)
    st = pipe.state
    leaves = jax.tree.leaves((st.densities, tuple(st.leverage),
                              tuple(st.fit)))
    biggest = max(leaf.size for leaf in leaves if hasattr(leaf, "size"))
    assert biggest <= max(n, st.num_landmarks ** 2), biggest
