"""Stage layer: composition, artifact dependencies, drop-in stages, the
default-sampler switch (Gumbel top-k without replacement), and the
predict/score fold (stages past fit)."""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import krr, nystrom
from repro.data import krr_data
from repro.pipeline import (DensityStage, FixedLandmarkStage, LeverageStage,
                            PipelineConfig, PrecomputedDensityStage,
                            PredictStage, SAKRRPipeline, SampleStage,
                            ScoreStage, SolveStage, StageContext, StageError,
                            default_stages, evaluate_stages, run_stages)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_forced_devices(body: str, devices: int = 2) -> str:
    """Run a snippet in a subprocess with forced host devices (fast enough
    for tier-1: tiny n, single jit each)."""
    code = ("import jax\nimport jax.numpy as jnp\nimport numpy as np\n"
            + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _ctx(n=1024, d=3, m=32, seed=0):
    data = krr_data.bimodal(jax.random.PRNGKey(seed), n, d=d)
    cfg = PipelineConfig(num_landmarks=m, tile=256)
    return data, StageContext(config=cfg, kernel=cfg.build_kernel(),
                              x=data.x, y=data.y, n=n, d=d,
                              lam=cfg.resolve_lam(n), num_landmarks=m)


def test_default_stage_list_shape_and_seconds():
    stages = default_stages(None)
    assert [s.name for s in stages] == ["kde", "leverage", "sample", "solve"]
    _, ctx = _ctx()
    run_stages(stages, ctx)
    assert set(ctx.seconds) == {"kde", "leverage", "sample", "solve"}
    assert all(v >= 0.0 for v in ctx.seconds.values())
    assert ctx.fit is not None and ctx.fit.beta.shape == (32,)


def test_stage_requires_enforced():
    _, ctx = _ctx()
    with pytest.raises(StageError):
        LeverageStage()(ctx)            # no densities yet
    with pytest.raises(StageError):
        SolveStage()(ctx)               # no landmarks yet


def test_run_stages_until_stops_inclusive():
    _, ctx = _ctx()
    run_stages(default_stages(None), ctx, until="leverage")
    assert ctx.leverage is not None and ctx.landmark_idx is None
    assert set(ctx.seconds) == {"kde", "leverage"}


def test_precomputed_density_stage_drops_in():
    """A pipeline fed the exact densities must match one that runs its own
    KDE stage on those densities' values downstream (same leverage)."""
    data, ctx = _ctx(seed=1)
    run_stages([DensityStage()], ctx)
    dens = ctx.densities
    _, ctx2 = _ctx(seed=1)
    run_stages([PrecomputedDensityStage(dens), LeverageStage()], ctx2)
    _, ctx3 = _ctx(seed=1)
    run_stages([DensityStage(), LeverageStage()], ctx3)
    np.testing.assert_allclose(np.asarray(ctx2.leverage.probs),
                               np.asarray(ctx3.leverage.probs), rtol=1e-6)
    with pytest.raises(ValueError):
        run_stages([PrecomputedDensityStage(dens[:10])], _ctx(seed=1)[1])


def test_fixed_landmark_stage_skips_density_pipeline():
    data, ctx = _ctx(seed=2)
    idx = jnp.arange(0, 1024, 32)[:32]
    run_stages([FixedLandmarkStage(idx), SolveStage()], ctx)
    assert ctx.densities is None            # KDE never ran
    dense = nystrom.fit_from_landmarks(ctx.kernel, data.x, data.y, ctx.lam,
                                       idx)
    want = np.asarray(nystrom.predict(ctx.kernel, dense, data.x[:200]))
    got = np.asarray(nystrom.predict_streaming(ctx.kernel, ctx.fit,
                                               data.x[:200], tile=256))
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-3)


def test_pipeline_accepts_custom_stage_list():
    data = krr_data.bimodal(jax.random.PRNGKey(3), 1024, d=3)
    cfg = PipelineConfig(num_landmarks=32, tile=256)
    idx = jnp.arange(32, dtype=jnp.int32) * 7
    pipe = SAKRRPipeline(cfg, stages=[FixedLandmarkStage(idx), SolveStage()])
    pipe.fit(data.x, data.y)
    assert set(pipe.seconds) == {"sample", "solve"}
    risk = float(krr.in_sample_risk(pipe.fitted(data.x), data.f_star))
    assert np.isfinite(risk)
    with pytest.raises(RuntimeError):       # no leverage stage ran
        pipe.d_stat


def test_partial_pipeline_cannot_predict():
    data = krr_data.bimodal(jax.random.PRNGKey(4), 512, d=3)
    pipe = SAKRRPipeline(PipelineConfig(num_landmarks=16, tile=128),
                         stages=[DensityStage()])
    pipe.fit(data.x, data.y)
    assert pipe.state.densities is not None and pipe.state.fit is None
    with pytest.raises(RuntimeError):
        pipe.predict(data.x[:10])


def test_default_sampling_is_without_replacement():
    """Gumbel top-k landmarks are distinct and carry inverse-inclusion
    importance weights; the paper's iid mode stays behind the config flag."""
    data = krr_data.bimodal(jax.random.PRNGKey(5), 4096, d=3)
    cfg = PipelineConfig(num_landmarks=256, tile=1024)
    pipe = SAKRRPipeline(cfg).fit(data.x, data.y)
    idx = np.asarray(pipe.state.fit.landmark_idx)
    assert len(np.unique(idx)) == 256       # distinct by construction
    w = np.asarray(pipe.state.sample_weights)
    assert w.shape == (256,)
    # inverse inclusion probabilities: always >= 1, and not all saturated
    # at the certain-inclusion value (the SA probs are far from uniform)
    assert np.all(w >= 1.0)
    assert np.max(w) > 1.5

    wr = PipelineConfig(num_landmarks=256, tile=1024,
                        sample_with_replacement=True)
    pipe_wr = SAKRRPipeline(wr).fit(data.x, data.y)
    assert pipe_wr.state.sample_weights is None
    # with replacement at m=256 on concentrated SA probs: near-certain dups
    assert len(np.unique(np.asarray(pipe_wr.state.fit.landmark_idx))) <= 256


def test_per_stage_overrides_beat_config():
    """Stage constructor knobs (method/tile/backend) override the config."""
    data = krr_data.bimodal(jax.random.PRNGKey(6), 512, d=3)
    cfg = PipelineConfig(num_landmarks=16, tile=128, kde_method="binned")
    stages = [DensityStage(method="direct"), LeverageStage(), SampleStage(),
              SolveStage(tile=64)]
    pipe = SAKRRPipeline(cfg, stages=stages).fit(data.x, data.y)
    from repro.core import kde
    want = np.asarray(kde.kde_direct(data.x, data.x,
                                     kde.scott_bandwidth(data.x)))
    np.testing.assert_allclose(np.asarray(pipe.state.densities), want,
                               rtol=1e-5, atol=1e-9)


# ------------------------------------------------------- predict / score --

def test_predict_stage_matches_direct_predict_streaming():
    """PredictStage composed into the fold == nystrom.predict_streaming on
    the fitted state (bit-exact: same code path, same backend/tile)."""
    data, ctx = _ctx(seed=7)
    run_stages(evaluate_stages(None), ctx)
    assert ctx.predictions is not None and ctx.predictions.shape == (ctx.n,)
    want = np.asarray(nystrom.predict_streaming(
        ctx.kernel, ctx.fit, ctx.x, tile=ctx.config.tile))
    np.testing.assert_array_equal(np.asarray(ctx.predictions), want)
    # score stage saw the in-sample default targets
    assert set(ctx.scores) == {"mse", "rmse"}
    assert ctx.scores["rmse"] == pytest.approx(ctx.scores["mse"] ** 0.5)
    assert set(ctx.seconds) == {"kde", "leverage", "sample", "solve",
                                "predict", "score"}


def test_predict_stage_out_of_sample_and_score_targets():
    data, ctx = _ctx(seed=8)
    x_new = data.x[:100] + 0.01
    f_new = jnp.zeros((100,))
    run_stages(default_stages(None)
               + [PredictStage(x_eval=x_new), ScoreStage(f_star=f_new)], ctx)
    assert ctx.predictions.shape == (100,)
    assert "risk" in ctx.scores and "mse" not in ctx.scores  # no y_eval known
    want = np.asarray(nystrom.predict_streaming(
        ctx.kernel, ctx.fit, x_new, tile=ctx.config.tile))
    np.testing.assert_array_equal(np.asarray(ctx.predictions), want)


def test_score_stage_requires_targets_out_of_sample():
    data, ctx = _ctx(seed=9)
    stages = default_stages(None) + [PredictStage(x_eval=data.x[:50] + 1.0),
                                     ScoreStage()]
    with pytest.raises(StageError):
        run_stages(stages, ctx)


def test_score_stage_requires_predictions():
    _, ctx = _ctx(seed=10)
    with pytest.raises(StageError):
        ScoreStage()(ctx)


def test_pipeline_evaluate_one_fold():
    """SAKRRPipeline.evaluate: KDE->leverage->sample->solve->predict->score
    through one run_stages fold, timing every stage."""
    data = krr_data.bimodal(jax.random.PRNGKey(11), 4096, d=3)
    cfg = PipelineConfig(num_landmarks=128, tile=1024)
    pipe = SAKRRPipeline(cfg)
    scores = pipe.evaluate(data.x, data.y, f_star=data.f_star)
    assert set(scores) == {"mse", "rmse", "risk"}
    assert scores["risk"] < 0.05            # well under the 0.25 noise floor
    assert scores["mse"] > scores["risk"]   # mse carries the noise variance
    assert set(pipe.seconds) == {"kde", "leverage", "sample", "solve",
                                 "predict", "score"}
    # fused in-sample scoring: the solve banked the score moments in its own
    # row stream, so no predict pass ran and no predictions materialized
    assert pipe.state.predictions is None
    assert pipe.state.scores == scores


def test_weighted_solve_stage_matches_unweighted_predictor():
    """SolveStage(weighted=True) feeds ctx.sample_weights into the column-
    rescaled SoR solve; the predictor is invariant (exact arithmetic), so
    the two stage configurations must agree to fp32 whitening noise."""
    preds = []
    for weighted in (False, True):
        _, ctx = _ctx(seed=12)
        stages = [DensityStage(), LeverageStage(), SampleStage(),
                  SolveStage(weighted=weighted), PredictStage(), ScoreStage()]
        run_stages(stages, ctx)
        preds.append(np.asarray(ctx.predictions))
    np.testing.assert_allclose(preds[0], preds[1], atol=5e-2)


def test_evaluate_fold_under_forced_two_device_mesh():
    """The same evaluate() fold inside an activated 2-device mesh shards the
    solve and predict rows and must match the unsharded scores."""
    out = _run_forced_devices("""
        from repro.data import krr_data
        from repro.distributed import sharding as shd
        from repro.pipeline import PipelineConfig, SAKRRPipeline
        assert jax.device_count() == 2
        data = krr_data.bimodal(jax.random.PRNGKey(0), 2048, d=3)
        cfg = PipelineConfig(num_landmarks=48, tile=512, seed=1)
        ref = SAKRRPipeline(cfg).evaluate(data.x, data.y, f_star=data.f_star)
        mesh = jax.make_mesh((2,), ("data",))
        with mesh, shd.activate(mesh):
            sh = SAKRRPipeline(cfg).evaluate(data.x, data.y,
                                             f_star=data.f_star)
        assert set(sh) == {"mse", "rmse", "risk"}, sh
        for k in ref:
            np.testing.assert_allclose(sh[k], ref[k], rtol=2e-2, atol=1e-4)
        print("EVALUATE_MESH_OK")
    """)
    assert "EVALUATE_MESH_OK" in out


def test_kde_binned_sharded_d2_non_dividing_n_falls_back():
    """kde_binned_sharded at d=2 with n not divisible by the mesh size must
    degrade to the exact single-device computation (no collective)."""
    out = _run_forced_devices("""
        from repro.core import distributed as D
        from repro.data import krr_data
        from repro.distributed import sharding as shd
        n, d, h = 2047, 2, 0.25          # 2047 odd: 2-device mesh cannot split
        data = krr_data.bimodal(jax.random.PRNGKey(3), n, d=d)
        lo = jnp.full((d,), -5.0); hi = jnp.full((d,), 5.0)
        ref = D.kde_binned_sharded(data.x, h, grid_size=64, lo=lo, hi=hi)
        mesh = jax.make_mesh((2,), ("data",))
        with mesh, shd.activate(mesh):
            sh = D.kde_binned_sharded(data.x, h, grid_size=64, lo=lo, hi=hi)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(sh))
        # and an even n=2048 run on the same grid agrees to psum tolerance
        x_even = data.x[:2046]
        ref_e = D.kde_binned_sharded(x_even, h, grid_size=64, lo=lo, hi=hi)
        with mesh, shd.activate(mesh):
            sh_e = D.kde_binned_sharded(x_even, h, grid_size=64, lo=lo, hi=hi)
        np.testing.assert_allclose(np.asarray(ref_e), np.asarray(sh_e),
                                   rtol=2e-4, atol=1e-7)
        print("KDE_FALLBACK_OK")
    """)
    assert "KDE_FALLBACK_OK" in out
