"""Stage layer: composition, artifact dependencies, drop-in stages, and the
default-sampler switch (Gumbel top-k without replacement)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import krr, nystrom
from repro.data import krr_data
from repro.pipeline import (DensityStage, FixedLandmarkStage, LeverageStage,
                            PipelineConfig, PrecomputedDensityStage,
                            SAKRRPipeline, SampleStage, SolveStage,
                            StageContext, StageError, default_stages,
                            run_stages)


def _ctx(n=1024, d=3, m=32, seed=0):
    data = krr_data.bimodal(jax.random.PRNGKey(seed), n, d=d)
    cfg = PipelineConfig(num_landmarks=m, tile=256)
    return data, StageContext(config=cfg, kernel=cfg.build_kernel(),
                              x=data.x, y=data.y, n=n, d=d,
                              lam=cfg.resolve_lam(n), num_landmarks=m)


def test_default_stage_list_shape_and_seconds():
    stages = default_stages(None)
    assert [s.name for s in stages] == ["kde", "leverage", "sample", "solve"]
    _, ctx = _ctx()
    run_stages(stages, ctx)
    assert set(ctx.seconds) == {"kde", "leverage", "sample", "solve"}
    assert all(v >= 0.0 for v in ctx.seconds.values())
    assert ctx.fit is not None and ctx.fit.beta.shape == (32,)


def test_stage_requires_enforced():
    _, ctx = _ctx()
    with pytest.raises(StageError):
        LeverageStage()(ctx)            # no densities yet
    with pytest.raises(StageError):
        SolveStage()(ctx)               # no landmarks yet


def test_run_stages_until_stops_inclusive():
    _, ctx = _ctx()
    run_stages(default_stages(None), ctx, until="leverage")
    assert ctx.leverage is not None and ctx.landmark_idx is None
    assert set(ctx.seconds) == {"kde", "leverage"}


def test_precomputed_density_stage_drops_in():
    """A pipeline fed the exact densities must match one that runs its own
    KDE stage on those densities' values downstream (same leverage)."""
    data, ctx = _ctx(seed=1)
    run_stages([DensityStage()], ctx)
    dens = ctx.densities
    _, ctx2 = _ctx(seed=1)
    run_stages([PrecomputedDensityStage(dens), LeverageStage()], ctx2)
    _, ctx3 = _ctx(seed=1)
    run_stages([DensityStage(), LeverageStage()], ctx3)
    np.testing.assert_allclose(np.asarray(ctx2.leverage.probs),
                               np.asarray(ctx3.leverage.probs), rtol=1e-6)
    with pytest.raises(ValueError):
        run_stages([PrecomputedDensityStage(dens[:10])], _ctx(seed=1)[1])


def test_fixed_landmark_stage_skips_density_pipeline():
    data, ctx = _ctx(seed=2)
    idx = jnp.arange(0, 1024, 32)[:32]
    run_stages([FixedLandmarkStage(idx), SolveStage()], ctx)
    assert ctx.densities is None            # KDE never ran
    dense = nystrom.fit_from_landmarks(ctx.kernel, data.x, data.y, ctx.lam,
                                       idx)
    want = np.asarray(nystrom.predict(ctx.kernel, dense, data.x[:200]))
    got = np.asarray(nystrom.predict_streaming(ctx.kernel, ctx.fit,
                                               data.x[:200], tile=256))
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-3)


def test_pipeline_accepts_custom_stage_list():
    data = krr_data.bimodal(jax.random.PRNGKey(3), 1024, d=3)
    cfg = PipelineConfig(num_landmarks=32, tile=256)
    idx = jnp.arange(32, dtype=jnp.int32) * 7
    pipe = SAKRRPipeline(cfg, stages=[FixedLandmarkStage(idx), SolveStage()])
    pipe.fit(data.x, data.y)
    assert set(pipe.seconds) == {"sample", "solve"}
    risk = float(krr.in_sample_risk(pipe.fitted(data.x), data.f_star))
    assert np.isfinite(risk)
    with pytest.raises(RuntimeError):       # no leverage stage ran
        pipe.d_stat


def test_partial_pipeline_cannot_predict():
    data = krr_data.bimodal(jax.random.PRNGKey(4), 512, d=3)
    pipe = SAKRRPipeline(PipelineConfig(num_landmarks=16, tile=128),
                         stages=[DensityStage()])
    pipe.fit(data.x, data.y)
    assert pipe.state.densities is not None and pipe.state.fit is None
    with pytest.raises(RuntimeError):
        pipe.predict(data.x[:10])


def test_default_sampling_is_without_replacement():
    """Gumbel top-k landmarks are distinct and carry importance weights;
    the paper's iid mode stays behind the config flag."""
    data = krr_data.bimodal(jax.random.PRNGKey(5), 4096, d=3)
    cfg = PipelineConfig(num_landmarks=256, tile=1024)
    pipe = SAKRRPipeline(cfg).fit(data.x, data.y)
    idx = np.asarray(pipe.state.fit.landmark_idx)
    assert len(np.unique(idx)) == 256       # distinct by construction
    w = np.asarray(pipe.state.sample_weights)
    assert w.shape == (256,) and np.all(w > 0)
    assert np.mean(w) == pytest.approx(1.0, rel=1e-5)

    wr = PipelineConfig(num_landmarks=256, tile=1024,
                        sample_with_replacement=True)
    pipe_wr = SAKRRPipeline(wr).fit(data.x, data.y)
    assert pipe_wr.state.sample_weights is None
    # with replacement at m=256 on concentrated SA probs: near-certain dups
    assert len(np.unique(np.asarray(pipe_wr.state.fit.landmark_idx))) <= 256


def test_per_stage_overrides_beat_config():
    """Stage constructor knobs (method/tile/backend) override the config."""
    data = krr_data.bimodal(jax.random.PRNGKey(6), 512, d=3)
    cfg = PipelineConfig(num_landmarks=16, tile=128, kde_method="binned")
    stages = [DensityStage(method="direct"), LeverageStage(), SampleStage(),
              SolveStage(tile=64)]
    pipe = SAKRRPipeline(cfg, stages=stages).fit(data.x, data.y)
    from repro.core import kde
    want = np.asarray(kde.kde_direct(data.x, data.x,
                                     kde.scott_bandwidth(data.x)))
    np.testing.assert_allclose(np.asarray(pipe.state.densities), want,
                               rtol=1e-5, atol=1e-9)
