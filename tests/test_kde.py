"""KDE substrates: direct oracle vs binned-FFT linear-time estimator."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kde
from repro.data import krr_data


def test_direct_kde_integrates_to_one_1d():
    x = jax.random.normal(jax.random.PRNGKey(0), (400, 1))
    grid = jnp.linspace(-6.0, 6.0, 2001)[:, None]
    dens = kde.kde_direct(grid, x, 0.3)
    total = float(jnp.trapezoid(dens, grid[:, 0]))
    assert total == pytest.approx(1.0, rel=1e-3)


def test_direct_kde_recovers_gaussian_density():
    n = 4000
    x = jax.random.normal(jax.random.PRNGKey(1), (n, 1))
    q = jnp.linspace(-2.0, 2.0, 41)[:, None]
    est = np.asarray(kde.kde_direct(q, x, 0.2))
    true = np.exp(-np.asarray(q[:, 0]) ** 2 / 2) / np.sqrt(2 * np.pi)
    np.testing.assert_allclose(est, true, rtol=0.25, atol=0.01)


@pytest.mark.parametrize("d", [1, 2, 3])
def test_binned_matches_direct(d):
    n = 1500
    x = jax.random.uniform(jax.random.PRNGKey(2), (n, d))
    h = 0.08
    direct = np.asarray(kde.kde_direct(x, x, h))
    binned = np.asarray(kde.kde_binned(x, x, h, grid_size=128))
    # Binning error is O((delta/h)^2); with these settings < 2% median.
    rel = np.abs(binned / direct - 1.0)
    assert np.median(rel) < 0.02, np.median(rel)
    assert np.quantile(rel, 0.95) < 0.08


def test_binned_kde_on_bimodal_separates_modes():
    data = krr_data.bimodal_1d_paper(jax.random.PRNGKey(3), 3000)
    h = 0.3 * 3000 ** (-1.0 / 3.0)
    dens = np.asarray(kde.kde_binned(data.x, data.x, h))
    x = np.asarray(data.x[:, 0])
    major = dens[(x > 0.1) & (x < 0.4)]
    minor = dens[(x > 1.0) & (x < 1.2)]
    assert major.mean() > 3.0 * minor.mean()


def test_estimate_densities_dispatch():
    x3 = jax.random.uniform(jax.random.PRNGKey(4), (200, 3))
    x5 = jax.random.uniform(jax.random.PRNGKey(5), (200, 5))
    d3 = kde.estimate_densities(x3)
    d5 = kde.estimate_densities(x5)
    assert d3.shape == (200,) and d5.shape == (200,)
    assert bool(jnp.all(d3 >= 0)) and bool(jnp.all(d5 >= 0))


def test_scott_bandwidth_scales():
    x_small = jax.random.normal(jax.random.PRNGKey(6), (100, 2))
    x_big = jax.random.normal(jax.random.PRNGKey(6), (10000, 2))
    assert float(kde.scott_bandwidth(x_big)) < float(kde.scott_bandwidth(x_small))
