"""KDE substrates: direct oracle vs binned-FFT linear-time estimator."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kde
from repro.data import krr_data


def test_direct_kde_integrates_to_one_1d():
    x = jax.random.normal(jax.random.PRNGKey(0), (400, 1))
    grid = jnp.linspace(-6.0, 6.0, 2001)[:, None]
    dens = kde.kde_direct(grid, x, 0.3)
    total = float(jnp.trapezoid(dens, grid[:, 0]))
    assert total == pytest.approx(1.0, rel=1e-3)


def test_direct_kde_recovers_gaussian_density():
    n = 4000
    x = jax.random.normal(jax.random.PRNGKey(1), (n, 1))
    q = jnp.linspace(-2.0, 2.0, 41)[:, None]
    est = np.asarray(kde.kde_direct(q, x, 0.2))
    true = np.exp(-np.asarray(q[:, 0]) ** 2 / 2) / np.sqrt(2 * np.pi)
    np.testing.assert_allclose(est, true, rtol=0.25, atol=0.01)


@pytest.mark.parametrize("d", [1, 2, 3])
def test_binned_matches_direct(d):
    n = 1500
    x = jax.random.uniform(jax.random.PRNGKey(2), (n, d))
    h = 0.08
    direct = np.asarray(kde.kde_direct(x, x, h))
    binned = np.asarray(kde.kde_binned(x, x, h, grid_size=128))
    # Binning error is O((delta/h)^2); with these settings < 2% median.
    rel = np.abs(binned / direct - 1.0)
    assert np.median(rel) < 0.02, np.median(rel)
    assert np.quantile(rel, 0.95) < 0.08


def test_binned_kde_on_bimodal_separates_modes():
    data = krr_data.bimodal_1d_paper(jax.random.PRNGKey(3), 3000)
    h = 0.3 * 3000 ** (-1.0 / 3.0)
    dens = np.asarray(kde.kde_binned(data.x, data.x, h))
    x = np.asarray(data.x[:, 0])
    major = dens[(x > 0.1) & (x < 0.4)]
    minor = dens[(x > 1.0) & (x < 1.2)]
    assert major.mean() > 3.0 * minor.mean()


def test_estimate_densities_dispatch():
    x3 = jax.random.uniform(jax.random.PRNGKey(4), (200, 3))
    x5 = jax.random.uniform(jax.random.PRNGKey(5), (200, 5))
    d3 = kde.estimate_densities(x3)
    d5 = kde.estimate_densities(x5)
    assert d3.shape == (200,) and d5.shape == (200,)
    assert bool(jnp.all(d3 >= 0)) and bool(jnp.all(d5 >= 0))


def test_scott_bandwidth_scales():
    x_small = jax.random.normal(jax.random.PRNGKey(6), (100, 2))
    x_big = jax.random.normal(jax.random.PRNGKey(6), (10000, 2))
    assert float(kde.scott_bandwidth(x_big)) < float(kde.scott_bandwidth(x_small))


# ------------------------------------------------- out-of-grid clamping --
# Regressions for the cic_prep frac clamp: a point outside the grid bounds
# must read/deposit the BOUNDARY value, not linearly extrapolate the grid.

def test_cic_prep_clamps_base_and_frac():
    lo = jnp.zeros(2)
    spacing = jnp.full((2,), 0.5)
    gs = 8                                    # grid spans [0, 3.5] per dim
    pts = jnp.array([[-3.0, 1.0], [9.9, 1.7], [1.25, 3.49]])
    base, frac = kde.cic_prep(pts, lo, spacing, gs)
    assert int(base.min()) >= 0 and int(base.max()) <= gs - 2
    assert float(frac.min()) >= 0.0 and float(frac.max()) <= 1.0
    # in-range coordinates are untouched by both clips
    np.testing.assert_allclose(np.asarray(frac[2]), [0.5, 0.98], atol=1e-5)
    np.testing.assert_array_equal(np.asarray(base[2]), [2, 6])


def test_scatter_deposit_out_of_range_mass_stays_on_boundary():
    lo = jnp.zeros(1)
    spacing = jnp.ones(1)
    gs = 6                                    # nodes at 0..5
    pts = jnp.array([[12.0], [-4.0], [2.5]])
    grid = np.asarray(kde.scatter_cic(pts, lo, spacing, gs))
    # pre-clamp, frac = 8 at the right edge deposits -7/+8 (negative mass)
    assert grid.min() >= 0.0, grid
    np.testing.assert_allclose(grid.sum(), 3.0, rtol=1e-6)
    assert grid[-1] == pytest.approx(1.0)     # +12 -> all mass at node 5
    assert grid[0] == pytest.approx(1.0)      # -4 -> all mass at node 0
    np.testing.assert_allclose(grid[2:4], [0.5, 0.5], rtol=1e-6)


def test_out_of_grid_query_gets_boundary_density():
    """Queries beyond pinned grid bounds: boundary value, verified against
    the kde_direct oracle evaluated AT the boundary."""
    x = jax.random.uniform(jax.random.PRNGKey(8), (2000, 1))
    h = 0.1
    lo, hi = jnp.array([0.0]), jnp.array([1.0])
    q = jnp.array([[1.0], [1.7], [55.0], [-3.0], [0.0]])
    dens = np.asarray(kde.kde_binned(q, x, h, grid_size=64, lo=lo, hi=hi))
    # every beyond-hi query clamps to the SAME stencil -> bit-equal values,
    # and they agree with the at-edge query up to fp in the lattice coords
    assert dens[1] == dens[2]
    assert dens[0] == pytest.approx(dens[1], rel=1e-5)
    assert dens[3] == pytest.approx(dens[4], rel=1e-5)
    # and that value is the true edge density, not an extrapolation
    oracle_hi = float(kde.kde_direct(jnp.array([[1.0]]), x, h)[0])
    oracle_lo = float(kde.kde_direct(jnp.array([[0.0]]), x, h)[0])
    assert dens[0] == pytest.approx(oracle_hi, rel=0.05)
    assert dens[4] == pytest.approx(oracle_lo, rel=0.05)
