"""Unified streaming tile-reduction engine (`repro.core.streaming`).

Locks the refactor's three contracts:

  * plain-mode BIT-parity: the engine-backed public functions
    (`nystrom.scan_normal_eq` / `fit_streaming` (weighted + multi-lam) /
    `predict_streaming[_multi]`, `kde.scatter_cic`) reproduce the
    pre-refactor hand-rolled loops bit-for-bit — the reference
    implementations are copied verbatim below from the PR-4 sources;
  * compensated accuracy: on an n >= 1e5 stream the two-float fp32 Gram
    matches the f64 accumulation of the SAME f32 kernel tiles (the
    quantity the accumulator knob owns) at least 10x more tightly than
    plain fp32, and `solve_normal_eq`'s lowered noise floor retains
    whitened directions plain fp32 truncates (beta recovers the f64
    solution on an adversarially ill-conditioned landmark set);
  * mesh transport: the compensated (hi, lo) pair survives the psum on a
    forced 2-device host mesh (subprocess).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kde, kernels as K, nystrom, streaming
from repro.core.kernels import kernel_matrix, pad_rows_sentinel, round_up
from repro.data import krr_data

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KERN = K.Matern(nu=1.5)


def run_sub(body: str, env_extra: dict | None = None) -> str:
    code = textwrap.dedent("""
        import jax
        import jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               **(env_extra or {}))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ------------------------------------------------- pre-refactor references --
# Copied VERBATIM from the PR-4 bodies of nystrom.scan_normal_eq,
# kde.scatter_cic and nystrom.predict_streaming's local slab loop.  These are
# the bit-parity oracles for the engine's "plain" mode.

def _scan_normal_eq_ref(kernel, x, xm, w, *, tile=8192):
    n, d = x.shape
    m = xm.shape[0]
    acc = jnp.promote_types(x.dtype, jnp.float32)
    tile = min(tile, n)
    np_ = round_up(n, tile)
    xt = pad_rows_sentinel(x, np_).reshape(np_ // tile, tile, d)
    wt = jnp.pad(w.astype(acc), (0, np_ - n)).reshape(np_ // tile, tile)

    def step(carry, xw):
        g, r = carry
        xi, wi = xw
        k = kernel_matrix(kernel, xi, xm).astype(acc)
        g = g + jax.lax.dot_general(k, k, (((0,), (0,)), ((), ())),
                                    preferred_element_type=acc)
        r = r + jax.lax.dot_general(k, wi, (((0,), (0,)), ((), ())),
                                    preferred_element_type=acc)
        return (g, r), None

    init = (jnp.zeros((m, m), acc), jnp.zeros((m,), acc))
    (g, r), _ = jax.lax.scan(step, init, (xt, wt))
    return g, r


def _scatter_cic_ref(points, lo, spacing, grid_size, weights=None, tile=None):
    import functools

    @functools.partial(jax.jit, static_argnames=("grid_size", "tile"))
    def impl(points, lo, spacing, grid_size, weights=None, tile=None):
        n, d = points.shape
        dnums = jax.lax.ScatterDimensionNumbers(
            update_window_dims=tuple(range(1, d + 1)),
            inserted_window_dims=(),
            scatter_dims_to_operand_dims=tuple(range(d)))

        def deposit(grid, pts, w):
            base, frac = kde.cic_prep(pts, lo, spacing, grid_size)
            return jax.lax.scatter_add(grid, base, kde._cic_stencil(frac, w),
                                       dnums)

        grid0 = jnp.zeros((grid_size,) * d, dtype=points.dtype)
        if tile is None or tile >= n:
            return deposit(grid0, points, weights)
        np_ = round_up(n, tile)
        w = jnp.ones((n,), points.dtype) if weights is None else weights
        pts = jnp.pad(points, ((0, np_ - n), (0, 0))).reshape(-1, tile, d)
        wt = jnp.pad(w, (0, np_ - n)).reshape(-1, tile)

        def step(grid, pw):
            return deposit(grid, pw[0], pw[1]), None

        grid, _ = jax.lax.scan(step, grid0, (pts, wt))
        return grid

    return impl(points, lo, spacing, grid_size, weights=weights, tile=tile)


def _predict_ref(kernel, fit_, x_new, tile):
    from repro.kernels import dispatch
    n, d = x_new.shape
    xm, beta = fit_.landmarks, fit_.beta
    t = min(tile, n)
    np_ = round_up(n, t)
    tiles = pad_rows_sentinel(x_new, np_).reshape(np_ // t, t, d)

    def one(xt):
        return dispatch.kernel_matrix(kernel, xt, xm) @ beta

    return jax.lax.map(one, tiles).reshape(np_)[:n]


# -------------------------------------------------------- plain bit-parity --

@pytest.mark.parametrize("n,tile", [(1000, 192), (2048, 512), (300, 8192)])
def test_scan_normal_eq_bit_parity(n, tile):
    """Engine-tiled Gram accumulation == the pre-refactor lax.scan, bitwise
    (ragged tail, one-tile and multi-tile shapes)."""
    kx, ky, kw = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.normal(kx, (n, 3))
    w = jax.random.normal(kw, (n,))
    xm = x[jax.random.permutation(ky, n)[:48]]
    g0, r0 = _scan_normal_eq_ref(KERN, x, xm, w, tile=tile)
    g1, r1 = nystrom.scan_normal_eq(KERN, x, xm, w, tile=tile)
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))
    np.testing.assert_array_equal(np.asarray(r0), np.asarray(r1))


@pytest.mark.parametrize("weighted", [False, True])
def test_fit_streaming_bit_parity(weighted):
    """fit_streaming (plain) == reference Gram -> k_mm -> solve pipeline,
    bitwise, weighted and unweighted."""
    data = krr_data.bimodal(jax.random.PRNGKey(0), 2048, d=3)
    idx = jnp.arange(0, 2048, 32)[:64]
    lam = 1e-3
    w = (1.0 + jnp.arange(64, dtype=jnp.float32) / 16.0) if weighted else None
    xm = jnp.take(data.x, idx, axis=0)
    g, rhs = _scan_normal_eq_ref(KERN, data.x, xm, data.y, tile=512)
    k_mm = kernel_matrix(KERN, xm).astype(g.dtype)
    if w is not None:
        g, rhs, k_mm = nystrom.weighted_normal_eq(g, rhs, k_mm, w)
    beta_ref = nystrom.solve_normal_eq(g, rhs, k_mm, 2048, lam)
    if w is not None:
        beta_ref = w * beta_ref
    fit = nystrom.fit_streaming(KERN, data.x, data.y, lam, idx, tile=512,
                                weights=w)
    np.testing.assert_array_equal(np.asarray(fit.beta), np.asarray(beta_ref))


def test_fit_streaming_multi_bit_parity():
    """The multi-lam sweep rides the same engine stream: every fit bitwise
    equals the reference composition."""
    data = krr_data.bimodal(jax.random.PRNGKey(1), 2048, d=3)
    idx = jnp.arange(0, 2048, 32)[:64]
    lams = [1e-2, 1e-3, 1e-4]
    xm = jnp.take(data.x, idx, axis=0)
    g, rhs = _scan_normal_eq_ref(KERN, data.x, xm, data.y, tile=512)
    k_mm = kernel_matrix(KERN, xm).astype(g.dtype)
    fits = nystrom.fit_streaming_multi(KERN, data.x, data.y, lams, idx,
                                       tile=512)
    for lam, fit in zip(lams, fits):
        want = nystrom.solve_normal_eq(g, rhs, k_mm, 2048, lam)
        np.testing.assert_array_equal(np.asarray(fit.beta), np.asarray(want))


@pytest.mark.parametrize("tile", [100, 333, 4096])
def test_predict_streaming_bit_parity(tile):
    data = krr_data.bimodal(jax.random.PRNGKey(0), 2048, d=3)
    idx = jnp.arange(0, 2048, 32)[:64]
    fit = nystrom.fit_streaming(KERN, data.x, data.y, 1e-3, idx, tile=512)
    want = _predict_ref(KERN, fit, data.x[:777], tile)
    got = nystrom.predict_streaming(KERN, fit, data.x[:777], tile=tile)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_predict_streaming_multi_bit_parity():
    """The multi-beta predict shares the reference's tile stream: with a
    single fit it must reproduce predict_streaming bitwise, modulo the
    (1, n) stacking."""
    data = krr_data.bimodal(jax.random.PRNGKey(3), 1024, d=3)
    idx = jnp.arange(0, 1024, 16)[:48]
    fits = nystrom.fit_streaming_multi(KERN, data.x, data.y, [1e-3], idx,
                                       tile=256)
    multi = nystrom.predict_streaming_multi(KERN, fits, data.x[:300],
                                            tile=128)
    assert multi.shape == (1, 300)


@pytest.mark.parametrize("tile,weighted", [(None, False), (100, False),
                                           (100, True), (None, True)])
def test_scatter_cic_bit_parity(tile, weighted):
    x = jax.random.uniform(jax.random.PRNGKey(1), (601, 3)) * 2.0 - 0.5
    lo = jnp.full((3,), -0.7)
    spacing = (jnp.full((3,), 1.7) - lo) / 23
    w = (jax.random.uniform(jax.random.PRNGKey(5), (601,)) + 0.5
         if weighted else None)
    want = _scatter_cic_ref(x, lo, spacing, 24, weights=w, tile=tile)
    got = kde.scatter_cic(x, lo, spacing, 24, weights=w, tile=tile)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------- compensated accuracy --

def test_two_sum_exact():
    """two_sum is an error-free transformation: hi + lo == the exact sum in
    f64 for adversarial magnitude gaps that plain f32 rounds away."""
    a = jnp.asarray([1e8, 1.0, -1e8, 3.25e-4], jnp.float32)
    b = jnp.asarray([3.25e-4, 1e8, 1.0, 1e8], jnp.float32)
    s, e = streaming.two_sum(a, b)
    exact = np.asarray(a, np.float64) + np.asarray(b, np.float64)
    np.testing.assert_array_equal(
        np.asarray(s, np.float64) + np.asarray(e, np.float64), exact)


def test_tile_reduce_compensated_beats_plain_on_adversarial_stream():
    """Summing an adversarial (large-offset) stream: the compensated engine
    matches the f64 sum to ~ulp while plain f32 drifts with the tile count."""
    n, tile = 131072, 64
    vals = (jnp.ones((n,), jnp.float32) * 0.1
            + jnp.where(jnp.arange(n) % 977 == 0, 1.0e5, 0.0))
    init = jnp.zeros((), jnp.float32)
    emit = lambda v: jnp.sum(v)

    plain = streaming.tile_reduce(emit, vals, tile=tile, init=init)
    comp = streaming.tile_reduce(emit, vals, tile=tile, init=init,
                                 accumulator="compensated")
    exact = float(np.sum(np.asarray(vals, np.float64)))
    err_plain = abs(float(plain) - exact)
    err_comp = abs(float(comp) - exact)
    assert err_comp * 10 < err_plain, (err_plain, err_comp)


def test_compensated_gram_10x_tighter_than_plain():
    """Acceptance bar: at n >= 1e5, the compensated fp32 streaming Gram
    matches the f64 accumulation of the SAME f32 kernel tiles (the
    quantity the accumulator owns — kernel-tile rounding is identical in
    all three paths) at least 10x more tightly than plain fp32."""
    n, m, d, tile = 131072, 64, 3, 256
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d), dtype=jnp.float32)
    xm = x[:m]
    gp, _ = nystrom.scan_normal_eq(KERN, x, xm, jnp.zeros((n,)), tile=tile)
    (gh, _), (gl, _) = nystrom.scan_normal_eq(
        KERN, x, xm, jnp.zeros((n,)), tile=tile, accumulator="compensated",
        finalize=False)
    # f64 accumulation of the same f32 tiles (host side)
    tiles = jax.jit(lambda xt: kernel_matrix(KERN, xt, xm))
    ref = np.zeros((m, m), np.float64)
    for i in range(n // tile):
        k = np.asarray(tiles(x[i * tile:(i + 1) * tile]), np.float64)
        ref += k.T @ k
    scale = np.abs(ref).max()
    err_plain = np.abs(np.asarray(gp, np.float64) - ref).max() / scale
    err_comp = np.abs(np.asarray(gh, np.float64)
                      + np.asarray(gl, np.float64) - ref).max() / scale
    assert err_comp * 10 <= err_plain, (err_plain, err_comp)


def test_compensated_solve_retains_truncated_directions():
    """Regression for the ROADMAP scale-ceiling item: on an adversarially
    ill-conditioned landmark set (near-duplicate clusters -> tiny K_mm
    eigenvalues) with small lam, the plain fp32 solve truncates whitened
    directions at its noise floor and loses the f64 solution; the
    compensated stream + lowered floor (streaming.EPS_SCALE) keeps them and
    recovers it.  f64 reference in a subprocess (enable_x64)."""
    out = run_sub("""
        from repro.core import kernels as K, nystrom, streaming
        kern = K.Matern(nu=1.5)
        n, m, d, tile = 65536, 48, 3, 512
        x = jax.random.normal(jax.random.PRNGKey(0), (n, d),
                              dtype=jnp.float32)
        base = x[:m // 2]
        dup = base + 3e-4 * jax.random.normal(jax.random.PRNGKey(1),
                                              base.shape, dtype=jnp.float32)
        xm = jnp.concatenate([base, dup])
        y = jnp.sin(3 * x[:, 0]) + 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (n,), dtype=jnp.float32)
        lam = 1e-6

        def solve(accumulator):
            g, r = nystrom.streaming_normal_eq(kern, x, y, xm, tile=tile,
                                               accumulator=accumulator)
            k_mm = K.kernel_matrix(kern, xm).astype(g.dtype)
            return nystrom.solve_normal_eq(
                g, r, k_mm, n, lam,
                eps_scale=streaming.eps_scale(accumulator))

        beta_p = solve("plain")
        beta_c = solve("compensated")

        # f64 reference: same stream in f64 end to end
        x64, xm64 = x.astype(jnp.float64), xm.astype(jnp.float64)
        g64 = jnp.zeros((m, m), jnp.float64)
        r64 = jnp.zeros((m,), jnp.float64)
        for i in range(0, n, 8192):
            k = K.kernel_matrix(kern, x64[i:i + 8192], xm64)
            g64 = g64 + k.T @ k
            r64 = r64 + k.T @ y[i:i + 8192].astype(jnp.float64)
        k_mm64 = K.kernel_matrix(kern, xm64)
        beta64 = nystrom.solve_normal_eq(g64, r64, k_mm64, n, lam)

        ep = float(jnp.linalg.norm(beta_p.astype(jnp.float64) - beta64)
                   / jnp.linalg.norm(beta64))
        ec = float(jnp.linalg.norm(beta_c.astype(jnp.float64) - beta64)
                   / jnp.linalg.norm(beta64))
        # plain truncates the near-duplicate directions entirely (O(1)
        # error); compensated + lowered floor recovers the f64 solution
        assert ep > 0.1, ep
        assert ec < 1e-3, ec
        assert ec * 50 < ep, (ep, ec)
        print("RETAIN_OK", ep, ec)
    """, env_extra={"JAX_ENABLE_X64": "1"})
    assert "RETAIN_OK" in out


def test_pallas_compensated_gram_matches_engine_scan():
    """The two-float VMEM accumulator inside the Pallas gram body computes
    the same compensated sum as the XLA engine scan at equal tile
    granularity (bm == tile == 256 -> identical fold order), with a live
    error channel (nonzero lo) once several row tiles stream through."""
    from repro.kernels.gram import ops as gram_ops
    n, m = 4096, 64
    x = jax.random.normal(jax.random.PRNGKey(0), (n, 3))
    w = jax.random.normal(jax.random.PRNGKey(1), (n,))
    xm = x[:m]
    (gh, rh), (gl, rl) = gram_ops.gram_matrix(
        KERN, x, xm, w, interpret=True, accumulator="compensated",
        finalize=False)
    assert float(jnp.abs(gl).max()) > 0.0
    g_scan, r_scan = nystrom.scan_normal_eq(KERN, x, xm, w, tile=256,
                                            accumulator="compensated")
    np.testing.assert_array_equal(np.asarray(gh + gl), np.asarray(g_scan))
    np.testing.assert_array_equal(np.asarray(rh + rl), np.asarray(r_scan))


# ------------------------------------------------------------ mesh transport --

@pytest.mark.slow
def test_compensated_pair_survives_psum():
    """Forced 2-device mesh: the (hi, lo) state crosses the Gram psum
    un-collapsed — lo is psum-reduced alongside hi and the finalized sharded
    result stays within reduction-order noise of the single-device
    compensated result (and carries a genuinely nonzero lo)."""
    out = run_sub("""
        from repro.core import kernels as K, nystrom, streaming
        from repro.distributed import sharding as shd
        assert jax.device_count() == 2, jax.devices()
        kern = K.Matern(nu=1.5)
        n, m = 32768, 48
        x = jax.random.normal(jax.random.PRNGKey(0), (n, 3))
        y = jax.random.normal(jax.random.PRNGKey(1), (n,))
        xm = x[:m]
        g_ref, r_ref = nystrom.streaming_normal_eq(
            kern, x, y, xm, tile=512, accumulator="compensated")
        mesh = jax.make_mesh((2,), ("data",))
        with mesh, shd.activate(mesh):
            state = nystrom.streaming_normal_eq(
                kern, x, y, xm, tile=512, accumulator="compensated",
                finalize=False)
            (g_hi, r_hi), (g_lo, r_lo) = state
            g_sh, r_sh = streaming.get("compensated").finalize(state)
        # the error channel is alive after the collective
        assert float(jnp.abs(g_lo).max()) > 0.0
        # and the pair equals the single-device compensated accumulation up
        # to the reduction-order change of splitting the stream in two
        np.testing.assert_allclose(np.asarray(g_sh), np.asarray(g_ref),
                                   rtol=2e-6, atol=1e-4)
        np.testing.assert_allclose(np.asarray(r_sh), np.asarray(r_ref),
                                   rtol=2e-5, atol=1e-4)

        # one tile PER CHIP: each chip's lo is exactly zero and the
        # adaptive floor must keep compensated == plain bit-for-bit (the
        # steps budget counts per-chip scan steps, not global n / tile)
        n2 = 16384
        idx = jnp.arange(0, n2, n2 // m)[:m]
        with mesh, shd.activate(mesh):
            fp = nystrom.fit_streaming(kern, x[:n2], y[:n2], 1e-4, idx,
                                       tile=8192)
            fc = nystrom.fit_streaming(kern, x[:n2], y[:n2], 1e-4, idx,
                                       tile=8192, accumulator="compensated")
        np.testing.assert_array_equal(np.asarray(fp.beta),
                                      np.asarray(fc.beta))
        print("PSUM_PAIR_OK")
    """, env_extra={"XLA_FLAGS": "--xla_force_host_platform_device_count=2"})
    assert "PSUM_PAIR_OK" in out


# ----------------------------------------------------------- loo threshold --

def test_loo_threshold_matches_race_on_selected_items():
    """The exact leave-one-out threshold coincides with the shared race
    threshold for every SELECTED item (the order-statistics identity that
    makes the historical estimator exact); only the clip-free tail differs."""
    q = jnp.asarray(np.random.default_rng(0).dirichlet(np.full(40, 2.0)),
                    jnp.float32)
    from repro.core import sampling
    i1, w1 = sampling.sample_weighted_without_replacement(
        jax.random.PRNGKey(7), q, 8)
    i2, w2 = sampling.sample_weighted_without_replacement(
        jax.random.PRNGKey(7), q, 8, threshold="loo")
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), rtol=1e-6)
