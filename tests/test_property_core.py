"""Hypothesis property tests on the core system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import kernels as K, leverage, nystrom, polylog, quadrature
from repro.core import sampling

SETTINGS = dict(max_examples=15, deadline=None)


@st.composite
def kernel_strategy(draw):
    family = draw(st.sampled_from(["matern05", "matern15", "matern25", "gauss"]))
    scale = draw(st.floats(0.3, 3.0))
    if family == "gauss":
        return K.Gaussian(sigma=scale)
    nu = {"matern05": 0.5, "matern15": 1.5, "matern25": 2.5}[family]
    return K.Matern(nu=nu, lengthscale=scale)


@given(kern=kernel_strategy(), seed=st.integers(0, 2**31 - 1), d=st.integers(1, 4))
@settings(**SETTINGS)
def test_kernel_matrix_always_psd(kern, seed, d):
    x = jax.random.normal(jax.random.PRNGKey(seed), (24, d))
    km = np.asarray(K.kernel_matrix(kern, x), dtype=np.float64)
    evals = np.linalg.eigvalsh(km)
    assert evals.min() > -1e-4
    np.testing.assert_allclose(np.diag(km), 1.0, atol=1e-5)


@given(
    kern=kernel_strategy(),
    seed=st.integers(0, 2**31 - 1),
    lam=st.floats(1e-6, 1e-1),
    method=st.sampled_from(["closed_form", "quadrature", "grid"]),
)
@settings(**SETTINGS)
def test_sa_leverage_is_valid_distribution(kern, seed, lam, method):
    dens = jnp.exp(jax.random.normal(jax.random.PRNGKey(seed), (64,)))
    sa = leverage.sa_leverage(dens, lam, kern, d=2, n=64, method=method)
    probs = np.asarray(sa.probs)
    assert np.all(probs >= 0)
    np.testing.assert_allclose(probs.sum(), 1.0, rtol=1e-4)
    assert np.all(np.asarray(sa.rescaled) <= 64.0 + 1e-3)


@given(kern=kernel_strategy(), lam=st.floats(1e-6, 1e-2), d=st.integers(1, 3))
@settings(**SETTINGS)
def test_radial_integral_monotone_decreasing_in_density(kern, lam, d):
    if isinstance(kern, K.Matern) and not 2 * kern.alpha(d) > d:
        return
    p = jnp.exp(jnp.linspace(-3.0, 2.0, 32))
    vals = np.asarray(quadrature.radial_integral(p, lam, kern, d))
    assert np.all(np.diff(vals) < 0)


@given(s=st.floats(0.5, 4.0), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_polylog_monotone_nonnegative(s, seed):
    x = jnp.sort(jax.random.uniform(jax.random.PRNGKey(seed), (16,)) * 100.0)
    f = np.asarray(polylog.neg_polylog(s, x))
    assert np.all(f >= -1e-7)
    assert np.all(np.diff(f) >= -1e-6)


# -- weighted without-replacement sampling (fuzzed versions of the
# -- deterministic instances in tests/test_sampling_weights.py) --------------

_WSAMPLE_N, _WSAMPLE_M, _WSAMPLE_R = 6, 3, 4096
_wsample_probs = st.lists(st.floats(0.2, 1.0), min_size=_WSAMPLE_N,
                          max_size=_WSAMPLE_N).map(
    lambda raw: (np.asarray(raw, np.float32) / np.sum(raw)).astype(np.float32))


@given(q=_wsample_probs, seed=st.integers(0, 2**31 - 1),
       m=st.integers(1, _WSAMPLE_N))
@settings(**SETTINGS)
def test_weighted_sample_distinct_and_inverse_inclusion_scale(q, seed, m):
    idx, w = sampling.sample_weighted_without_replacement(
        jax.random.PRNGKey(seed), jnp.asarray(q), m)
    assert len(np.unique(np.asarray(idx))) == m
    assert np.all(np.asarray(w) >= 1.0)   # inverse inclusion probabilities
    if m == _WSAMPLE_N:                   # certain inclusion: exactly 1
        np.testing.assert_allclose(np.asarray(w), 1.0)


@given(q=_wsample_probs)
@settings(max_examples=5, deadline=None)
def test_weighted_sample_unbiased_inclusion_estimator(q):
    """E[1{i in S} w_i] ~ 1 for every i — the weights are (approximately)
    unbiased inverse-inclusion estimates, matching the exact Plackett-Luce
    inclusion probabilities enumerated in tests/test_sampling_weights.py."""
    from test_sampling_weights import _mc_stats, pl_inclusion
    pi = pl_inclusion(q, _WSAMPLE_M)
    freq, wacc = _mc_stats(q)
    np.testing.assert_allclose(freq, pi, atol=0.04)
    np.testing.assert_allclose(wacc, 1.0, atol=0.10)


@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.1, 10.0))
@settings(max_examples=8, deadline=None)
def test_weighted_sor_invariant_to_weight_rescaling(seed, scale):
    """Fuzzed form of the SoR weight-rescaling regression: any positive
    rescaling of the landmark weights leaves the predictor unchanged."""
    kern = K.Matern(nu=1.5)
    key = jax.random.PRNGKey(seed)
    x = jax.random.uniform(key, (80, 2))
    y = jnp.sin(3.0 * x[:, 0]) + 0.1 * jax.random.normal(key, (80,))
    idx = jnp.arange(0, 80, 5)
    w = 1.0 + jax.random.uniform(jax.random.fold_in(key, 1), (16,)) * 4.0
    f1 = nystrom.fitted(kern, nystrom.fit_from_landmarks(
        kern, x, y, 1e-3, idx, weights=w), x)
    f2 = nystrom.fitted(kern, nystrom.fit_from_landmarks(
        kern, x, y, 1e-3, idx, weights=scale * w), x)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=5e-2)


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_nystrom_invariant_to_landmark_duplication(seed):
    """L = K S (S^T K S)^+ S^T K is invariant to duplicating S's columns."""
    kern = K.Matern(nu=1.5)
    key = jax.random.PRNGKey(seed)
    x = jax.random.uniform(key, (80, 2))
    y = jnp.sin(3.0 * x[:, 0]) + 0.1 * jax.random.normal(key, (80,))
    idx = jnp.arange(0, 80, 5)  # 16 landmarks
    idx_dup = jnp.concatenate([idx, idx[:4]])  # duplicate 4 of them
    f1 = nystrom.fitted(kern, nystrom.fit_from_landmarks(kern, x, y, 1e-3, idx), x)
    f2 = nystrom.fitted(kern, nystrom.fit_from_landmarks(kern, x, y, 1e-3, idx_dup), x)
    # Exact invariance holds at jitter = 0; the fp32-stabilizing relative
    # jitter perturbs the two solves slightly differently, so allow O(1e-2).
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=5e-2)
