"""Training-infrastructure tests: optimizer, checkpoint/restart fault
tolerance, deterministic data resume, microbatching, compression, serving."""

from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import manager as ckpt
from repro.data import tokens
from repro.distributed import compression
from repro.models import model as M
from repro.optim import adamw
from repro.serving.lm_engine import Engine
from repro.training.train import Trainer, TrainerConfig, make_train_step


def _small_cfg():
    return configs.get_smoke("llama3.2-1b")


# ------------------------------------------------------------------ adamw ---
def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    hp = adamw.Hparams(peak_lr=0.2, warmup_steps=0, total_steps=200,
                       weight_decay=0.0, clip_norm=100.0)
    state = adamw.init(params)
    for _ in range(200):
        grads = {"w": 2.0 * params["w"]}
        params, state, _ = adamw.update(grads, state, params, hp)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_grad_clipping_bounds_update():
    grads = {"w": jnp.full((4,), 1e6)}
    clipped, norm = adamw.clip_by_global_norm(grads, 1.0)
    assert float(norm) > 1e5
    np.testing.assert_allclose(float(adamw.global_norm(clipped)), 1.0,
                               rtol=1e-4)


# ------------------------------------------------------------- train loop ---
def test_loss_decreases_on_synthetic_stream():
    cfg = _small_cfg()
    data = tokens.for_config(cfg, batch=8, seq_len=32)
    hp = adamw.Hparams(peak_lr=3e-3, warmup_steps=2, total_steps=30)
    step_fn = jax.jit(make_train_step(cfg, hp))
    params = M.init(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    losses = []
    for i in range(30):
        params, opt, m = step_fn(params, opt, data.batch_at(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_microbatch_accumulation_matches_full_batch():
    cfg = dataclasses.replace(_small_cfg(), compute_dtype="float32")
    data = tokens.for_config(cfg, batch=8, seq_len=16)
    hp = adamw.Hparams(clip_norm=1e9)
    params = M.init(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    batch = data.batch_at(0)
    p1, _, m1 = make_train_step(cfg, hp, num_microbatches=1)(
        params, opt, batch)
    p4, _, m4 = make_train_step(cfg, hp, num_microbatches=4)(
        params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-5)


# -------------------------------------------------- checkpointing / resume --
def test_checkpoint_restart_resumes_exactly(tmp_path):
    cfg = _small_cfg()
    data = tokens.for_config(cfg, batch=4, seq_len=16)
    hp = adamw.Hparams(total_steps=20)
    tc = TrainerConfig(checkpoint_dir=str(tmp_path), checkpoint_every=5,
                       async_checkpoint=False)

    t1 = Trainer(cfg, hp, data, tc, jax.random.PRNGKey(0))
    t1.run(7)  # checkpoints at step 5; steps 6-7 lost on "crash"
    loss_ref_trainer = Trainer(cfg, hp, data, tc, jax.random.PRNGKey(0))
    # fresh process simulation: new trainer resumes from step 5
    assert loss_ref_trainer.step == 5
    m = loss_ref_trainer.run(3)
    assert loss_ref_trainer.step == 8
    assert np.isfinite(m["loss"])


def test_checkpoint_torn_write_ignored(tmp_path):
    man = ckpt.Manager(str(tmp_path), async_write=False)
    state = {"w": jnp.arange(4.0)}
    man.save(3, state)
    # torn checkpoint: directory without MANIFEST
    os.makedirs(tmp_path / "step_9")
    (tmp_path / "step_9" / "state.npz").write_bytes(b"garbage")
    assert man.latest_step() == 3
    out = man.restore(3, state)
    np.testing.assert_allclose(np.asarray(out["w"]), np.arange(4.0))


def test_checkpoint_elastic_restore_new_sharding(tmp_path):
    man = ckpt.Manager(str(tmp_path), async_write=False)
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    man.save(1, state)
    mesh = jax.make_mesh((1,), ("data",))
    target = {"w": jax.ShapeDtypeStruct(
        (4, 4), jnp.float32,
        sharding=jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec("data", None)))}
    out = man.restore(1, target)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.arange(16.0).reshape(4, 4))


def test_data_stream_is_stateless_resumable():
    cfg = _small_cfg()
    data = tokens.for_config(cfg, batch=2, seq_len=8, seed=7)
    a = data.batch_at(11)
    b = tokens.for_config(cfg, batch=2, seq_len=8, seed=7).batch_at(11)
    np.testing.assert_array_equal(np.asarray(a["inputs"]),
                                  np.asarray(b["inputs"]))
    c = data.batch_at(12)
    assert not np.array_equal(np.asarray(a["inputs"]), np.asarray(c["inputs"]))


# ------------------------------------------------------------ compression ---
def test_sign_compression_error_feedback_converges():
    # EF-compressed gradient descent still drives a quadratic to zero
    w = jnp.array([4.0, -2.0, 1.5])
    state = compression.ef_init({"w": w})
    for _ in range(300):
        g = {"w": 2.0 * w}
        comp, state = compression.sign_compress(g, state)
        w = w - 0.05 * comp["w"]
    assert float(jnp.abs(w).max()) < 0.2


def test_bf16_compression_close():
    g = {"w": jnp.array([1.0, 1e-3, 123.456])}
    out = compression.bf16_compress(g)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               rtol=1e-2)


# ---------------------------------------------------------------- serving ---
def test_engine_inputs_embeds_arch():
    # audio/vlm stub archs: the engine embeds token prompts via the table
    cfg = dataclasses.replace(configs.get_smoke("musicgen-large"),
                              compute_dtype="float32")
    params = M.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0,
                                 cfg.vocab_size)
    out = eng.generate(jax.random.PRNGKey(2), prompts, max_new_tokens=4)
    assert out.tokens.shape == (2, 4)
    assert np.isfinite(np.asarray(out.logprobs)).all()


def test_engine_greedy_generation_deterministic():
    cfg = dataclasses.replace(_small_cfg(), compute_dtype="float32")
    params = M.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                                 cfg.vocab_size)
    r1 = eng.generate(jax.random.PRNGKey(2), prompts, max_new_tokens=5)
    r2 = eng.generate(jax.random.PRNGKey(3), prompts, max_new_tokens=5)
    np.testing.assert_array_equal(np.asarray(r1.tokens),
                                  np.asarray(r2.tokens))
    assert r1.tokens.shape == (2, 5)
    assert np.all(np.asarray(r1.logprobs) <= 0.0)
