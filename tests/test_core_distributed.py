"""Sharded SA+Nyström pipeline == single-device reference (subprocess,
8 forced host devices), plus an abstract lowering check."""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"), XLA_FLAGS="")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_pipeline_matches_reference():
    out = run_sub("""
        import numpy as np
        from repro.core import distributed as D
        from repro.core import kernels as K
        from repro.core import kde as core_kde
        from repro.core import leverage, nystrom
        from repro.data import krr_data
        from repro.distributed import sharding as shd

        n, d, m, m_kde = 1024, 3, 32, 256
        lam = 0.075 * n ** (-2/3)
        h = 0.3
        data = krr_data.bimodal(jax.random.PRNGKey(0), n, d=d)
        kde_sample = data.x[:m_kde]
        idx = jnp.arange(0, n, n // m)[:m]
        kern = K.Matern(nu=1.5)
        fn = D.make_pipeline_fn(kern, lam, h)

        # single-device reference
        ref = jax.jit(fn)(data.x, data.y, kde_sample, idx)

        mesh = jax.make_mesh((8,), ("data",))
        with mesh, shd.activate(mesh, {"batch": ("data",)}):
            sh = jax.jit(fn)(data.x, data.y, kde_sample, idx)

        np.testing.assert_allclose(np.asarray(ref.probs), np.asarray(sh.probs),
                                   rtol=2e-4, atol=1e-9)
        # beta itself is near-null-space sensitive (fp32 normal equations);
        # the stable functionals are the predictions and d_stat
        np.testing.assert_allclose(np.asarray(ref.fitted),
                                   np.asarray(sh.fitted), rtol=1e-2, atol=1e-3)
        np.testing.assert_allclose(float(ref.d_stat), float(sh.d_stat),
                                   rtol=1e-5)

        # the pipeline's density/leverage agree with the core (host) path
        p_core = core_kde.kde_direct(data.x, kde_sample, h)
        sa = leverage.sa_leverage(p_core, lam, kern, d, n=n)
        np.testing.assert_allclose(np.asarray(sh.probs), np.asarray(sa.probs),
                                   rtol=2e-4, atol=1e-9)
        print("PIPELINE_MATCH_OK")
    """)
    assert "PIPELINE_MATCH_OK" in out


@pytest.mark.slow
def test_binned_kde_sharded_matches_oracle():
    out = run_sub("""
        import numpy as np
        from repro.core import distributed as D
        from repro.core import kde as core_kde
        from repro.data import krr_data
        from repro.distributed import sharding as shd

        n, d, h = 2048, 3, 0.25
        data = krr_data.bimodal(jax.random.PRNGKey(3), n, d=d)
        lo = jnp.full((d,), -5.0); hi = jnp.full((d,), 5.0)

        # oracle: single-device binned KDE on the same fixed grid bounds
        from repro.kernels.kde_binned import ref as kb_ref
        spacing = (hi - lo) / (96 - 1)
        grid = kb_ref.binned_grid(data.x, lo, spacing, 96)
        smooth = core_kde._fft_smooth(grid, spacing, jnp.float32(h), 96, d)

        ref = D.kde_binned_sharded(data.x, h, grid_size=96, lo=lo, hi=hi)

        mesh = jax.make_mesh((8,), ("data",))
        with mesh, shd.activate(mesh, {"batch": ("data",)}):
            sh = jax.jit(lambda x: D.kde_binned_sharded(
                x, h, grid_size=96, lo=lo, hi=hi))(data.x)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(sh),
                                   rtol=2e-4, atol=1e-7)
        # sanity vs direct KDE: binned approximation within a few percent
        direct = core_kde.kde_direct(data.x, data.x, h)
        rel = np.abs(np.asarray(sh) - np.asarray(direct)) / (
            np.asarray(direct) + 1e-9)
        assert np.quantile(rel, 0.9) < 0.05, np.quantile(rel, 0.9)
        print("BINNED_SHARDED_OK")
    """)
    assert "BINNED_SHARDED_OK" in out


@pytest.mark.slow
def test_pipeline_lowers_on_production_like_mesh():
    out = run_sub("""
        from repro.core import distributed as D
        from repro.roofline import analysis as roofline
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        lowered, compiled = D.lower_pipeline(mesh, n=65536, d=3)
        cost = roofline.cost_dict(compiled)   # list/dict across jax versions
        assert cost.get("flops", 0) > 0
        txt = compiled.as_text()
        assert "all-reduce" in txt  # the K_nm^T K_nm reduction
        print("PIPELINE_LOWER_OK", int(cost["flops"]))
    """)
    assert "PIPELINE_LOWER_OK" in out
