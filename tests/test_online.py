"""Online ingestion across the pipeline stack: `partial_fit`, decayed /
windowed absorption, checkpointed accumulator state (tear-safe resume),
online landmark maintenance, and schema-version gating.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as mgr_mod
from repro.core import accstate, kde, nystrom
from repro.data import krr_data
from repro.pipeline import (FixedLandmarkStage, PipelineConfig,
                            SAKRRPipeline, SolveStage)
from repro.pipeline import online as online_mod

N, D, TILE, LAM = 4096, 2, 256, 1e-4


@pytest.fixture(scope="module")
def data():
    return krr_data.bimodal(jax.random.PRNGKey(0), N, D)


def _fixed_idx(n_fit: int, m: int = 64, seed: int = 7):
    idx = np.random.default_rng(seed).choice(n_fit, m, replace=False)
    return jnp.asarray(np.sort(idx), jnp.int32)


def _pipe(accumulator="plain", stages=None):
    cfg = PipelineConfig(tile=TILE, lam=LAM, accumulator=accumulator)
    return SAKRRPipeline(cfg, stages=stages)


# ------------------------------------------------------------ partial_fit --

@pytest.mark.parametrize("accumulator", ["plain", "compensated"])
def test_partial_fit_is_bit_equal_to_one_shot_fit(data, accumulator):
    """Tile-aligned partial_fit chunks reproduce the one-shot fit beta
    bit-for-bit (the absorb continues the scan carry)."""
    idx = _fixed_idx(N // 2)
    pipe = _pipe(accumulator, [FixedLandmarkStage(idx), SolveStage()])
    pipe.fit(data.x[:N // 2], data.y[:N // 2])
    for lo in range(N // 2, N, 1024):
        pipe.partial_fit(data.x[lo:lo + 1024], data.y[lo:lo + 1024])
    ref = _pipe(accumulator, [FixedLandmarkStage(idx), SolveStage()])
    ref.fit(data.x, data.y)
    np.testing.assert_array_equal(np.asarray(pipe.state.fit.beta),
                                  np.asarray(ref.state.fit.beta))
    assert pipe.online.rows == N


def test_partial_fit_through_default_stages_and_predict(data):
    """The full KDE->leverage->sample->solve fold banks its state too, and
    predict serves the refreshed beta immediately."""
    pipe = _pipe().fit(data.x[:N // 2], data.y[:N // 2])
    before = np.asarray(pipe.predict(data.x[:16]))
    pipe.partial_fit(data.x[N // 2:], data.y[N // 2:])
    after = np.asarray(pipe.predict(data.x[:16]))
    assert not np.array_equal(before, after)
    assert "partial_fit" in pipe.state.seconds
    assert pipe.online.rows == N


def test_partial_fit_requires_a_fit(data):
    pipe = _pipe()
    with pytest.raises(RuntimeError, match="fit"):
        pipe.partial_fit(data.x[:64], data.y[:64])


def test_partial_fit_decay_tracks_effective_rows(data):
    pipe = _pipe().fit(data.x[:2048], data.y[:2048])
    pipe.partial_fit(data.x[2048:2560], data.y[2048:2560], decay=0.5)
    assert pipe.online.rows == pytest.approx(2048 * 0.5 + 512)


def test_partial_fit_window_evicts_oldest(data):
    pipe = _pipe().fit(data.x[:2048], data.y[:2048])
    pipe.partial_fit(data.x[2048:3072], data.y[2048:3072], window=2)
    # ring: [fit(2048), chunk(1024)] -> rows 3072
    assert pipe.online.rows == 3072
    pipe.partial_fit(data.x[3072:], data.y[3072:], window=2)
    # ring: [chunk(1024), chunk(1024)] -> the initial fit fell out
    assert pipe.online.rows == 2048
    # windowed state == one fold over exactly the windowed rows (merging
    # independent chunk states reassociates, so tolerance not bitwise)
    win = pipe.online.solve
    ref = nystrom.normal_eq_init(pipe.kernel, win.landmarks, tile=TILE)
    ref = nystrom.normal_eq_absorb(pipe.kernel, ref,
                                   data.x[2048:], data.y[2048:])
    g_win, _ = accstate.finalize(win.acc)
    g_ref, _ = accstate.finalize(ref.acc)
    np.testing.assert_allclose(np.asarray(g_win), np.asarray(g_ref),
                               rtol=1e-5, atol=1e-4)


def test_decay_and_window_are_mutually_exclusive(data):
    pipe = _pipe().fit(data.x[:1024], data.y[:1024])
    with pytest.raises(ValueError, match="mutually exclusive"):
        pipe.partial_fit(data.x[1024:1280], data.y[1024:1280],
                         decay=0.9, window=2)


def test_online_deposit_tracks_densities(data):
    """A deposit-backed OnlineState reproduces the binned KDE densities of
    the absorbed rows."""
    pipe = _pipe().fit(data.x[:2048], data.y[:2048])
    state = online_mod.from_context(pipe._ctx, deposit=True)
    h = kde.scott_bandwidth(data.x[:2048])
    dens = np.asarray(kde.densities_from_state(state.deposit,
                                               data.x[:2048], h))
    ref = np.asarray(kde.estimate_densities(data.x[:2048], h=h,
                                            method="binned"))
    np.testing.assert_allclose(dens, ref, rtol=1e-4, atol=1e-6)


# ------------------------------------------------------------- checkpoint --

def _chunks(lo, hi, step):
    return [(a, min(a + step, hi)) for a in range(lo, hi, step)]


def test_checkpoint_save_kill_restore_continues_bitwise(data, tmp_path):
    """save -> (simulated) kill -> restore -> continue reproduces the
    uninterrupted stream's beta bit-for-bit, and a torn newer checkpoint
    (state.npz without MANIFEST) is ignored by restore."""
    idx = _fixed_idx(1024)
    pipe = _pipe(stages=[FixedLandmarkStage(idx), SolveStage()])
    pipe.fit(data.x[:1024], data.y[:1024])
    mgr = mgr_mod.Manager(str(tmp_path), async_write=True)

    for step, (a, b) in enumerate(_chunks(1024, 2048, 512)):
        pipe.partial_fit(data.x[a:b], data.y[a:b])
        mgr.save(step, pipe.online.checkpoint_state())
    mgr.wait()

    # a kill mid-write leaves state.npz without MANIFEST: torn, ignored
    torn = tmp_path / "step_99"
    torn.mkdir()
    np.savez(torn / "state.npz", junk=np.zeros(3))
    assert mgr.latest_step() == 1

    # "restart": rebuild the pre-stream fit (same op sequence), restore the
    # checkpointed accumulators, continue the stream
    pipe2 = _pipe(stages=[FixedLandmarkStage(idx), SolveStage()])
    pipe2.fit(data.x[:1024], data.y[:1024])
    target = pipe2.online.checkpoint_state()
    restored = mgr.restore(mgr.latest_step(), target)
    pipe2.online.restore_checkpoint_state(restored)
    for a, b in _chunks(2048, N, 512):
        pipe2.partial_fit(data.x[a:b], data.y[a:b])

    # oracle: the same stream uninterrupted
    pipe3 = _pipe(stages=[FixedLandmarkStage(idx), SolveStage()])
    pipe3.fit(data.x[:1024], data.y[:1024])
    for a, b in _chunks(1024, N, 512):
        pipe3.partial_fit(data.x[a:b], data.y[a:b])
    np.testing.assert_array_equal(np.asarray(pipe2.state.fit.beta),
                                  np.asarray(pipe3.state.fit.beta))
    assert pipe2.online.rows == N


def test_save_joins_previous_async_write_before_flatten(tmp_path,
                                                        monkeypatch):
    """Regression: `save` must wait() for the in-flight async write BEFORE
    flattening the new state — flatten-first overlaps the host copy with
    the previous writer thread, so a crash strands a half-written
    checkpoint and an in-place-updated state races the old writer."""
    events = []
    orig_write = mgr_mod.Manager._write
    orig_flatten = mgr_mod._flatten

    def slow_write(self, step, flat):
        events.append(("write_start", step))
        time.sleep(0.25)
        orig_write(self, step, flat)
        events.append(("write_end", step))

    def recording_flatten(tree):
        events.append(("flatten",))
        return orig_flatten(tree)

    monkeypatch.setattr(mgr_mod.Manager, "_write", slow_write)
    monkeypatch.setattr(mgr_mod, "_flatten", recording_flatten)
    mgr = mgr_mod.Manager(str(tmp_path), async_write=True)
    state = {"a": jnp.arange(4.0)}
    mgr.save(0, state)
    mgr.save(1, state)
    mgr.wait()
    # the second flatten must come strictly after step 0's write finished
    flattens = [i for i, e in enumerate(events) if e == ("flatten",)]
    assert len(flattens) == 2
    assert events.index(("write_end", 0)) < flattens[1]
    assert sorted(mgr._steps()) == [0, 1]


def test_torn_checkpoint_dirs_are_invisible(tmp_path):
    mgr = mgr_mod.Manager(str(tmp_path), async_write=False)
    assert mgr.latest_step() is None
    (tmp_path / "step_3").mkdir()
    np.savez(tmp_path / "step_3" / "state.npz", a=np.zeros(2))
    (tmp_path / "step_4.tmp").mkdir()       # interrupted os.replace staging
    assert mgr.latest_step() is None
    mgr.save(5, {"a": jnp.zeros(2)})
    assert mgr.latest_step() == 5


# -------------------------------------------------------- online landmarks --

def test_online_landmarks_admit_and_drop_under_shift(data):
    pipe = _pipe().fit(data.x[:2048], data.y[:2048])
    ol = online_mod.seed_landmarks(pipe)
    m0 = len(ol)
    assert np.all(ol.p > 0) and np.all(ol.p <= 1)
    assert np.all(ol.u < ol.p)          # seeded members are all alive
    shifted = krr_data.bimodal(jax.random.PRNGKey(5), 512, D, offset=4.0)
    changed = ol.update(shifted.x, shifted.y)
    # far-from-dictionary points have RLS ~ 1 -> some must be admitted
    assert changed and ol.changes >= 1 and ol.updates == 1
    assert ol.n == pytest.approx(2048 + 512)
    fit = ol.refit()
    assert fit.beta.shape == (len(ol),)
    pred = np.asarray(nystrom.predict_streaming(pipe.kernel, fit,
                                                shifted.x[:64]))
    assert np.all(np.isfinite(pred))
    ds2 = krr_data.bimodal(jax.random.PRNGKey(6), 512, D)
    ol.update(ds2.x, ds2.y)
    # every surviving member's retained uniform sits below its probability
    assert np.all(ol.u < ol.p)
    assert len(ol.idx) == len(ol.p) == len(ol.u) == len(ol)
    del m0


def test_online_landmark_stage_rides_a_fold(data):
    stage = online_mod.OnlineLandmarkStage()
    from repro.pipeline import default_stages
    pipe = SAKRRPipeline(PipelineConfig(tile=TILE, lam=LAM),
                         stages=default_stages() + [stage])
    pipe.fit(data.x[:1024], data.y[:1024])
    assert stage.landmarks is not None
    assert len(stage.landmarks) == pipe.state.num_landmarks
    assert "online_landmarks" in pipe.state.seconds


# ---------------------------------------------------------- schema version --

def test_config_dict_round_trips_with_schema_version():
    cfg = PipelineConfig(nu=2.5, tile=512)
    d = cfg.to_dict()
    assert d["schema_version"] == PipelineConfig.SCHEMA_VERSION
    assert PipelineConfig.from_dict(d) == cfg


def test_config_from_dict_rejects_version_mismatch():
    d = PipelineConfig().to_dict()
    d["schema_version"] = PipelineConfig.SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema_version mismatch"):
        PipelineConfig.from_dict(d)


def test_config_from_dict_accepts_legacy_unstamped_dict():
    d = PipelineConfig(nu=0.5).to_dict()
    d.pop("schema_version")
    assert PipelineConfig.from_dict(d).nu == 0.5


def test_servable_bundle_rejects_stale_format_version(data, tmp_path):
    from repro.serving import ServableKRR

    pipe = _pipe().fit(data.x[:1024], data.y[:1024])
    art = ServableKRR.freeze(pipe)
    path = art.save(str(tmp_path / "model"))
    # rewrite the embedded meta header to a stale version
    import json
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"].tobytes()).decode())
        arrays = {k: z[k] for k in z.files if k != "__meta__"}
    meta["format_version"] = 1
    with open(path, "wb") as f:
        np.savez(f, __meta__=np.frombuffer(
            json.dumps(meta).encode(), dtype=np.uint8), **arrays)
    with pytest.raises(ValueError, match="format_version"):
        ServableKRR.load(path)
