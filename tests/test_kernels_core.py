"""Spectral-density conventions and kernel-matrix sanity (pins Theorem 1 setup)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernels as K

KERNELS = [
    K.Matern(nu=0.5, lengthscale=1.0),
    K.Matern(nu=1.5, lengthscale=1.0),
    K.Matern(nu=2.5, lengthscale=0.7),
    K.Gaussian(sigma=0.8),
]


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: repr(k))
def test_spectral_density_integrates_to_k0_1d(kern):
    # K(0) = int m(s) ds = 1 for all our unit-variance kernels (d = 1).
    s = jnp.linspace(-400.0, 400.0, 800_001)
    m = kern.spectral_density(jnp.abs(s), d=1)
    total = jnp.trapezoid(m, s)
    np.testing.assert_allclose(float(total), 1.0, rtol=2e-3)


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: repr(k))
def test_inverse_fourier_matches_kernel_1d(kern):
    # K(u) = int m(s) cos(2 pi u s) ds  (paper's ordinary-frequency convention).
    s = jnp.linspace(0.0, 400.0, 400_001)
    for u in (0.3, 1.0, 2.2):
        m = kern.spectral_density(s, d=1)
        val = 2.0 * jnp.trapezoid(m * jnp.cos(2.0 * jnp.pi * u * s), s)
        expected = float(kern.from_distance(jnp.asarray(u)))
        np.testing.assert_allclose(float(val), expected, rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: repr(k))
def test_kernel_matrix_psd_and_unit_diagonal(kern):
    x = jax.random.normal(jax.random.PRNGKey(0), (60, 3))
    km = K.kernel_matrix(kern, x)
    np.testing.assert_allclose(np.asarray(jnp.diag(km)), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(km), np.asarray(km.T), atol=1e-6)
    evals = np.linalg.eigvalsh(np.asarray(km, dtype=np.float64))
    assert evals.min() > -1e-4


def test_cross_kernel_matrix_matches_pointwise():
    kern = K.Matern(nu=1.5)
    x = jax.random.normal(jax.random.PRNGKey(1), (7, 2))
    y = jax.random.normal(jax.random.PRNGKey(2), (5, 2))
    km = K.kernel_matrix(kern, x, y)
    for i in range(7):
        for j in range(5):
            r = float(jnp.linalg.norm(x[i] - y[j]))
            np.testing.assert_allclose(
                float(km[i, j]), float(kern.from_distance(jnp.asarray(r))), rtol=2e-4, atol=1e-5
            )


def test_laplacian_is_matern_half():
    lap = K.Laplacian(lengthscale=2.0)
    r = jnp.asarray([0.0, 0.5, 1.0, 3.0])
    np.testing.assert_allclose(
        np.asarray(lap.from_distance(r)), np.exp(-np.asarray(r) / 2.0), rtol=1e-6
    )
