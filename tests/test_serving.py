"""repro.serving: frozen artifact round-trip parity + microbatching engine.

The contract under test (pipeline/README.md "Serving"): freeze -> save ->
load -> predict is BIT-equal to the live `pipeline.predict`, and the
threaded engine returns per-request results matching single-call predict.
"""

from __future__ import annotations

import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import krr_data
from repro.pipeline import PipelineConfig, SAKRRPipeline
from repro.serving import ServableKRR, ServingEngine

N, M, D = 768, 64, 3


@pytest.fixture(scope="module")
def fitted():
    data = krr_data.bimodal(jax.random.PRNGKey(0), N, d=D)
    cfg = PipelineConfig(num_landmarks=M, tile=256, seed=0)
    pipe = SAKRRPipeline(cfg).fit(data.x, data.y)
    queries = krr_data.bimodal(jax.random.PRNGKey(1), 200, d=D).x
    return pipe, ServableKRR.freeze(pipe), queries


def test_freeze_requires_fitted_pipeline():
    with pytest.raises(RuntimeError, match="needs a fitted pipeline"):
        ServableKRR.freeze(SAKRRPipeline(PipelineConfig(num_landmarks=M)))


def test_artifact_predict_bit_equal_to_pipeline(fitted):
    pipe, art, q = fitted
    np.testing.assert_array_equal(np.asarray(pipe.predict(q)),
                                  np.asarray(art.predict(q)))


def test_artifact_save_load_roundtrip_lossless(fitted, tmp_path):
    pipe, art, q = fitted
    path = art.save(os.fspath(tmp_path / "model"))
    assert path.endswith(".npz")
    loaded = ServableKRR.load(path)
    # config round-trips through JSON to an EQUAL frozen dataclass
    assert loaded.config == pipe.config
    assert (loaded.lam, loaded.bandwidth, loaded.n_fit) == (
        art.lam, art.bandwidth, art.n_fit)
    assert (loaded.backend, loaded.tile, loaded.precision) == (
        art.backend, art.tile, art.precision)
    for name in ("beta", "landmarks", "landmark_idx", "k_mm_whitener",
                 "grid_lo", "grid_hi"):
        np.testing.assert_array_equal(np.asarray(getattr(loaded, name)),
                                      np.asarray(getattr(art, name)))
    # the acceptance bar: loaded predict bit-equal to the live pipeline
    np.testing.assert_array_equal(np.asarray(loaded.predict(q)),
                                  np.asarray(pipe.predict(q)))


def test_artifact_predict_never_touches_pipeline_state(fitted):
    pipe, art, q = fitted
    before_scores = pipe.state.scores
    before_beta = np.asarray(pipe.state.fit.beta).copy()
    art.predict(q)
    assert pipe.state.scores is before_scores
    np.testing.assert_array_equal(np.asarray(pipe.state.fit.beta),
                                  before_beta)


def test_artifact_in_support_flags_grid_bounds(fitted):
    _, art, _ = fitted
    inside = jnp.asarray(art.landmarks[:4])
    outside = inside + 1e3
    assert bool(jnp.all(art.in_support(inside)))
    assert not bool(jnp.any(art.in_support(outside)))


def test_engine_matches_single_call_predict(fitted):
    _, art, q = fitted
    ref = np.asarray(art.predict(q))
    with ServingEngine(art, max_batch=64, min_bucket=8) as eng:
        # mixed request sizes, including single (d,) rows
        futs = [eng.submit(np.asarray(q[0]))]
        futs += [eng.submit(np.asarray(q[i:i + 7]))
                 for i in range(1, 50, 7)]
        out0 = futs[0].result()
        assert out0.shape == ()
        np.testing.assert_allclose(out0, ref[0], rtol=1e-6, atol=1e-6)
        for j, f in enumerate(futs[1:]):
            i = 1 + 7 * j
            np.testing.assert_allclose(f.result(), ref[i:i + 7],
                                       rtol=1e-6, atol=1e-6)
    assert eng.stats.rows == 50
    assert eng.stats.batches >= 1


def test_engine_threaded_smoke_matches_reference(fitted):
    """4 producer threads of single-row requests: every response matches
    the single-call predict for that row."""
    _, art, q = fitted
    ref = np.asarray(art.predict(q))
    errs: list[AssertionError] = []

    def producer(rows_idx):
        try:
            futs = [(i, eng.submit(np.asarray(q[i]))) for i in rows_idx]
            for i, f in futs:
                np.testing.assert_allclose(f.result(timeout=60), ref[i],
                                           rtol=1e-6, atol=1e-6)
        except AssertionError as e:       # surface across the thread edge
            errs.append(e)

    with ServingEngine(art, max_batch=32) as eng:
        eng.warm()
        threads = [threading.Thread(target=producer,
                                    args=(range(p, len(q), 4),))
                   for p in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert not errs, errs[0]
    assert eng.stats.rows == len(q)
    # microbatching actually happened: fewer dispatches than requests
    assert eng.stats.batches < len(q)
    assert eng.stats.compiles <= 4      # pow2 buckets 8..32 + warm only


def test_refresh_reuses_whitener_and_serves_new_fit(fitted):
    pipe, art, q = fitted
    fit = pipe.state.fit
    art2 = art.refresh(fit._replace(beta=fit.beta * 2.0))
    # unchanged landmark set: the frozen O(m^3) whitener is reused as-is
    assert art2.k_mm_whitener is art.k_mm_whitener
    np.testing.assert_allclose(np.asarray(art2.predict(q)),
                               2.0 * np.asarray(art.predict(q)),
                               rtol=1e-6, atol=1e-6)
    # changed dictionary (SQUEAK drop): whitener recomputed at new shape
    fit3 = fit._replace(beta=fit.beta[:-1], landmarks=fit.landmarks[:-1],
                        landmark_idx=fit.landmark_idx[:-1])
    art3 = art.refresh(fit3)
    assert art3.k_mm_whitener.shape == (M - 1, M - 1)
    assert art3.num_landmarks == M - 1


def test_hot_swap_validates_shape_compatibility(fitted):
    import dataclasses

    _, art, _ = fitted
    eng = ServingEngine(art)
    bad = dataclasses.replace(art, landmarks=jnp.zeros((M, D + 1)))
    with pytest.raises(ValueError, match="dim"):
        eng.hot_swap(bad)
    assert eng.stats.swaps == 0


def test_hot_swap_under_load_serves_exactly_one_artifact(fitted):
    """4 producer threads while the main thread hot-swaps repeatedly:
    every response matches EXACTLY one of the two artifacts (batches never
    mix weights across a swap), and stats count the swaps."""
    import time

    pipe, art, q = fitted
    fit = pipe.state.fit
    art2 = art.refresh(fit._replace(beta=fit.beta * 2.0))
    ref1 = np.asarray(art.predict(q))
    ref2 = np.asarray(art2.predict(q))
    # rows where the two artifacts are unambiguously distinguishable
    idx = np.nonzero(np.abs(ref1 - ref2) > 1e-3)[0]
    assert len(idx) >= 20
    errs: list[AssertionError] = []
    hits = [0, 0]
    lock = threading.Lock()
    stop = threading.Event()

    def producer(p):
        try:
            first_pass = True
            while first_pass or not stop.is_set():
                first_pass = False
                for i in idx[p::4]:
                    out = float(eng.predict(np.asarray(q[int(i)])))
                    is1 = abs(out - ref1[i]) < 1e-5
                    is2 = abs(out - ref2[i]) < 1e-5
                    assert is1 != is2, (out, ref1[i], ref2[i])
                    with lock:
                        hits[0 if is1 else 1] += 1
        except AssertionError as e:        # surface across the thread edge
            errs.append(e)

    with ServingEngine(art, max_batch=32) as eng:
        eng.warm()
        # deterministic pre-swap check: the seeded artifact is served
        i0 = int(idx[0])
        assert abs(float(eng.predict(np.asarray(q[i0]))) - ref1[i0]) < 1e-5
        threads = [threading.Thread(target=producer, args=(p,))
                   for p in range(4)]
        for t in threads:
            t.start()
        for target in (art2, art, art2, art, art2):
            time.sleep(0.05)
            eng.hot_swap(target)
        stop.set()
        for t in threads:
            t.join()
        # deterministic post-swap check: the last swapped artifact serves
        assert abs(float(eng.predict(np.asarray(q[i0]))) - ref2[i0]) < 1e-5
    assert not errs, errs[0]
    assert eng.stats.swaps == 5
    # every threaded response resolved to exactly one artifact
    assert sum(hits) > 0
    assert eng.artifact is art2


def test_engine_submit_validates_and_requires_start(fitted):
    _, art, _ = fitted
    eng = ServingEngine(art)
    with pytest.raises(RuntimeError, match="not started"):
        eng.submit(np.zeros((1, D)))
    with eng:
        with pytest.raises(ValueError, match="expected rows of dim"):
            eng.submit(np.zeros((2, D + 1)))
    # stop() is idempotent and re-poses the not-started error
    eng.stop()
    with pytest.raises(RuntimeError, match="not started"):
        eng.submit(np.zeros((1, D)))
