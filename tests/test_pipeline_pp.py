"""Pipeline parallelism: gpipe == sequential stage application (subprocess,
forced host devices), plus the bubble-fraction arithmetic."""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.pipeline import bubble_fraction

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax
        import jax.numpy as jnp
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"), XLA_FLAGS="")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_bubble_fraction():
    assert bubble_fraction(4, 12) == pytest.approx(3 / 15)
    assert bubble_fraction(1, 8) == 0.0


@pytest.mark.slow
def test_gpipe_matches_sequential():
    out = run_sub("""
        import numpy as np
        from repro.distributed.pipeline import gpipe

        S, M, mb, d = 4, 6, 2, 16
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        params = {
            "w1": jax.random.normal(keys[0], (S, d, 4 * d)) / np.sqrt(d),
            "w2": jax.random.normal(keys[1], (S, 4 * d, d)) / np.sqrt(4 * d),
        }
        x = jax.random.normal(keys[2], (M, mb, d))

        def layer_fn(p, h):  # one stage = one MLP block w/ residual
            return h + jax.nn.gelu(h @ p["w1"]) @ p["w2"]

        # sequential reference
        ref = x
        for s in range(S):
            sp = jax.tree.map(lambda a: a[s], params)
            ref = jax.vmap(lambda h: layer_fn(sp, h))(ref)

        mesh = jax.make_mesh((4,), ("stage",))
        got = jax.jit(lambda p, x: gpipe(layer_fn, p, x, mesh=mesh,
                                         axis="stage"))(params, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        print("GPIPE_OK")
    """)
    assert "GPIPE_OK" in out