"""Interpret-mode validation of every Pallas kernel against its ref.py oracle.

Each kernel is swept over shapes (aligned + ragged) and dtypes and checked
with assert_allclose against the pure-jnp reference.  interpret=True executes
the kernel body in Python on CPU — the same body lowers to Mosaic on TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernels as core_kernels
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.gram import ops as gram_ops
from repro.kernels.gram import ref as gram_ref
from repro.kernels.kde import ops as kde_ops
from repro.kernels.kde import ref as kde_ref
from repro.kernels.pairwise import ops as pw_ops
from repro.kernels.pairwise import ref as pw_ref
from repro.kernels.ssd import ops as ssd_ops
from repro.kernels.ssd import ref as ssd_ref


# ---------------------------------------------------------------- pairwise --
@pytest.mark.parametrize("n,m,d", [(64, 64, 4), (100, 37, 3), (257, 130, 8),
                                   (16, 300, 1)])
@pytest.mark.parametrize("kind,nu,sigma", [("matern", 0.5, 1.0),
                                           ("matern", 1.5, 1.0),
                                           ("matern", 2.5, 1.0),
                                           ("gaussian", 0.0, 0.7)])
def test_pairwise_matches_ref(n, m, d, kind, nu, sigma):
    kx, ky = jax.random.split(jax.random.PRNGKey(n * 7 + m))
    x = jax.random.normal(kx, (n, d), dtype=jnp.float32)
    y = jax.random.normal(ky, (m, d), dtype=jnp.float32)
    got = pw_ops.pairwise(x, y, kind=kind, nu=nu, a=1.3, sigma=sigma,
                          bm=32, bn=32, interpret=True)
    want = pw_ref.pairwise(x, y, kind=kind, nu=nu, a=1.3, sigma=sigma)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (48, 5), dtype=dtype)
    got = pw_ops.pairwise(x, x, kind="matern", nu=1.5, a=1.0, bm=16, bn=16,
                          interpret=True)
    want = pw_ref.pairwise(x, x, kind="matern", nu=1.5, a=1.0)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_pairwise_drop_in_for_core_kernel_matrix():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (70, 3))
    kern = core_kernels.Matern(nu=1.5, lengthscale=0.8)
    got = pw_ops.kernel_matrix(kern, x, interpret=True)
    want = core_kernels.kernel_matrix(kern, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.diag(got), 1.0, atol=1e-6)


# -------------------------------------------------------------------- gram --
@pytest.mark.parametrize("n,m,d", [(64, 32, 3), (100, 37, 3), (257, 130, 8),
                                   (16, 140, 1)])
@pytest.mark.parametrize("kind,nu,sigma", [("matern", 1.5, 1.0),
                                           ("matern", 0.5, 1.0),
                                           ("gaussian", 0.0, 0.7)])
def test_gram_matches_ref(n, m, d, kind, nu, sigma):
    kx, ky, kw = jax.random.split(jax.random.PRNGKey(n * 3 + m), 3)
    x = jax.random.normal(kx, (n, d), dtype=jnp.float32)
    y = jax.random.normal(ky, (m, d), dtype=jnp.float32)
    w = jax.random.normal(kw, (n,), dtype=jnp.float32)
    g, r = gram_ops.gram(x, y, w, kind=kind, nu=nu, a=1.3, sigma=sigma,
                         bm=32, bn=32, interpret=True)
    g_want, r_want = gram_ref.gram(x, y, w, kind=kind, nu=nu, a=1.3,
                                   sigma=sigma)
    np.testing.assert_allclose(g, g_want, rtol=2e-5, atol=1e-4)
    np.testing.assert_allclose(r, r_want, rtol=2e-5, atol=1e-4)


def test_gram_symmetry_and_kernel_object_adapter():
    key = jax.random.PRNGKey(11)
    x = jax.random.normal(key, (90, 3))
    y = x[:40]
    w = jnp.ones((90,))
    kern = core_kernels.Matern(nu=1.5, lengthscale=0.8)
    g, r = gram_ops.gram_matrix(kern, x, y, w, bm=32, bn=32, interpret=True)
    np.testing.assert_allclose(g, g.T, rtol=1e-6, atol=1e-6)  # G is PSD/symm
    k_nm = core_kernels.kernel_matrix(kern, x, y)
    np.testing.assert_allclose(g, k_nm.T @ k_nm, rtol=2e-5, atol=1e-4)
    np.testing.assert_allclose(r, k_nm.T @ w, rtol=2e-5, atol=1e-4)


# --------------------------------------------------------------------- kde --
@pytest.mark.parametrize("n,m,d", [(64, 64, 2), (90, 41, 3), (33, 260, 1)])
@pytest.mark.parametrize("h", [0.1, 0.5])
def test_kde_matches_ref(n, m, d, h):
    kq, kx = jax.random.split(jax.random.PRNGKey(n + m))
    q = jax.random.normal(kq, (n, d))
    x = jax.random.normal(kx, (m, d))
    got = kde_ops.kde(q, x, h=h, bm=32, bn=32, interpret=True)
    want = kde_ref.kde(q, x, h)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)


def test_kde_matches_core_direct():
    from repro.core import kde as core_kde
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (120, 2))
    got = kde_ops.kde(x, x, h=0.3, bm=64, bn=64, interpret=True)
    want = core_kde.kde_direct(x, x, 0.3)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)


# --------------------------------------------------------- flash attention --
@pytest.mark.parametrize("b,hq,hkv,s,dh", [(1, 4, 4, 64, 16),
                                           (2, 8, 2, 128, 32),
                                           (1, 4, 1, 96, 16)])
def test_flash_causal_matches_ref(b, hq, hkv, s, dh):
    keys = jax.random.split(jax.random.PRNGKey(b * 31 + s), 3)
    q = jax.random.normal(keys[0], (b, hq, s, dh), dtype=jnp.float32)
    k = jax.random.normal(keys[1], (b, hkv, s, dh), dtype=jnp.float32)
    v = jax.random.normal(keys[2], (b, hkv, s, dh), dtype=jnp.float32)
    got = fa_ops.attention(q, k, v, causal=True, block_q=32, block_k=32,
                           interpret=True)
    want = fa_ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [16, 48])
def test_flash_sliding_window(window):
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    b, hq, hkv, s, dh = 1, 4, 2, 128, 16
    q = jax.random.normal(keys[0], (b, hq, s, dh))
    k = jax.random.normal(keys[1], (b, hkv, s, dh))
    v = jax.random.normal(keys[2], (b, hkv, s, dh))
    got = fa_ops.attention(q, k, v, causal=True, window=window,
                           block_q=32, block_k=32, interpret=True)
    want = fa_ref.attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_ragged_seq_and_bf16():
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    b, hq, hkv, s, dh = 1, 2, 2, 83, 16  # ragged seq -> padding path
    q = jax.random.normal(keys[0], (b, hq, s, dh), dtype=jnp.bfloat16)
    k = jax.random.normal(keys[1], (b, hkv, s, dh), dtype=jnp.bfloat16)
    v = jax.random.normal(keys[2], (b, hkv, s, dh), dtype=jnp.bfloat16)
    got = fa_ops.attention(q, k, v, causal=True, block_q=32, block_k=32,
                           interpret=True)
    want = fa_ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), rtol=5e-2, atol=5e-2)


# --------------------------------------------------------------------- ssd --
def _ssd_inputs(key, b, l, h, p, s):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, l, h, p))
    # decays in (0.7, 1.0): a_log in (-0.35, 0)
    a_log = -0.35 * jax.random.uniform(ks[1], (b, l, h))
    B = jax.random.normal(ks[2], (b, l, s)) / np.sqrt(s)
    C = jax.random.normal(ks[3], (b, l, s)) / np.sqrt(s)
    return x, a_log, B, C


def test_ssd_chunked_ref_matches_scan():
    x, a_log, B, C = _ssd_inputs(jax.random.PRNGKey(0), 2, 64, 3, 8, 4)
    want = ssd_ref.ssd_scan(x, a_log, B, C)
    got = ssd_ref.ssd_chunked(x, a_log, B, C, chunk=16)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("b,l,h,p,s,chunk", [(1, 64, 2, 8, 4, 16),
                                             (2, 96, 1, 16, 8, 32),
                                             (1, 50, 2, 4, 4, 16)])
def test_ssd_pallas_matches_scan(b, l, h, p, s, chunk):
    x, a_log, B, C = _ssd_inputs(jax.random.PRNGKey(b * 13 + l), b, l, h, p, s)
    want = ssd_ref.ssd_scan(x, a_log, B, C)
    got = ssd_ops.ssd(x, a_log, B, C, chunk=chunk, interpret=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_ssd_state_decay_property():
    # with a_log == 0 (no decay) and B == C == 1/s, y_t = mean over s of
    # cumulative sum of x -> cumsum(x) exactly (s-dim inner product of ones/s).
    b, l, h, p, s = 1, 32, 1, 4, 4
    x = jax.random.normal(jax.random.PRNGKey(2), (b, l, h, p))
    a_log = jnp.zeros((b, l, h))
    B = jnp.ones((b, l, s))
    C = jnp.ones((b, l, s)) / s
    got = ssd_ops.ssd(x, a_log, B, C, chunk=8, interpret=True)
    want = jnp.cumsum(x, axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
