"""Distributed-runtime tests on forced host devices (subprocess isolation).

jax locks the device count at first backend init, so every case that needs
multiple devices runs in a fresh subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8.  Covers: logical-rule
spec mapping (pure unit tests), sharded train step numerics vs single-device,
dry-run cell lowering on a reduced mesh, and roofline HLO parsing.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

from repro.roofline import analysis as roofline

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ------------------------------------------------------------ rule mapping --
def test_logical_rules_dedupe_and_divisibility():
    import jax
    from repro.distributed import sharding as shd
    mesh = jax.make_mesh((1,), ("data",))

    class FakeMesh:  # shape-only stand-in for spec computation
        axis_names = ("pod", "data", "model")
        class devices:
            shape = (2, 16, 16)

    act = shd.Activation(FakeMesh, dict(shd.DEFAULT_RULES))
    # batch takes (pod, data); seq_kv would reuse data -> dropped
    spec = act.spec(("batch", "kv_heads", "seq_kv", None))
    assert spec[0] == ("pod", "data") and spec[1] == "model"
    assert spec[2] is None and spec[3] is None
    # non-divisible dims lose mesh axes (50280 % 16 != 0)
    spec = act.spec(("vocab", "embed"), shape=(50280, 768))
    assert spec[0] is None and spec[1] == "data"
    # divisible dims keep them
    spec = act.spec(("vocab", "embed"), shape=(50304, 768))
    assert spec[0] == "model"


# ------------------------------------------------- sharded == single device --
@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    out = run_sub("""
        import numpy as np
        from repro import configs
        from repro.data import tokens
        from repro.distributed import sharding as shd
        from repro.launch import mesh as mesh_lib
        from repro.models import model as M
        from repro.optim import adamw
        from repro.training.train import make_train_step
        import dataclasses

        cfg = dataclasses.replace(configs.get_smoke("llama3.2-1b"),
                                  compute_dtype="float32")
        hp = adamw.Hparams(clip_norm=1e9)
        data = tokens.for_config(cfg, batch=8, seq_len=16)
        batch = data.batch_at(0)
        params = M.init(jax.random.PRNGKey(0), cfg)
        opt = adamw.init(params)
        step = make_train_step(cfg, hp)

        # single-device reference
        p_ref, _, m_ref = jax.jit(step)(params, opt, batch)

        # 2x4 mesh (data x model), sharded params + batch
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh, shd.activate(mesh):
            p_sh, _, m_sh = jax.jit(step)(params, opt, batch)
        np.testing.assert_allclose(float(m_ref["loss"]),
                                   float(m_sh["loss"]), rtol=2e-4)
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-4)
        print("SHARDED_MATCH_OK")
    """)
    assert "SHARDED_MATCH_OK" in out


@pytest.mark.slow
def test_dryrun_cell_compiles_on_reduced_mesh():
    out = run_sub("""
        import dataclasses
        from repro import configs
        from repro.distributed import sharding as shd
        from repro.launch import specs
        from repro.launch.dryrun import rules_for, step_and_args
        from repro.models.config import SHAPES
        from repro.roofline import analysis as roofline

        # reduced-size mixtral on a 2x4 mesh with a scaled-down train shape
        cfg = configs.get_smoke("mixtral-8x7b")
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64,
                                    global_batch=8)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh, shd.activate(mesh, rules_for(shape, cfg)):
            fn, args = step_and_args(cfg, shape)
            compiled = jax.jit(fn).lower(*args).compile()
            cost = roofline.cost_dict(compiled)  # list/dict across versions
            assert cost.get("flops", 0) > 0
        print("CELL_COMPILE_OK", int(cost["flops"]))
    """)
    assert "CELL_COMPILE_OK" in out


# ----------------------------------------------------------- HLO parsing ----
def test_collective_bytes_parser():
    hlo = """
  %all-gather.12 = f32[512,2048]{0,1} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[256], dimensions={1}
  %all-reduce.3 = bf16[128,64]{1,0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add
  %reduce-scatter.7 = f32[32,16]{1,0} reduce-scatter(%z), replica_groups=[8,2]<=[16], dimensions={0}
  %add.1 = f32[4,4]{1,0} add(%a, %b)
  %collective-permute.9 = f32[10]{0} collective-permute(%w), source_target_pairs={{0,1}}
"""
    out = roofline.collective_bytes(hlo)
    assert out["all-gather"] == 512 * 2048 * 4 // 16
    assert out["all-reduce"] == 128 * 64 * 2
    assert out["reduce-scatter"] == 32 * 16 * 4 * 2
    assert out["collective-permute"] == 10 * 4
    assert out["all-to-all"] == 0


def test_collective_bytes_parser_tuple_async_metadata():
    # tuple-typed combined all-reduce (XLA all-reduce combiner), async
    # start/done pairs, and op names recurring inside metadata strings
    hlo = """
  %all-reduce.5 = (f32[1280,1280]{0,1}, f32[1280]{0}) all-reduce(%a, %b), channel_id=4, replica_groups=[1,256]<=[256], to_apply=%add
  %all-gather-start.2 = (f32[64,8]{1,0}, f32[64,128]{1,0}) all-gather-start(%x), channel_id=9, replica_groups=[4,16]<=[64], dimensions={1}
  %all-gather-done.2 = f32[64,128]{1,0} all-gather-done(%all-gather-start.2)
  %fusion.77 = f32[256,256]{1,0} fusion(%c), kind=kLoop, metadata={op_name="jit(f)/all-reduce/fake"}
"""
    out = roofline.collective_bytes(hlo)
    assert out["all-reduce"] == (1280 * 1280 + 1280) * 4
    assert out["all-gather"] == 64 * 128 * 4 // 16  # result/group from -start
    # the fusion line's metadata mention must NOT count
    assert sum(out.values()) == out["all-reduce"] + out["all-gather"]


def test_roofline_terms_and_bottleneck():
    r = roofline.Roofline(
        arch="a", shape="train_4k", mesh="single", chips=256,
        device_flops=1.97e14, device_bytes=819e9 * 2.0,
        device_collective_bytes=50e9 * 0.5, collective_breakdown={},
        model_flops_global=1.97e14 * 256 * 0.8)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 2.0) < 1e-9
    assert abs(r.t_collective - 0.5) < 1e-9
    assert r.bottleneck == "memory"
    assert abs(r.useful_flops_ratio - 0.8) < 1e-9
    assert abs(r.roofline_fraction - 0.4) < 1e-9
