"""Model-stack correctness: attention impl agreement + cached-decode exactness.

The decode tests are the strong ones: running the full sequence through
``forward`` must produce the same last-position logits as prefill + one-token
``decode_step`` replay — this exercises KV rings, SSM state extraction,
hybrid group wiring and RoPE position bookkeeping end to end.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.models.config import ModelConfig


def _fp32(cfg: ModelConfig, **kw) -> ModelConfig:
    return dataclasses.replace(cfg, compute_dtype="float32", remat="none",
                               **kw)


def _inputs(key, cfg: ModelConfig, b: int, s: int):
    if cfg.inputs_embeds:
        return jax.random.normal(key, (b, s, cfg.d_model), dtype=jnp.float32)
    return jax.random.randint(key, (b, s), 0, cfg.vocab_size)


# ------------------------------------------------- attention impl agreement --
@pytest.mark.parametrize("window", [-1, 24])
def test_attention_impls_agree(window):
    cfg = _fp32(configs.get_smoke("llama3.2-1b"), sliding_window=window,
                attn_chunk=16)
    key = jax.random.PRNGKey(0)
    params = M.init(key, cfg)
    x = _inputs(jax.random.PRNGKey(1), cfg, 2, 64)
    outs = {}
    for impl in ("xla", "xla_chunked", "pallas"):
        c = dataclasses.replace(cfg, attention_impl=impl)
        logits, _ = M.forward(params, x, c)
        outs[impl] = np.asarray(logits)
    np.testing.assert_allclose(outs["xla"], outs["xla_chunked"],
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs["xla"], outs["pallas"],
                               rtol=2e-3, atol=2e-3)


# ------------------------------------------------------ decode == forward ----
DECODE_ARCHS = ["llama3.2-1b", "qwen3-1.7b", "qwen1.5-0.5b", "mixtral-8x7b",
                "mamba2-130m", "zamba2-7b", "musicgen-large"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg = _fp32(configs.get_smoke(arch), capacity_factor=8.0)
    b, s, t0 = 2, 24, 8
    key = jax.random.PRNGKey(3)
    params = M.init(key, cfg)
    inputs = _inputs(jax.random.PRNGKey(4), cfg, b, s)

    full_logits, _ = M.forward(params, inputs, cfg)          # (b, s, v)

    prompt = inputs[:, :t0]
    logits0, caches = M.prefill(params, prompt, cfg, cache_seq_len=s)
    np.testing.assert_allclose(np.asarray(logits0[:, 0]),
                               np.asarray(full_logits[:, t0 - 1]),
                               rtol=2e-3, atol=2e-3)

    for t in range(t0, s):
        step_in = inputs[:, t:t + 1]
        logits_t, caches = M.decode_step(params, step_in, caches,
                                         jnp.int32(t), cfg)
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch} decode mismatch at position {t}")


def test_int8_kv_cache_decode_close_to_fp():
    # quantized serving path: same prompts, logits within ~1% of fp cache
    cfg = _fp32(configs.get_smoke("llama3.2-1b"))
    cfg_q = dataclasses.replace(cfg, kv_cache_quant=True)
    b, s, t0 = 2, 20, 8
    params = M.init(jax.random.PRNGKey(0), cfg)
    inputs = _inputs(jax.random.PRNGKey(1), cfg, b, s)
    _, caches_fp = M.prefill(params, inputs[:, :t0], cfg, cache_seq_len=s)
    _, caches_q = M.prefill(params, inputs[:, :t0], cfg_q, cache_seq_len=s)
    from repro.serving import kv_quant
    assert isinstance(caches_q["k"], kv_quant.QuantizedKV)
    for t in range(t0, s):
        la, caches_fp = M.decode_step(params, inputs[:, t:t + 1], caches_fp,
                                      jnp.int32(t), cfg)
        lb, caches_q = M.decode_step(params, inputs[:, t:t + 1], caches_q,
                                     jnp.int32(t), cfg_q)
        a = np.asarray(jax.nn.log_softmax(la[:, 0].astype(jnp.float32)))
        bq = np.asarray(jax.nn.log_softmax(lb[:, 0].astype(jnp.float32)))
        assert np.abs(a - bq).max() < 0.15, (t, np.abs(a - bq).max())
        # the quantized path's greedy pick is (near-)optimal under fp logits
        # (exact argmax can flip between near-ties of a random-init model)
        picked = np.take_along_axis(a, bq.argmax(-1)[:, None], -1)[:, 0]
        assert (a.max(-1) - picked < 0.05).all(), t


def test_swa_ring_decode_crosses_window():
    # window smaller than the sequence: ring buffer wraps during decode
    cfg = _fp32(configs.get_smoke("mixtral-8x7b"), sliding_window=12,
                capacity_factor=8.0)
    b, s, t0 = 1, 32, 6
    params = M.init(jax.random.PRNGKey(0), cfg)
    inputs = _inputs(jax.random.PRNGKey(1), cfg, b, s)
    full_logits, _ = M.forward(params, inputs, cfg)
    _, caches = M.prefill(params, inputs[:, :t0], cfg, cache_seq_len=s)
    for t in range(t0, s):
        logits_t, caches = M.decode_step(params, inputs[:, t:t + 1], caches,
                                         jnp.int32(t), cfg)
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3, err_msg=f"ring mismatch at {t}")


# ------------------------------------------------------------- scan parity ---
@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mixtral-8x7b", "mamba2-130m",
                                  "zamba2-7b"])
def test_scan_vs_unrolled_layers(arch):
    cfg = _fp32(configs.get_smoke(arch), capacity_factor=8.0)
    params = M.init(jax.random.PRNGKey(0), cfg)
    x = _inputs(jax.random.PRNGKey(1), cfg, 2, 16)
    a, _ = M.forward(params, x, cfg)
    b_, _ = M.forward(params, x, dataclasses.replace(cfg, scan_layers=False))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "zamba2-7b"])
def test_scan_vs_unrolled_prefill_decode(arch):
    cfg = _fp32(configs.get_smoke(arch), capacity_factor=8.0)
    cfg_u = dataclasses.replace(cfg, scan_layers=False)
    params = M.init(jax.random.PRNGKey(0), cfg)
    inputs = _inputs(jax.random.PRNGKey(1), cfg, 2, 12)
    la, ca = M.prefill(params, inputs, cfg, cache_seq_len=16)
    lb, cb = M.prefill(params, inputs, cfg_u, cache_seq_len=16)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=1e-5, atol=1e-5)
    for xa, xb in zip(jax.tree.leaves(ca), jax.tree.leaves(cb)):
        np.testing.assert_allclose(np.asarray(xa), np.asarray(xb),
                                   rtol=1e-5, atol=1e-5)
    tok = _inputs(jax.random.PRNGKey(2), cfg, 2, 1)
    da, _ = M.decode_step(params, tok, ca, jnp.int32(12), cfg)
    db, _ = M.decode_step(params, tok, cb, jnp.int32(12), cfg_u)
    np.testing.assert_allclose(np.asarray(da), np.asarray(db),
                               rtol=1e-5, atol=1e-5)


def test_param_count_matches_analytic():
    for arch in ("llama3.2-1b", "mixtral-8x7b", "mamba2-130m", "zamba2-7b"):
        cfg = configs.get_smoke(arch)
        from repro.models.layers import param_count
        got = param_count(M.model_specs(cfg))
        want = cfg.param_count()
        assert abs(got - want) / want < 0.02, (arch, got, want)
