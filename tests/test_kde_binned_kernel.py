"""Binned-KDE deposit subsystem: Pallas kernel (interpret) vs windowed XLA
scatter vs corner-loop oracle, density parity vs kde_direct, and
sharded-vs-single-device grid parity on a forced 2-device mesh."""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kde
from repro.kernels import dispatch
from repro.kernels.kde_binned import ops as kb_ops
from repro.kernels.kde_binned import ref as kb_ref

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _setup(d: int, g: int, n: int = 600, seed: int = 0):
    x = jax.random.uniform(jax.random.PRNGKey(seed), (n, d)) * 2.0 - 0.5
    lo = jnp.full((d,), -0.7)
    spacing = (jnp.full((d,), 1.7) - lo) / (g - 1)
    return x, lo, spacing


# ----------------------------------------------------------- scatter parity --
@pytest.mark.parametrize("d,g", [(1, 64), (2, 48), (3, 24)])
def test_scatter_pallas_matches_ref(d, g):
    x, lo, spacing = _setup(d, g)
    want = kb_ref.binned_grid(x, lo, spacing, g)
    got = kb_ops.binned_scatter(x, lo, spacing, g, bm=64, interpret=True)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)
    # total deposited mass is exactly n (partition-of-unity stencil)
    assert float(jnp.sum(got)) == pytest.approx(x.shape[0], rel=1e-5)


@pytest.mark.parametrize("d,g", [(1, 64), (2, 48), (3, 24)])
@pytest.mark.parametrize("tile", [None, 100])
def test_scatter_windowed_xla_matches_ref(d, g, tile):
    x, lo, spacing = _setup(d, g, seed=1)
    want = kb_ref.binned_grid(x, lo, spacing, g)
    got = kde.scatter_cic(x, lo, spacing, g, tile=tile)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)


def test_scatter_weighted_and_dispatch_routes():
    d, g = 2, 32
    x, lo, spacing = _setup(d, g, n=300, seed=2)
    w = jax.random.uniform(jax.random.PRNGKey(3), (300,)) + 0.5
    want = kb_ref.binned_grid(x, lo, spacing, g, weights=w)
    for backend, kw in [("xla", dict(tile=64)),
                        ("pallas", dict(interpret=True))]:
        got = dispatch.binned_scatter(x, lo, spacing, g, backend=backend,
                                      weights=w, **kw)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6,
                                   err_msg=backend)


def test_scatter_matches_corner_loop_oracle_tight():
    """The windowed deposit must reproduce the pre-refactor corner-loop
    numbers (the acceptance bar for the KDE front-end swap) at rtol 1e-5."""
    d, g = 3, 24
    x, lo, spacing = _setup(d, g, n=900, seed=4)
    old = kb_ref.binned_grid(x, lo, spacing, g)
    new = kde.scatter_cic(x, lo, spacing, g, tile=256)
    np.testing.assert_allclose(new, old, rtol=1e-5, atol=1e-7)


# ----------------------------------------------------------- density parity --
@pytest.mark.parametrize("d", [1, 2, 3])
def test_kde_binned_backends_agree_and_track_direct(d):
    """Pallas (interpret) vs XLA deposits give the same density, and both
    stay within binning error of the exact kde_direct oracle."""
    n, g, h = 1200, 96, 0.1
    x = jax.random.uniform(jax.random.PRNGKey(10 + d), (n, d))
    via_xla = np.asarray(kde.kde_binned(x, x, h, grid_size=g, backend="xla",
                                        tile=256))
    via_pallas = np.asarray(kde.kde_binned(x, x, h, grid_size=g,
                                           backend="pallas", interpret=True))
    np.testing.assert_allclose(via_pallas, via_xla, rtol=1e-5, atol=1e-9)
    direct = np.asarray(kde.kde_direct(x, x, h))
    rel = np.abs(via_xla / direct - 1.0)
    assert np.median(rel) < 0.02, np.median(rel)


def test_estimate_densities_streaming_tile_invariance():
    """The deposit tile is an execution detail: densities must not depend
    on it beyond fp32 reduction order."""
    x = jax.random.uniform(jax.random.PRNGKey(20), (2048, 3))
    a = np.asarray(kde.estimate_densities(x, tile=None))
    b = np.asarray(kde.estimate_densities(x, tile=500))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-9)


# ------------------------------------------------------------ sharded parity --
def test_sharded_kde_grid_matches_single_device():
    """kde_binned_sharded on a forced 2-device mesh == single-device
    kde_binned on the same bounds (up to psum reduction order)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.core import distributed as D
        from repro.core import kde
        from repro.distributed import sharding as shd
        assert jax.device_count() == 2
        n, d, g = 2048, 3, 48
        x = jax.random.uniform(jax.random.PRNGKey(0), (n, d))
        h = jnp.asarray(kde.scott_bandwidth(x), x.dtype)
        lo, hi = kde.binned_bounds(x, x, h)
        ref = kde.kde_binned(x, x, h, grid_size=g)
        mesh = jax.make_mesh((2,), ("data",))
        with mesh, shd.activate(mesh):
            sh = D.kde_binned_sharded(x, h, grid_size=g, lo=lo, hi=hi,
                                      tile=512)
        np.testing.assert_allclose(np.asarray(sh), np.asarray(ref),
                                   rtol=2e-5, atol=1e-9)
        print("KDE_SHARDED_OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"), XLA_FLAGS="")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "KDE_SHARDED_OK" in out.stdout
