"""Binned-KDE deposit subsystem: Pallas kernel (interpret) vs windowed XLA
scatter vs corner-loop oracle, density parity vs kde_direct, and
sharded-vs-single-device grid parity on a forced 2-device mesh."""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kde
from repro.kernels import dispatch
from repro.kernels.kde_binned import ops as kb_ops
from repro.kernels.kde_binned import ref as kb_ref

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _setup(d: int, g: int, n: int = 600, seed: int = 0):
    x = jax.random.uniform(jax.random.PRNGKey(seed), (n, d)) * 2.0 - 0.5
    lo = jnp.full((d,), -0.7)
    spacing = (jnp.full((d,), 1.7) - lo) / (g - 1)
    return x, lo, spacing


# ----------------------------------------------------------- scatter parity --
@pytest.mark.parametrize("d,g", [(1, 64), (2, 48), (3, 24)])
def test_scatter_pallas_matches_ref(d, g):
    x, lo, spacing = _setup(d, g)
    want = kb_ref.binned_grid(x, lo, spacing, g)
    got = kb_ops.binned_scatter(x, lo, spacing, g, bm=64, interpret=True)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)
    # total deposited mass is exactly n (partition-of-unity stencil)
    assert float(jnp.sum(got)) == pytest.approx(x.shape[0], rel=1e-5)


@pytest.mark.parametrize("d,g", [(1, 64), (2, 48), (3, 24)])
@pytest.mark.parametrize("tile", [None, 100])
def test_scatter_windowed_xla_matches_ref(d, g, tile):
    x, lo, spacing = _setup(d, g, seed=1)
    want = kb_ref.binned_grid(x, lo, spacing, g)
    got = kde.scatter_cic(x, lo, spacing, g, tile=tile)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)


def test_scatter_weighted_and_dispatch_routes():
    d, g = 2, 32
    x, lo, spacing = _setup(d, g, n=300, seed=2)
    w = jax.random.uniform(jax.random.PRNGKey(3), (300,)) + 0.5
    want = kb_ref.binned_grid(x, lo, spacing, g, weights=w)
    for backend, kw in [("xla", dict(tile=64)),
                        ("pallas", dict(interpret=True))]:
        got = dispatch.binned_scatter(x, lo, spacing, g, backend=backend,
                                      weights=w, **kw)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6,
                                   err_msg=backend)


def test_scatter_matches_corner_loop_oracle_tight():
    """The windowed deposit must reproduce the pre-refactor corner-loop
    numbers (the acceptance bar for the KDE front-end swap) at rtol 1e-5."""
    d, g = 3, 24
    x, lo, spacing = _setup(d, g, n=900, seed=4)
    old = kb_ref.binned_grid(x, lo, spacing, g)
    new = kde.scatter_cic(x, lo, spacing, g, tile=256)
    np.testing.assert_allclose(new, old, rtol=1e-5, atol=1e-7)


# ------------------------------------------------- segment-reduce deposit --
@pytest.mark.parametrize("d,g", [(1, 64), (2, 48), (3, 24)])
def test_scatter_segment_method_matches_window(d, g):
    """`scatter_cic(method="segment")` (sort + segment_sum, the XLA twin of
    the Pallas kernel) matches the historical windowed deposit at rtol 1e-5
    — with and without weights, tiled and one-shot."""
    x, lo, spacing = _setup(d, g, n=700, seed=5)
    w = jax.random.uniform(jax.random.PRNGKey(6), (700,)) + 0.5
    for weights in (None, w):
        for tile in (None, 128):
            want = kde.scatter_cic(x, lo, spacing, g, weights=weights,
                                   tile=tile)
            got = kde.scatter_cic(x, lo, spacing, g, weights=weights,
                                  tile=tile, method="segment")
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)


def test_scatter_duplicate_cell_collisions():
    """Hundreds of points stacked into a handful of cells: the sorted
    segment-reduce must sum every colliding corner (the regime the old
    serial scatter handled by construction, and a vectorized deposit can
    silently drop)."""
    d, g, n = 3, 24, 500
    lo = jnp.full((d,), -0.7)
    spacing = (jnp.full((d,), 1.7) - lo) / (g - 1)
    # all points land in 4 distinct cells, jittered inside each cell
    cells = jax.random.randint(jax.random.PRNGKey(7), (4, d), 2, g - 3)
    pick = jax.random.randint(jax.random.PRNGKey(8), (n,), 0, 4)
    jit_ = jax.random.uniform(jax.random.PRNGKey(9), (n, d))
    x = lo + (cells[pick] + jit_) * spacing
    want = kb_ref.binned_grid(x, lo, spacing, g)
    got = kb_ops.binned_scatter(x, lo, spacing, g, bm=64, interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    seg = kde.scatter_cic(x, lo, spacing, g, method="segment")
    np.testing.assert_allclose(seg, want, rtol=1e-5, atol=1e-6)
    assert float(jnp.sum(got)) == pytest.approx(n, rel=1e-5)


def test_scatter_pallas_compensated_state_and_parity():
    """Compensated Pallas deposit: the (hi, lo) state folds to the plain
    grid, and finalize=False returns the un-collapsed pair (the form a mesh
    psum would cross)."""
    d, g = 3, 24
    x, lo, spacing = _setup(d, g, n=900, seed=10)
    plain = kb_ops.binned_scatter(x, lo, spacing, g, bm=64, interpret=True)
    comp = kb_ops.binned_scatter(x, lo, spacing, g, bm=64, interpret=True,
                                 accumulator="compensated")
    np.testing.assert_allclose(comp, plain, rtol=1e-5, atol=1e-7)
    hi, lo_bank = kb_ops.binned_scatter(x, lo, spacing, g, bm=64,
                                        interpret=True,
                                        accumulator="compensated",
                                        finalize=False)
    assert hi.shape == lo_bank.shape == (g,) * d
    np.testing.assert_array_equal(np.asarray(hi + lo_bank), np.asarray(comp))


def test_dispatch_compensated_deposit_stays_on_pallas(monkeypatch):
    """`dispatch.binned_scatter(backend="pallas", accumulator="compensated")`
    must run the Pallas segment-reduce kernel — the historical silent
    reroute to the XLA scatter is gone."""
    from repro.core import kde as core_kde

    def boom(*a, **k):
        raise AssertionError("compensated deposit rerouted to XLA")

    monkeypatch.setattr(core_kde, "scatter_cic", boom)
    d, g = 2, 32
    x, lo, spacing = _setup(d, g, n=400, seed=11)
    want = kb_ref.binned_grid(x, lo, spacing, g)
    got = dispatch.binned_scatter(x, lo, spacing, g, backend="pallas",
                                  interpret=True,
                                  accumulator="compensated")
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)


# ----------------------------------------------------------- density parity --
@pytest.mark.parametrize("d", [1, 2, 3])
def test_kde_binned_backends_agree_and_track_direct(d):
    """Pallas (interpret) vs XLA deposits give the same density, and both
    stay within binning error of the exact kde_direct oracle."""
    n, g, h = 1200, 96, 0.1
    x = jax.random.uniform(jax.random.PRNGKey(10 + d), (n, d))
    via_xla = np.asarray(kde.kde_binned(x, x, h, grid_size=g, backend="xla",
                                        tile=256))
    via_pallas = np.asarray(kde.kde_binned(x, x, h, grid_size=g,
                                           backend="pallas", interpret=True))
    np.testing.assert_allclose(via_pallas, via_xla, rtol=1e-5, atol=1e-9)
    direct = np.asarray(kde.kde_direct(x, x, h))
    rel = np.abs(via_xla / direct - 1.0)
    assert np.median(rel) < 0.02, np.median(rel)


def test_estimate_densities_streaming_tile_invariance():
    """The deposit tile is an execution detail: densities must not depend
    on it beyond fp32 reduction order."""
    x = jax.random.uniform(jax.random.PRNGKey(20), (2048, 3))
    a = np.asarray(kde.estimate_densities(x, tile=None))
    b = np.asarray(kde.estimate_densities(x, tile=500))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-9)


# ------------------------------------------------------------ sharded parity --
def test_sharded_kde_grid_matches_single_device():
    """kde_binned_sharded on a forced 2-device mesh == single-device
    kde_binned on the same bounds (up to psum reduction order)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.core import distributed as D
        from repro.core import kde
        from repro.distributed import sharding as shd
        assert jax.device_count() == 2
        n, d, g = 2048, 3, 48
        x = jax.random.uniform(jax.random.PRNGKey(0), (n, d))
        h = jnp.asarray(kde.scott_bandwidth(x), x.dtype)
        lo, hi = kde.binned_bounds(x, x, h)
        ref = kde.kde_binned(x, x, h, grid_size=g)
        mesh = jax.make_mesh((2,), ("data",))
        with mesh, shd.activate(mesh):
            sh = D.kde_binned_sharded(x, h, grid_size=g, lo=lo, hi=hi,
                                      tile=512)
        np.testing.assert_allclose(np.asarray(sh), np.asarray(ref),
                                   rtol=2e-5, atol=1e-9)
        print("KDE_SHARDED_OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"), XLA_FLAGS="")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "KDE_SHARDED_OK" in out.stdout
