"""Weight-consuming sampling/estimator layer.

Locks in the contract that makes `state.sample_weights` load-bearing:

  * `sampling.sample_weighted_without_replacement` returns distinct Gumbel
    top-k indices plus inverse-inclusion importance weights (exponential-race
    threshold estimator) — verified against exact Plackett-Luce inclusion
    probabilities enumerated on small exhaustive cases;
  * the weighted projection-leverage estimator (`rls.projection_leverage` /
    `rls.from_sketch`) genuinely consumes the weights (weighted and
    unweighted estimates differ);
  * the weighted SoR solve (`nystrom.fit_streaming(weights=...)`) is
    invariant to positive rescaling of the weights — the regression test for
    the column-rescaling identity the solver relies on.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernels as K, nystrom, rls, sampling
from repro.data import krr_data

KERN = K.Matern(nu=1.5)

# Monte-Carlo configuration for the distributional properties.  Tolerances
# are ~3x the observed worst case at R=4096 over the strategy's prob range
# (binomial std ~ sqrt(pi (1-pi) / R) ~ 0.008; threshold-estimator bias is
# O(1/m) and bounded by the [0.2, 1.0]-bounded raw probs below).
N, M, R = 6, 3, 4096
FREQ_TOL = 0.04
WEIGHT_TOL = 0.10

# one vmapped sampler per threshold mode: "race" is the historical shared
# (m+1)-th-arrival estimator, "loo" the exact per-item leave-one-out form
# (clip-free tail) — both must match the exact Plackett-Luce enumeration
_SAMPLERS = {
    t: jax.jit(jax.vmap(
        lambda k, p, t=t: sampling.sample_weighted_without_replacement(
            k, p, M, threshold=t),
        in_axes=(0, None)))
    for t in ("race", "loo")}
_SAMPLE = _SAMPLERS["race"]
_KEYS = jax.random.split(jax.random.PRNGKey(0), R)


def pl_inclusion(q: np.ndarray, m: int) -> np.ndarray:
    """Exact Plackett-Luce inclusion probabilities by enumerating all ordered
    m-draws (Gumbel top-k == sequential sampling without replacement)."""
    n = len(q)
    pi = np.zeros(n)

    def rec(prefix, prob, rem):
        if len(prefix) == m:
            for i in prefix:
                pi[i] += prob
            return
        for i in range(n):
            if i not in prefix:
                rec(prefix + [i], prob * q[i] / rem, rem - q[i])

    rec([], 1.0, float(q.sum()))
    return pi


def _mc_stats(q: np.ndarray, threshold: str = "race"):
    """(inclusion frequency, mean of 1{i in S} * w_i) over R seeded draws."""
    idx, w = _SAMPLERS[threshold](_KEYS, jnp.asarray(q))
    idx, w = np.asarray(idx), np.asarray(w)
    freq = np.zeros(len(q))
    wacc = np.zeros(len(q))
    for i in range(len(q)):
        mask = idx == i
        freq[i] = mask.any(axis=1).mean()
        wacc[i] = (w * mask).sum() / R
    return freq, wacc


def _fixed_probs(seed: int) -> np.ndarray:
    raw = np.random.default_rng(seed).uniform(0.2, 1.0, N)
    return (raw / raw.sum()).astype(np.float32)


# Deterministic instances of the Hypothesis properties in
# tests/test_property_core.py (which importorskips hypothesis) — these run
# in every environment, the fuzzed versions run where hypothesis exists.

@pytest.mark.parametrize("seed", [0, 1])
def test_weighted_sample_indices_distinct(seed):
    q = _fixed_probs(seed)
    idx, w = sampling.sample_weighted_without_replacement(
        jax.random.PRNGKey(seed), jnp.asarray(q), M)
    assert len(np.unique(np.asarray(idx))) == M
    assert np.all(np.asarray(w) >= 1.0)     # inverse inclusion probabilities


@pytest.mark.parametrize("threshold", ["race", "loo"])
@pytest.mark.parametrize("seed", [0, 7])
def test_weights_match_exact_inclusion_on_exhaustive_case(seed, threshold):
    """freq_i ~ exact PL inclusion pi_i and E[1{i in S} w_i] ~ 1 in BOTH
    threshold modes — the conditional estimator is exact for the
    exponential race (the loo derivation in `sampling` shows why), so any
    residual here is Monte-Carlo noise, not the Pareto-race O(1/m) bias."""
    q = _fixed_probs(seed)
    pi = pl_inclusion(q, M)
    freq, wacc = _mc_stats(q, threshold)
    np.testing.assert_allclose(freq, pi, atol=FREQ_TOL)
    np.testing.assert_allclose(wacc, 1.0, atol=WEIGHT_TOL)


def test_weighted_sample_permutation_invariant_in_distribution():
    """Permuting the probs permutes the inclusion distribution: the sampler
    has no positional bias (per-key outputs differ — the Gumbel noise is
    positional — but the law is exchangeable)."""
    q = _fixed_probs(3)
    perm = np.random.default_rng(3).permutation(N)
    freq, _ = _mc_stats(q)
    freq_perm, _ = _mc_stats(q[perm])
    np.testing.assert_allclose(freq_perm, freq[perm], atol=2 * FREQ_TOL)


@pytest.mark.parametrize("threshold", ["race", "loo"])
def test_weighted_sample_full_support_weights_are_one(threshold):
    """m == n: every index is certainly included, weights are exactly 1."""
    q = jnp.asarray(np.full(5, 0.2, np.float32))
    idx, w = sampling.sample_weighted_without_replacement(
        jax.random.PRNGKey(3), q, 5, threshold=threshold)
    assert sorted(np.asarray(idx).tolist()) == [0, 1, 2, 3, 4]
    np.testing.assert_allclose(np.asarray(w), 1.0)


def test_shared_gumbel_race_reproduces_and_shares_noise():
    """Passing a precomputed race (gumbel=) is bit-identical to the in-call
    draw from the same key, and two draws with DIFFERENT probs but the same
    race differ only through the probs (the CalibrateStage h-axis
    contract): identical probs -> identical sample."""
    q = _fixed_probs(1)
    key = jax.random.PRNGKey(11)
    race = jax.random.gumbel(key, (N,), dtype=jnp.float32)
    i0, w0 = sampling.sample_weighted_without_replacement(
        key, jnp.asarray(q), M)
    i1, w1 = sampling.sample_weighted_without_replacement(
        key, jnp.asarray(q), M, gumbel=race)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(w0), np.asarray(w1))
    # a fresh key with the same explicit race still yields the same draw —
    # the race, not the key, is the only noise source
    i2, _ = sampling.sample_weighted_without_replacement(
        jax.random.PRNGKey(999), jnp.asarray(q), M, gumbel=race)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


# ------------------------------------------------------- weight consumers --

def _sketch(n=400, m=48, seed=0):
    data = krr_data.uniform(jax.random.PRNGKey(seed), n)
    lam = 1e-3
    probs = jnp.asarray(
        np.random.default_rng(seed).dirichlet(np.full(n, 2.0)).astype(
            np.float32))
    idx, w = sampling.sample_weighted_without_replacement(
        jax.random.PRNGKey(seed + 1), probs, m)
    return data, lam, idx, w


def test_projection_leverage_consumes_weights():
    """Weighted vs unweighted projection estimates must differ materially —
    the PR 2 roadmap gap ('the weights are inert') is closed."""
    data, lam, idx, w = _sketch()
    lev_w = rls.from_sketch(KERN, data.x, lam, idx, weights=w)
    lev_u = rls.from_sketch(KERN, data.x, lam, idx)
    rel = np.abs(np.asarray(lev_w.leverage) - np.asarray(lev_u.leverage))
    rel /= np.asarray(lev_u.leverage)
    assert rel.max() > 0.05, rel.max()
    # both are still valid distributions
    for lev in (lev_w, lev_u):
        probs = np.asarray(lev.probs)
        assert np.all(probs >= 0)
        np.testing.assert_allclose(probs.sum(), 1.0, rtol=1e-4)


def test_projection_leverage_weighted_full_sketch_still_exact():
    """S = [n] with CONSTANT weights reproduces exact ridge leverage: the
    projection span is all of the data, and a uniform w = c I cancels in
    W^{1/2} (W^{1/2} K W^{1/2} + mu I)^{-1} W^{1/2} only at c = 1 — so this
    pins the weight convention (inverse inclusion of a certain event = 1)."""
    from repro.core import krr
    n = 200
    data = krr_data.uniform(jax.random.PRNGKey(4), n)
    lam = 1e-3
    exact = krr.exact_leverage(KERN, data.x, lam)
    est = rls.projection_leverage(KERN, data.x, data.x, jnp.ones(n),
                                  mu=n * lam, jitter=0.0)
    np.testing.assert_allclose(np.asarray(est), np.asarray(exact.leverage),
                               rtol=2e-3, atol=2e-4)


def test_sor_solve_invariant_to_weight_rescaling():
    """Regression: the weighted SoR solve must give the same predictor for
    weights w and c*w (exact-arithmetic invariance of subset-of-regressors
    to positive column rescaling; fp32 leaves reduction-order noise)."""
    data, lam, idx, w = _sketch(n=600, m=64, seed=2)
    kern = KERN
    preds = []
    for scale in (1.0, 7.3):
        fit = nystrom.fit_streaming(kern, data.x, data.y, lam, idx, tile=256,
                                    weights=scale * w)
        preds.append(np.asarray(
            nystrom.predict_streaming(kern, fit, data.x[:200], tile=256)))
    np.testing.assert_allclose(preds[0], preds[1], atol=5e-3)
    # ... and stays consistent with the unweighted solve (same predictor)
    fit_u = nystrom.fit_streaming(kern, data.x, data.y, lam, idx, tile=256)
    pred_u = np.asarray(
        nystrom.predict_streaming(kern, fit_u, data.x[:200], tile=256))
    np.testing.assert_allclose(preds[0], pred_u, atol=5e-2)


def test_dense_weighted_solve_matches_streaming_weighted():
    data, lam, idx, w = _sketch(n=300, m=32, seed=5)
    dense = nystrom.fit_from_landmarks(KERN, data.x, data.y, lam, idx,
                                       weights=w)
    stream = nystrom.fit_streaming(KERN, data.x, data.y, lam, idx, tile=128,
                                   weights=w)
    pd = np.asarray(nystrom.predict(KERN, dense, data.x[:100]))
    ps = np.asarray(nystrom.predict(KERN, stream, data.x[:100]))
    np.testing.assert_allclose(ps, pd, rtol=2e-2, atol=2e-3)
