"""SA leverage vs the exact oracle — the paper's Theorem 5 / Figure 2 claims."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kde, kernels as K, krr, leverage
from repro.data import krr_data

KERN = K.Matern(nu=1.5)


def _ratio_stats(approx_probs, exact_probs):
    r = np.asarray(approx_probs) / np.asarray(exact_probs)
    return r.mean(), np.quantile(r, 0.05), np.quantile(r, 0.95)


def _sa_vs_exact(n, key, dataset_fn, lam_scale=0.45, lam_pow=-0.8, use_true_density=True):
    data = dataset_fn(jax.random.PRNGKey(key), n)
    lam = lam_scale * n ** lam_pow
    exact = krr.exact_leverage(KERN, data.x, lam)
    dens = data.density if use_true_density else kde.estimate_densities(data.x)
    sa = leverage.sa_leverage(dens, lam, KERN, d=data.x.shape[1], n=n)
    return sa, exact


def test_sa_matches_exact_uniform_interior():
    """Unif[0,1] is the paper's easiest case — tight interior agreement."""
    n = 800
    data = krr_data.uniform(jax.random.PRNGKey(0), n)
    lam = 0.45 * n ** -0.8
    exact = krr.exact_leverage(KERN, data.x, lam)
    sa = leverage.sa_leverage(data.density, lam, KERN, d=1, n=n)
    interior = (data.x[:, 0] > 0.1) & (data.x[:, 0] < 0.9)
    ratio = np.asarray(sa.rescaled / exact.rescaled)[np.asarray(interior)]
    # Rescaled leverage pointwise ratio near 1 on the interior.
    assert 0.8 < ratio.mean() < 1.25, ratio.mean()
    assert np.quantile(ratio, 0.05) > 0.6
    assert np.quantile(ratio, 0.95) < 1.6


def test_sa_relative_error_decreases_with_n():
    """Theorem 5: relative error -> 0 as n grows (median over interior points)."""
    errs = []
    for n in (200, 800, 2400):
        data = krr_data.uniform(jax.random.PRNGKey(1), n)
        lam = 0.45 * n ** -0.8
        exact = krr.exact_leverage(KERN, data.x, lam)
        sa = leverage.sa_leverage(data.density, lam, KERN, d=1, n=n)
        interior = np.asarray((data.x[:, 0] > 0.1) & (data.x[:, 0] < 0.9))
        rel = np.abs(np.asarray(sa.rescaled / exact.rescaled) - 1.0)[interior]
        errs.append(np.median(rel))
    assert errs[2] < errs[0], errs


def test_sa_captures_bimodal_nonuniformity():
    """Minor-mode (low-density) points must get boosted sampling probability."""
    n = 1500
    data = krr_data.bimodal_1d_paper(jax.random.PRNGKey(2), n)
    lam = 0.45 * n ** -0.8
    exact = krr.exact_leverage(KERN, data.x, lam)
    sa = leverage.sa_leverage(data.density, lam, KERN, d=1, n=n)
    minor = np.asarray(data.x[:, 0] > 0.9)
    assert minor.sum() > 5
    # Both exact and SA should give minor-mode points a higher mean probability.
    for probs in (np.asarray(exact.probs), np.asarray(sa.probs)):
        assert probs[minor].mean() > 2.0 * probs[~minor].mean()
    # And SA should broadly agree with exact on the minor/major ratio.
    boost_exact = np.asarray(exact.probs)[minor].mean() / np.asarray(exact.probs)[~minor].mean()
    boost_sa = np.asarray(sa.probs)[minor].mean() / np.asarray(sa.probs)[~minor].mean()
    assert 0.3 < boost_sa / boost_exact < 3.0


def test_sa_with_estimated_density_close_to_true_density():
    """Lemma 14: o(1) KDE error perturbs the leverage only mildly."""
    n = 1200
    data = krr_data.bimodal_1d_paper(jax.random.PRNGKey(3), n)
    lam = 0.45 * n ** -0.8
    dens_hat = kde.estimate_densities(data.x, h=0.3 * n ** (-1.0 / 3.0))
    sa_true = leverage.sa_leverage(data.density, lam, KERN, d=1, n=n)
    sa_hat = leverage.sa_leverage(dens_hat, lam, KERN, d=1, n=n, floor=1e-3)
    r = np.asarray(sa_hat.probs) / np.asarray(sa_true.probs)
    assert 0.6 < np.median(r) < 1.6


def test_rule_of_thumb_power_law():
    """ell ~ p^{d/(2 alpha) - 1}: slope of log K_tilde vs log p matches."""
    kern = K.Matern(nu=1.5)
    d = 1
    alpha = kern.alpha(d)  # = 2.0
    p = jnp.exp(jnp.linspace(jnp.log(0.05), jnp.log(2.0), 32))
    vals = leverage.matern_closed_form(p, 1e-3, kern, d)
    slope = np.polyfit(np.log(np.asarray(p)), np.log(np.asarray(vals)), 1)[0]
    np.testing.assert_allclose(slope, d / (2 * alpha) - 1.0, atol=1e-6)


def test_d_stat_estimate_matches_exact_order():
    n = 1000
    data = krr_data.uniform(jax.random.PRNGKey(4), n)
    lam = 0.45 * n ** -0.8
    exact = krr.exact_leverage(KERN, data.x, lam)
    sa = leverage.sa_leverage(data.density, lam, KERN, d=1, n=n)
    # SA's implied statistical dimension within 2x of the exact trace.
    est = float(sa.d_stat)        # = sum_i K_tilde_i / n  ~=  sum_i ell_i
    true = float(exact.d_stat)    # = Tr(K (K + n lam)^{-1})
    assert 0.5 < est / true < 2.0, (est, true)


def test_density_floor_behaviour():
    p = jnp.asarray([1e-6, 0.5, 2.0])
    out = np.asarray(leverage.density_floor(p, 0.1))
    assert out[0] == pytest.approx((0.05 + 1e-6) / 1.5)
    assert out[1] == pytest.approx(0.5)


@pytest.mark.parametrize("method", ["closed_form", "grid", "quadrature"])
@pytest.mark.parametrize("kern", [K.Matern(nu=1.5), K.Gaussian(sigma=0.7)])
def test_zero_density_yields_finite_leverage(method, kern):
    """kde_binned clips its FFT output at 0, so p_i = 0.0 reaches sa_leverage;
    every method must clamp (DENSITY_EPS) instead of emitting NaN/inf."""
    p = jnp.asarray([0.0, 0.0, 1e-12, 0.3, 1.1])
    sa = leverage.sa_leverage(p, lam=1e-3, kernel=kern, d=1, n=5, method=method)
    assert np.isfinite(np.asarray(sa.rescaled)).all(), sa.rescaled
    assert np.isfinite(np.asarray(sa.probs)).all(), sa.probs
    np.testing.assert_allclose(float(jnp.sum(sa.probs)), 1.0, rtol=1e-5)


def test_zero_density_closed_forms_finite_directly():
    p = jnp.zeros((4,))
    mat = leverage.matern_closed_form(p, 1e-3, K.Matern(nu=1.5), d=1)
    gau = leverage.gaussian_closed_form(p, 1e-3, K.Gaussian(sigma=1.0), d=1)
    assert np.isfinite(np.asarray(mat)).all()
    assert np.isfinite(np.asarray(gau)).all()
