"""First-class accumulator state (repro.core.accstate): the monoid
contract and its bit-parity guarantees.

Locked here:

  * absorbing a stream in ANY tile-aligned partition reproduces the
    one-shot fold BIT-FOR-BIT (the scan carry continues across absorbs —
    for the plain AND the compensated strategy, Gram and deposit alike);
  * merge is bitwise commutative (IEEE add / TwoSum are symmetric) and
    any merge order agrees within the compensated tolerance;
  * decayed absorption matches an oracle reweighted full refit;
  * the raw pair crosses a forced-2-device psum and merges with a
    replicated prior correctly (subprocess, slow).

A hypothesis property version of the partition test runs when hypothesis
is installed; the seeded parametrized version below always runs.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import accstate, kde, kernels as K, nystrom, streaming
from repro.data import krr_data

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KERN = K.Matern(nu=1.5)
N, D, M, TILE = 2048, 2, 48, 256
LAM = 1e-4


def _data(seed=0, n=N, offset=2.0):
    return krr_data.bimodal(jax.random.PRNGKey(seed), n, D, offset=offset)


def _landmarks(n=N, m=M, seed=3):
    idx = np.random.default_rng(seed).choice(n, m, replace=False)
    return jnp.asarray(np.sort(idx), jnp.int32)


def _partition(seed: int, n: int, tile: int) -> list[tuple[int, int]]:
    """Random tile-aligned partition of [0, n) into >= 2 chunks."""
    rng = np.random.default_rng(seed)
    n_tiles = n // tile
    k = int(rng.integers(1, n_tiles))            # cut points, >= 1
    cuts = np.sort(rng.choice(np.arange(1, n_tiles), size=min(k, n_tiles - 1),
                              replace=False)) * tile
    bounds = np.concatenate([[0], cuts, [n]])
    return list(zip(bounds[:-1], bounds[1:]))


def _absorb_partition(ds, idx, accumulator, parts):
    state = nystrom.normal_eq_init(KERN, ds.x[idx], idx, tile=TILE,
                                   accumulator=accumulator)
    for lo, hi in parts:
        state = nystrom.normal_eq_absorb(KERN, state, ds.x[lo:hi],
                                         ds.y[lo:hi])
    return state


# --------------------------------------------------- partition bit-parity --

@pytest.mark.parametrize("accumulator", ["plain", "compensated"])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_any_tile_aligned_partition_is_bit_equal_to_one_shot(
        accumulator, seed):
    ds = _data()
    idx = _landmarks()
    fit_ref, ref = nystrom.fit_streaming(
        KERN, ds.x, ds.y, LAM, idx, tile=TILE, accumulator=accumulator,
        return_state=True)
    parts = _partition(seed, N, TILE)
    assert len(parts) >= 2
    state = _absorb_partition(ds, idx, accumulator, parts)
    g_ref, rhs_ref = accstate.finalize(ref.acc)
    g, rhs = accstate.finalize(state.acc)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(g_ref))
    np.testing.assert_array_equal(np.asarray(rhs).ravel(),
                                  np.asarray(rhs_ref).ravel())
    assert accstate.rows_of(state.acc) == N
    assert accstate.steps_of(state.acc) == accstate.steps_of(ref.acc)
    fit_inc = nystrom.solve_from_state(state, LAM)
    np.testing.assert_array_equal(np.asarray(fit_inc.beta),
                                  np.asarray(fit_ref.beta))


@pytest.mark.parametrize("accumulator", ["plain", "compensated"])
def test_deposit_partition_is_bit_equal_to_one_shot(accumulator):
    ds = _data()
    h = jnp.asarray(0.4, ds.x.dtype)
    lo, hi = kde.binned_bounds(ds.x, ds.x, h)
    grid = 32
    one_shot = kde.scatter_cic(
        ds.x, lo, (hi - lo) / (grid - 1), grid, tile=TILE,
        accumulator=accumulator)
    for seed in (0, 1):
        state = kde.deposit_init(lo, hi, grid, tile=TILE,
                                 accumulator=accumulator)
        for a, b in _partition(seed, N, TILE):
            state = kde.deposit_absorb(state, ds.x[a:b])
        np.testing.assert_array_equal(np.asarray(kde.deposit_finalize(state)),
                                      np.asarray(one_shot))


def test_hypothesis_partition_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ds = _data()
    idx = _landmarks()
    _, ref = nystrom.fit_streaming(KERN, ds.x, ds.y, LAM, idx, tile=TILE,
                                   return_state=True)
    g_ref, rhs_ref = accstate.finalize(ref.acc)

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def prop(seed):
        state = _absorb_partition(ds, idx, "plain",
                                  _partition(seed, N, TILE))
        g, rhs = accstate.finalize(state.acc)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(g_ref))
        np.testing.assert_array_equal(np.asarray(rhs).ravel(),
                                      np.asarray(rhs_ref).ravel())

    prop()


# ----------------------------------------------------------------- merge --

@pytest.mark.parametrize("accumulator", ["plain", "compensated"])
def test_merge_is_bitwise_commutative(accumulator):
    ds = _data()
    idx = _landmarks()
    a = _absorb_partition(ds, idx, accumulator, [(0, N // 2)])
    b = _absorb_partition(ds, idx, accumulator, [(N // 2, N)])
    ab = nystrom.normal_eq_merge(a, b)
    ba = nystrom.normal_eq_merge(b, a)
    for la, lb in zip(jax.tree.leaves(ab.acc), jax.tree.leaves(ba.acc)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


@pytest.mark.parametrize("accumulator", ["plain", "compensated"])
def test_merge_any_order_matches_one_shot_within_tolerance(accumulator):
    """Merging independently-built chunk states in any order reproduces the
    one-shot moments to reassociation tolerance; the PREDICTIONS (the
    well-conditioned functional — the whitened solve can amplify last-bit
    G noise through its smallest retained eigenvalues) stay tight."""
    ds = _data()
    idx = _landmarks()
    fit_ref, ref = nystrom.fit_streaming(
        KERN, ds.x, ds.y, LAM, idx, tile=TILE, accumulator=accumulator,
        return_state=True)
    g_ref, rhs_ref = accstate.finalize(ref.acc)
    x_q = ds.x[:64]
    f_ref = np.asarray(nystrom.predict_streaming(KERN, fit_ref, x_q))
    quarters = [(i * N // 4, (i + 1) * N // 4) for i in range(4)]
    states = [_absorb_partition(ds, idx, accumulator, [q]) for q in quarters]
    for order in ((0, 1, 2, 3), (3, 1, 0, 2), (2, 3, 0, 1)):
        merged = states[order[0]]
        for j in order[1:]:
            merged = nystrom.normal_eq_merge(merged, states[j])
        assert accstate.rows_of(merged.acc) == N
        g, rhs = accstate.finalize(merged.acc)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                                   rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(np.asarray(rhs).ravel(),
                                   np.asarray(rhs_ref).ravel(),
                                   rtol=1e-5, atol=1e-4)
        fit = nystrom.solve_from_state(merged, LAM)
        f = np.asarray(nystrom.predict_streaming(KERN, fit, x_q))
        np.testing.assert_allclose(f, f_ref, rtol=2e-3, atol=2e-3)


def test_merge_rejects_spec_mismatch():
    ds = _data()
    idx = _landmarks()
    a = _absorb_partition(ds, idx, "plain", [(0, N // 2)])
    b = _absorb_partition(ds, idx, "compensated", [(N // 2, N)])
    with pytest.raises(ValueError, match="spec"):
        nystrom.normal_eq_merge(a, b)


# ----------------------------------------------------------------- decay --

@pytest.mark.parametrize("accumulator", ["plain", "compensated"])
def test_decayed_absorb_matches_oracle_reweighted_refit(accumulator):
    """decay(gamma) then absorb == the oracle that refits with every old
    row's contribution scaled by gamma (G and rhs are row-additive)."""
    gamma = 0.75
    ds = _data()
    idx = _landmarks()
    half = N // 2
    state = _absorb_partition(ds, idx, accumulator, [(0, half)])
    state = nystrom.normal_eq_decay(state, gamma)
    state = nystrom.normal_eq_absorb(KERN, state, ds.x[half:], ds.y[half:])
    g, rhs = accstate.finalize(state.acc)

    old = _absorb_partition(ds, idx, accumulator, [(0, half)])
    new = _absorb_partition(ds, idx, accumulator, [(half, N)])
    g_old, rhs_old = accstate.finalize(old.acc)
    g_new, rhs_new = accstate.finalize(new.acc)
    np.testing.assert_allclose(np.asarray(g),
                               gamma * np.asarray(g_old) + np.asarray(g_new),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(rhs).ravel(),
        gamma * np.asarray(rhs_old).ravel() + np.asarray(rhs_new).ravel(),
        rtol=1e-5, atol=1e-5)
    assert np.isclose(accstate.rows_of(state.acc), gamma * half + half)

    # the solve at the decayed state matches the oracle solve on the
    # reweighted moments with the effective sample size — compared through
    # the prediction functional (beta components under the smallest
    # retained eigenvalues amplify last-bit moment noise).  Only the plain
    # floor admits this oracle: the compensated floor sits BELOW the f32
    # finalization noise of the hand-assembled oracle moments, so a
    # near-cutoff eigendirection can flip between the two solves — for
    # compensated the moment-level identity above is the oracle.
    if accumulator == "plain":
        fit = nystrom.solve_from_state(state, LAM)
        n_eff = gamma * half + half
        beta_oracle = nystrom.solve_normal_eq(
            jnp.asarray(gamma * np.asarray(g_old) + np.asarray(g_new)),
            jnp.asarray(gamma * np.asarray(rhs_old).ravel()
                        + np.asarray(rhs_new).ravel()),
            state.k_mm, n_eff, LAM)
        fit_oracle = nystrom.NystromFit(beta=beta_oracle,
                                        landmarks=fit.landmarks,
                                        landmark_idx=fit.landmark_idx,
                                        lam=LAM)
        x_q = ds.x[:64]
        np.testing.assert_allclose(
            np.asarray(nystrom.predict_streaming(KERN, fit, x_q)),
            np.asarray(nystrom.predict_streaming(KERN, fit_oracle, x_q)),
            rtol=2e-3, atol=2e-3)


def test_decay_scales_hi_and_lo_and_preserves_steps():
    ds = _data()
    idx = _landmarks()
    state = _absorb_partition(ds, idx, "compensated", [(0, N)])
    steps0 = accstate.steps_of(state.acc)
    dec = accstate.decay(state.acc, 0.5)
    hi0, lo0 = state.acc.value
    hi1, lo1 = dec.value
    for a, b in zip(jax.tree.leaves(hi0), jax.tree.leaves(hi1)):
        np.testing.assert_array_equal(np.asarray(a) * np.float32(0.5),
                                      np.asarray(b))
    for a, b in zip(jax.tree.leaves(lo0), jax.tree.leaves(lo1)):
        np.testing.assert_array_equal(np.asarray(a) * np.float32(0.5),
                                      np.asarray(b))
    assert accstate.steps_of(dec) == steps0
    assert accstate.rows_of(dec) == pytest.approx(N * 0.5)


# ---------------------------------------------------------------- window --

def test_sliding_window_drops_oldest_chunk_exactly():
    ds = _data()
    idx = _landmarks()
    quarters = [(i * N // 4, (i + 1) * N // 4) for i in range(4)]
    states = [_absorb_partition(ds, idx, "plain", [q]) for q in quarters]
    win = accstate.SlidingWindow(2, merge_fn=nystrom.normal_eq_merge)
    for s in states:
        win.push(s)
    assert len(win) == 2
    folded = win.state()
    oracle = nystrom.normal_eq_merge(states[2], states[3])
    for la, lb in zip(jax.tree.leaves(folded.acc),
                      jax.tree.leaves(oracle.acc)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# --------------------------------------------------------- pytree plumbing --

def test_accstate_roundtrips_as_pytree():
    ds = _data()
    idx = _landmarks()
    state = _absorb_partition(ds, idx, "compensated", [(0, N)])
    leaves, treedef = jax.tree_util.tree_flatten(state)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.acc.spec == state.acc.spec
    assert rebuilt.accumulator == state.accumulator
    fit_a = nystrom.solve_from_state(state, LAM)
    fit_b = nystrom.solve_from_state(rebuilt, LAM)
    np.testing.assert_array_equal(np.asarray(fit_a.beta),
                                  np.asarray(fit_b.beta))


def test_normalize_spec_validates():
    assert accstate.normalize_spec("plain") == "plain"
    assert accstate.normalize_spec(["plain", "compensated"]) == (
        "plain", "compensated")
    with pytest.raises((KeyError, ValueError)):
        accstate.normalize_spec("nope")


# ------------------------------------------------------ forced two-device --

@pytest.mark.slow
def test_merge_with_replicated_prior_across_psum_two_devices():
    """Under a forced 2-device mesh, `mesh_reduce(init_state=...)` must add
    the prior ONCE (merged after the psum) — threading it through each
    chip's local fold would double-count it."""
    code = textwrap.dedent("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.core import accstate, kernels as K, nystrom, streaming
        from repro.distributed import sharding as shd
        from repro.data import krr_data

        kern = K.Matern(nu=1.5)
        ds = krr_data.bimodal(jax.random.PRNGKey(0), 1024, 2)
        idx = jnp.arange(48, dtype=jnp.int32)
        assert jax.device_count() == 2

        # prior built single-device, then absorbed under the mesh
        prior = nystrom.normal_eq_init(kern, ds.x[idx], idx, tile=128)
        prior = nystrom.normal_eq_absorb(kern, prior, ds.x[:512], ds.y[:512])
        mesh = jax.make_mesh((2,), ("data",))
        with mesh, shd.activate(mesh):
            state = nystrom.normal_eq_absorb(kern, prior,
                                             ds.x[512:], ds.y[512:])
        g, rhs = accstate.finalize(state.acc)

        ref = nystrom.normal_eq_init(kern, ds.x[idx], idx, tile=128)
        ref = nystrom.normal_eq_absorb(kern, ref, ds.x, ds.y)
        g_ref, rhs_ref = accstate.finalize(ref.acc)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(rhs).ravel(),
                                   np.asarray(rhs_ref).ravel(),
                                   rtol=1e-5, atol=1e-5)
        assert float(state.acc.rows) == 1024.0
        print("TWO_DEVICE_MERGE_OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "TWO_DEVICE_MERGE_OK" in out.stdout
