"""Streaming Nyström solve: parity vs the dense oracle, Pallas gram backend,
and sharded-vs-single-device equivalence on a forced 2-device host mesh.

The ≤ 1e-4 beta-parity contract is checked under enable_x64 in a subprocess
(the streaming machinery is dtype-preserving): in f64 the two paths differ
only by reduction order, ~1e-10.  In f32 the normal equations' conditioning
(~1e6 at the paper's lam) amplifies fp32 epsilon into the few-1e-3 range on
beta, so the in-process f32 checks assert the stable functional (fitted
values) instead.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernels as K, nystrom
from repro.data import krr_data

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KERN = K.Matern(nu=1.5)


def run_sub(body: str, env_extra: dict | None = None) -> str:
    code = textwrap.dedent("""
        import jax
        import jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               **(env_extra or {}))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ------------------------------------------------------------------ parity --

def test_streaming_beta_parity_x64():
    """Acceptance contract: streaming beta == dense beta to <= 1e-4 relative
    (n = 4096 fixture, both XLA-scan and Pallas-gram interpret backends)."""
    out = run_sub("""
        from repro.core import kernels as K, nystrom
        n, m, d = 4096, 256, 3
        kx, ky, kw = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jax.random.normal(kx, (n, d), dtype=jnp.float64)
        y = jax.random.normal(kw, (n,), dtype=jnp.float64)
        idx = jax.random.randint(ky, (m,), 0, n)   # with replacement
        kern = K.Matern(nu=1.5)
        lam = 0.075 * n ** (-2.0 / 3.0)
        dense = nystrom.fit_from_landmarks(kern, x, y, lam, idx)
        for backend, kw2 in (("xla", {}), ("pallas", dict(interpret=True))):
            st = nystrom.fit_streaming(kern, x, y, lam, idx, tile=512,
                                       backend=backend, **kw2)
            rel = float(jnp.linalg.norm(st.beta - dense.beta)
                        / jnp.linalg.norm(dense.beta))
            assert rel < 1e-4, (backend, rel)
        print("BETA_PARITY_OK")
    """, env_extra={"JAX_ENABLE_X64": "1"})
    assert "BETA_PARITY_OK" in out


def test_streaming_fitted_parity_f32():
    """f32 in-process: fitted values (the conditioning-stable functional)
    agree between streaming backends and the dense solve."""
    n, m = 2048, 128
    data = krr_data.bimodal(jax.random.PRNGKey(0), n, d=3)
    lam = 0.075 * n ** (-2.0 / 3.0)
    idx = jax.random.randint(jax.random.PRNGKey(1), (m,), 0, n)
    dense = nystrom.fit_from_landmarks(KERN, data.x, data.y, lam, idx)
    want = np.asarray(nystrom.fitted(KERN, dense, data.x))
    for backend, kw in (("xla", {}), ("pallas", dict(interpret=True))):
        st = nystrom.fit_streaming(KERN, data.x, data.y, lam, idx, tile=256,
                                   backend=backend, **kw)
        got = np.asarray(nystrom.predict_streaming(KERN, st, data.x, tile=256))
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-3,
                                   err_msg=backend)


def test_scan_normal_eq_matches_dense_gram():
    n, m = 1000, 64
    kx, ky, kw = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.normal(kx, (n, 3))
    w = jax.random.normal(kw, (n,))
    xm = x[jax.random.permutation(ky, n)[:m]]
    k_nm = K.kernel_matrix(KERN, x, xm)
    g, r = nystrom.scan_normal_eq(KERN, x, xm, w, tile=192)  # ragged last tile
    np.testing.assert_allclose(np.asarray(g), np.asarray(k_nm.T @ k_nm),
                               rtol=2e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(r), np.asarray(k_nm.T @ w),
                               rtol=2e-5, atol=1e-3)


def test_predict_streaming_matches_dense_predict():
    n, m = 700, 48
    data = krr_data.bimodal(jax.random.PRNGKey(3), n, d=3)
    lam = 1e-3
    idx = jax.random.randint(jax.random.PRNGKey(4), (m,), 0, n)
    fit_ = nystrom.fit_from_landmarks(KERN, data.x, data.y, lam, idx)
    want = np.asarray(nystrom.predict(KERN, fit_, data.x[:333]))
    got = np.asarray(nystrom.predict_streaming(KERN, fit_, data.x[:333],
                                               tile=100))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------- sharded --

def test_sharded_streaming_matches_single_device():
    """Fake 2-device host mesh: rows sharded on the 'data' axis, Gram/rhs
    psum-reduced; equals the single-device solve up to reduction order."""
    out = run_sub("""
        import os
        from repro.core import kernels as K, nystrom
        from repro.distributed import sharding as shd
        assert jax.device_count() == 2, jax.devices()
        n, m = 2048, 64
        kx, ky, kw = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jax.random.normal(kx, (n, 3))
        y = jax.random.normal(kw, (n,))
        idx = jax.random.randint(ky, (m,), 0, n)
        kern = K.Matern(nu=1.5)
        lam = 1e-3
        ref = nystrom.fit_streaming(kern, x, y, lam, idx, tile=256)
        mesh = jax.make_mesh((2,), ("data",))
        with mesh, shd.activate(mesh):
            sh = nystrom.fit_streaming(kern, x, y, lam, idx, tile=256)
        rel = float(jnp.linalg.norm(sh.beta - ref.beta)
                    / jnp.linalg.norm(ref.beta))
        assert rel < 2e-3, rel
        fr = nystrom.predict_streaming(kern, ref, x[:256])
        fs = nystrom.predict_streaming(kern, sh, x[:256])
        np.testing.assert_allclose(np.asarray(fs), np.asarray(fr),
                                   rtol=2e-2, atol=2e-3)
        print("SHARDED_STREAM_OK")
    """, env_extra={"XLA_FLAGS": "--xla_force_host_platform_device_count=2"})
    assert "SHARDED_STREAM_OK" in out


def test_indivisible_rows_fall_back_to_single_device():
    """n not divisible by the data axis -> the rules table drops the axis
    (replicated) and the solve still runs, matching the unsharded result."""
    out = run_sub("""
        from repro.core import kernels as K, nystrom
        from repro.distributed import sharding as shd
        n, m = 1027, 32   # prime-ish: not divisible by 2
        kx, ky, kw = jax.random.split(jax.random.PRNGKey(5), 3)
        x = jax.random.normal(kx, (n, 3))
        y = jax.random.normal(kw, (n,))
        idx = jax.random.randint(ky, (m,), 0, n)
        kern = K.Matern(nu=1.5)
        ref = nystrom.fit_streaming(kern, x, y, 1e-3, idx, tile=256)
        mesh = jax.make_mesh((2,), ("data",))
        with mesh, shd.activate(mesh):
            sh = nystrom.fit_streaming(kern, x, y, 1e-3, idx, tile=256)
        np.testing.assert_allclose(np.asarray(sh.beta), np.asarray(ref.beta),
                                   rtol=1e-4, atol=1e-6)
        print("FALLBACK_OK")
    """, env_extra={"XLA_FLAGS": "--xla_force_host_platform_device_count=2"})
    assert "FALLBACK_OK" in out
