"""Per-arch smoke tests (reduced configs): one forward + one train step on CPU,
asserting output shapes and the absence of NaNs — required by the brief for
every assigned architecture."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M


def _batch(key, cfg, b=2, s=16):
    k1, k2 = jax.random.split(key)
    if cfg.inputs_embeds:
        inputs = jax.random.normal(k1, (b, s, cfg.d_model), dtype=jnp.float32)
    else:
        inputs = jax.random.randint(k1, (b, s), 0, cfg.vocab_size)
    labels = jax.random.randint(k2, (b, s), 0, cfg.vocab_size)
    return {"inputs": inputs, "labels": labels}


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_forward_and_train_step(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(42)
    params = M.init(key, cfg)
    batch = _batch(jax.random.PRNGKey(1), cfg)

    logits, aux = M.forward(params, batch["inputs"], cfg)
    b, s = batch["labels"].shape
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all(), arch

    (loss, metrics), grads = jax.value_and_grad(M.loss_fn, has_aux=True)(
        params, batch, cfg)
    assert np.isfinite(float(loss)), arch
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, dtype=np.float32)).all()
               for g in flat), f"{arch}: non-finite grads"
    # sgd step must change the loss (graph is differentiable end-to-end)
    new_params = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype),
                              params, grads)
    loss2, _ = M.loss_fn(new_params, batch, cfg)
    assert float(loss2) != float(loss), arch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_full_config_geometry(arch):
    """The full (not smoke) configs match the published geometry exactly."""
    cfg = configs.get(arch)
    spec = {
        "mamba2-130m": dict(num_layers=24, d_model=768, vocab_size=50280,
                            ssm_state=128),
        "qwen3-1.7b": dict(num_layers=28, d_model=2048, n_heads=16,
                           n_kv_heads=8, d_ff=6144, vocab_size=151936,
                           qk_norm=True),
        "llama3.2-1b": dict(num_layers=16, d_model=2048, n_heads=32,
                            n_kv_heads=8, d_ff=8192, vocab_size=128256),
        "stablelm-12b": dict(num_layers=40, d_model=5120, n_heads=32,
                             n_kv_heads=8, d_ff=13824, vocab_size=100352),
        "qwen1.5-0.5b": dict(num_layers=24, d_model=1024, n_heads=16,
                             n_kv_heads=16, d_ff=2816, vocab_size=151936,
                             qkv_bias=True),
        "mixtral-8x7b": dict(num_layers=32, d_model=4096, n_heads=32,
                             n_kv_heads=8, d_ff=14336, vocab_size=32000,
                             n_experts=8, top_k=2, sliding_window=4096),
        "mixtral-8x22b": dict(num_layers=56, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab_size=32768,
                              n_experts=8, top_k=2),
        "musicgen-large": dict(num_layers=48, d_model=2048, n_heads=32,
                               n_kv_heads=32, d_ff=8192, vocab_size=2048,
                               inputs_embeds=True),
        "zamba2-7b": dict(num_layers=81, d_model=3584, n_heads=32,
                          n_kv_heads=32, d_ff=14336, vocab_size=32000,
                          ssm_state=64),
        "llava-next-34b": dict(num_layers=60, d_model=7168, n_heads=56,
                               n_kv_heads=8, d_ff=20480, vocab_size=64000,
                               inputs_embeds=True),
    }[arch]
    for k, v in spec.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
