"""Numerical substrates of Eq. (6): polylog and radial quadrature."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernels as K
from repro.core import leverage, polylog, quadrature


def test_neg_polylog_s1_is_log1p():
    x = jnp.asarray([1e-3, 0.1, 1.0, 10.0, 1e4, 1e8])
    got = polylog.neg_polylog(1.0, x)
    np.testing.assert_allclose(np.asarray(got), np.log1p(np.asarray(x)), rtol=1e-5)


@pytest.mark.parametrize("s", [0.5, 1.5, 2.5, 4.0])
def test_neg_polylog_matches_series_small_x(s):
    x = jnp.asarray([0.01, 0.1, 0.5, 0.8])
    got = polylog.neg_polylog(s, x)
    want = polylog.neg_polylog_series(s, x, terms=4000)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


def test_neg_polylog_monotone_and_zero_at_zero():
    x = jnp.linspace(0.0, 50.0, 101)
    f = np.asarray(polylog.neg_polylog(1.5, x))
    assert f[0] == pytest.approx(0.0, abs=1e-7)
    assert np.all(np.diff(f) > 0)


@pytest.mark.parametrize(
    "nu,d,bound",
    # Closed-form error is O(lam^{1/alpha}), alpha = nu + d/2: the larger
    # alpha, the slower the decay — bounds scale accordingly.
    [(0.5, 1, 0.03), (1.5, 1, 0.06), (1.5, 2, 0.08), (2.5, 3, 0.2)],
)
def test_matern_quadrature_close_to_closed_form(nu, d, bound):
    """App. D.2: dropping +a^2 gives O(lam^{1/alpha}) relative error."""
    kern = K.Matern(nu=nu)
    lam = 1e-4
    p = jnp.asarray([0.05, 0.2, 1.0, 4.0])
    exact = quadrature.radial_integral(p, lam, kern, d, order=512)
    closed = leverage.matern_closed_form(p, lam, kern, d)
    rel = np.abs(np.asarray(closed) / np.asarray(exact) - 1.0)
    assert rel.max() < bound, rel


def test_matern_closed_form_error_shrinks_with_lambda():
    kern = K.Matern(nu=1.5)
    p = jnp.asarray([0.5])
    rels = []
    for lam in (1e-2, 1e-3, 1e-4):
        exact = quadrature.radial_integral(p, lam, kern, 1, order=512)
        closed = leverage.matern_closed_form(p, lam, kern, 1)
        rels.append(abs(float(closed[0] / exact[0]) - 1.0))
    assert rels[0] > rels[1] > rels[2]


@pytest.mark.parametrize("d", [1, 2, 3])
def test_gaussian_quadrature_matches_polylog_closed_form(d):
    kern = K.Gaussian(sigma=0.5)
    lam = 1e-3
    p = jnp.asarray([0.05, 0.3, 1.0, 5.0])
    quad = quadrature.radial_integral(p, lam, kern, d, order=512)
    closed = leverage.gaussian_closed_form(p, lam, kern, d)
    np.testing.assert_allclose(np.asarray(quad), np.asarray(closed), rtol=2e-3)


def test_radial_integral_decreasing_in_density():
    kern = K.Matern(nu=1.5)
    p = jnp.linspace(0.05, 5.0, 64)
    vals = np.asarray(quadrature.radial_integral(p, 1e-3, kern, 2))
    assert np.all(np.diff(vals) < 0)


def test_grid_interpolation_matches_direct_quadrature():
    kern = K.Matern(nu=1.5)
    lam = 2e-4
    p = jnp.exp(jnp.linspace(jnp.log(0.02), jnp.log(8.0), 500))
    direct = leverage.sa_leverage(p, lam, kern, d=2, method="quadrature").rescaled
    grid = leverage.sa_leverage(p, lam, kern, d=2, method="grid").rescaled
    np.testing.assert_allclose(np.asarray(grid), np.asarray(direct), rtol=2e-3)
