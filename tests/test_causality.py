"""Causality property tests: token t's logits must not depend on tokens > t.

This is the strongest single invariant for sequence models — it exercises
causal masking in all attention impls, the SWA window mask, the SSD scan
direction, the depthwise conv padding, and hybrid wiring at once.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M


def _fp32(cfg):
    return dataclasses.replace(cfg, compute_dtype="float32", remat="none",
                               capacity_factor=8.0)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen3-1.7b", "mixtral-8x7b",
                                  "mamba2-130m", "zamba2-7b"])
def test_future_tokens_do_not_change_past_logits(arch):
    cfg = _fp32(configs.get_smoke(arch))
    b, s, t = 2, 20, 11
    params = M.init(jax.random.PRNGKey(0), cfg)
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    if cfg.inputs_embeds:
        base = jax.random.normal(k1, (b, s, cfg.d_model))
        alt = base.at[:, t:].set(jax.random.normal(k2, (b, s - t,
                                                        cfg.d_model)))
    else:
        base = jax.random.randint(k1, (b, s), 0, cfg.vocab_size)
        alt = base.at[:, t:].set(
            jax.random.randint(k2, (b, s - t), 0, cfg.vocab_size))
    la, _ = M.forward(params, base, cfg)
    lb, _ = M.forward(params, alt, cfg)
    np.testing.assert_allclose(np.asarray(la[:, :t]), np.asarray(lb[:, :t]),
                               rtol=1e-5, atol=1e-5,
                               err_msg=f"{arch}: future leak into positions < {t}")
    # and the suffix MUST differ (the perturbation is real)
    assert not np.allclose(np.asarray(la[:, t:]), np.asarray(lb[:, t:]))


@pytest.mark.parametrize("impl", ["xla", "xla_chunked", "pallas"])
def test_attention_impl_causality(impl):
    cfg = _fp32(configs.get_smoke("llama3.2-1b"))
    cfg = dataclasses.replace(cfg, attention_impl=impl, attn_chunk=8)
    b, s, t = 1, 32, 17
    params = M.init(jax.random.PRNGKey(0), cfg)
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    base = jax.random.randint(k1, (b, s), 0, cfg.vocab_size)
    alt = base.at[:, t:].set(jax.random.randint(k2, (b, s - t), 0,
                                                cfg.vocab_size))
    la, _ = M.forward(params, base, cfg)
    lb, _ = M.forward(params, alt, cfg)
    np.testing.assert_allclose(np.asarray(la[:, :t]), np.asarray(lb[:, :t]),
                               rtol=2e-4, atol=2e-4)
